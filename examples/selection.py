"""Vehicle-selection policy comparison (DESIGN.md §11).

Runs the same fleet world under each admission policy — the paper's
admit-everyone baseline, score-based top-k (arXiv:2304.02832's
data x compute x residence ingredients), upload-airtime budget
(arXiv:2210.15496), and the epsilon-greedy bandit — through the
device-resident jit engine, and prints the accuracy / wall-clock /
admitted-fleet table that EXPERIMENTS.md records.

    PYTHONPATH=src python examples/selection.py                # fleet-k100
    PYTHONPATH=src python examples/selection.py fleet-k1000 30
"""
import sys
import time

from repro.core import run_simulation
from repro.core.scenarios import build_world, get_scenario
from repro.selection import SelectionSpec


def policies_for(K: int):
    k = max(1, K // 4)
    return {
        "admit-all": None,
        "weighted-topk": SelectionSpec(policy="weighted-topk", k=k),
        "budget": SelectionSpec(policy="budget", budget=0.002 * K / 4),
        "eps-bandit": SelectionSpec(policy="eps-bandit", k=k, eps=0.1,
                                    resel_every=10),
    }


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "fleet-k100"
    sc = get_scenario(name)
    rounds = int(sys.argv[2]) if len(sys.argv) > 2 else min(sc.rounds, 40)
    vehicles, te_i, te_l, p = build_world(sc, seed=0)
    print(f"{name}: K={p.K}, {rounds} rounds, l={sc.l_iters} — comparing "
          "admission policies on the jit engine\n")

    rows = []
    for pname, spec in policies_for(p.K).items():
        t0 = time.time()
        r = run_simulation(vehicles, te_i, te_l, scheme=sc.scheme,
                           rounds=rounds, l_iters=sc.l_iters, lr=sc.lr,
                           params=p, seed=0, eval_every=rounds,
                           engine="jit", selection=spec)
        dt = time.time() - t0
        admitted = (r.report.selection["n_admitted_final"]
                    if spec is not None else p.K)
        rows.append((pname, admitted, r.final_accuracy(),
                     dt * 1e3 / rounds))

    print(f"{'policy':<15s} {'admitted':>8s} {'final acc':>9s} "
          f"{'ms/round':>9s}")
    for pname, admitted, acc, ms in rows:
        print(f"{pname:<15s} {admitted:>8d} {acc:>9.3f} {ms:>9.1f}")

    base = rows[0]
    best = max(rows[1:], key=lambda r: r[2])
    print(f"\nbest selective policy: {best[0]} "
          f"({best[2]:.3f} vs admit-all {base[2]:.3f}, "
          f"{base[3] / best[3]:.1f}x faster per round)")


if __name__ == "__main__":
    main()

"""Continuous-batching serving example: a fixed slot pool drains a queue of
variable-length requests with no batch barrier (the runtime the decode
shapes measure one step of).

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import BatchedServer


def main():
    cfg = get_config("smollm-360m").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    srv = BatchedServer(cfg, params, n_slots=3, max_seq=48)

    rng = np.random.default_rng(0)
    reqs = [srv.submit(rng.integers(0, cfg.vocab_size, rng.integers(4, 12)),
                       max_new=int(rng.integers(3, 9))) for _ in range(7)]
    print(f"submitted {len(reqs)} requests over {srv.n_slots} slots")

    t0 = time.time()
    ticks = srv.run_until_drained()
    dt = time.time() - t0
    total = sum(len(r.out) for r in reqs)
    print(f"drained in {ticks} ticks / {dt:.1f}s ({total} tokens)")
    for r in reqs:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()

"""Fig. 5 in miniature: MAFL accuracy vs the aggregation proportion beta.

    PYTHONPATH=src python examples/beta_ablation.py
"""
import dataclasses

from repro.channel.params import ChannelParams
from repro.core import run_simulation
from repro.data import partition_vehicles, synth_mnist


def main():
    tr_i, tr_l, te_i, te_l = synth_mnist(n_train=4000, n_test=500, seed=0,
                                         noise=0.5)
    base = ChannelParams()
    vehicles = partition_vehicles(tr_i, tr_l, base, seed=0, scale=0.01)
    for beta in (0.1, 0.3, 0.5, 0.7, 0.9):
        p = dataclasses.replace(base, beta=beta)
        r = run_simulation(vehicles, te_i, te_l, scheme="mafl", rounds=10,
                           l_iters=8, lr=0.05, params=p, eval_every=10,
                           seed=0)
        print(f"beta={beta:.1f}  acc@10 = {r.final_accuracy():.3f}")


if __name__ == "__main__":
    main()

"""Telemetry walkthrough (DESIGN.md §14): rush hour, observed per RSU.

Runs a rush-hour corridor — a platoon density wave entering at the west
end of an eight-RSU highway — with ``metrics="on"``, appends the run's
:class:`~repro.telemetry.report.RunReport` to a JSONL log, and then
renders per-RSU staleness / occupancy / handover curves **from the log
alone**: everything below the run call reads only the JSONL, because the
structured log is the interchange format (``python -m repro.telemetry
report`` renders the same file).

    PYTHONPATH=src python examples/telemetry.py                       # r8-k4000 rush hour
    PYTHONPATH=src python examples/telemetry.py corridor-quick-r2-k8  # 10s smoke
"""
import sys

import numpy as np

from repro.core.scenarios import get_scenario, run_scenario
from repro.telemetry import runlog

BARS = " ▁▂▃▄▅▆▇█"


def spark(xs, width=48):
    """Bucket-averaged unicode sparkline."""
    xs = np.asarray(xs, float)
    if len(xs) > width:
        cuts = np.linspace(0, len(xs), width + 1).astype(int)
        xs = np.array([xs[a:b].mean()
                       for a, b in zip(cuts[:-1], cuts[1:]) if b > a])
    hi = float(xs.max())
    s = np.zeros_like(xs) if hi <= 0 else np.clip(xs, 0, None) / hi
    return "".join(BARS[int(round(v * (len(BARS) - 1)))] for v in s)


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "corridor-rush-hour-r8-k4000"
    sc = get_scenario(name)
    rounds = int(sys.argv[2]) if len(sys.argv) > 2 else sc.rounds
    out = "telemetry_example.jsonl"
    print(f"{name}: K={sc.K}, R={sc.n_rsus}, {rounds} rounds, "
          f"entry={sc.corridor_entry}, metrics=on")
    r = run_scenario(sc, rounds=rounds, engine="corridor",
                     eval_every=rounds, metrics="on")
    runlog.append(out, r.report)
    print(f"final acc {r.final_accuracy():.3f}; run log -> {out}\n")

    # ---- from here on: the JSONL is the only input ----
    d = runlog.load(out)[-1]
    ch = d["channels"]
    n_rsus = d["spec"]["n_rsus"]
    phases = d["phases"]
    print("phases: " + "  ".join(f"{k}={v:.2f}s"
                                 for k, v in sorted(phases.items())))

    edges = d["spec"]["edges"]
    hist = np.asarray(ch["stale_hist"])           # [R, n_bins]
    occ = np.asarray(ch["occupancy"])             # [M, R]
    ho = np.asarray(ch["handover_count"])         # [R]
    print(f"\nstaleness bin edges (s): "
          f"{', '.join(f'{e:.3g}' for e in edges)}")
    print(f"{'RSU':>4s} {'uploads':>8s} {'handovers':>9s}  "
          f"staleness histogram / occupancy over time")
    for j in range(n_rsus):
        print(f"{j:>4d} {int(hist[j].sum()):>8d} {int(ho[j]):>9d}  "
              f"hist |{spark(hist[j], width=len(hist[j]))}|")
        print(f"{'':>23s}  occ  |{spark(occ[:, j])}|")

    flags = np.asarray(ch["handover"], float)
    if flags.any():
        print(f"\ncumulative handovers   |{spark(np.cumsum(flags))}|")
    gap = np.asarray(ch["gap"], float)
    print(f"argmin-pop wait (mean {gap.mean():.4f}s) "
          f"|{spark(gap)}|")
    if sc.corridor_entry == "rush":
        west = occ[:, 0].astype(float)
        east = occ[:, -1].astype(float)
        m = len(west)
        print(f"\nrush wave: west-RSU occupancy falls "
              f"{west[:m // 4].mean():.0f} -> {west[-m // 4:].mean():.0f} "
              f"while east rises {east[:m // 4].mean():.0f} -> "
              f"{east[-m // 4:].mean():.0f} as the platoons roll through")


if __name__ == "__main__":
    main()

"""Mega-fleet quickstart: a thousand vehicles through the device-resident
engine (DESIGN.md §9).

Builds the ``fleet-k1000`` world — 1000 vehicles sharing one synthetic-MNIST
pool, so shards are small and heterogeneity lives in the Table-I delays —
and runs 30 rounds with ``engine="jit"``: the event queue, the AR(1) slot
gains, the stale-snapshot ring, and every pop → aggregate → re-schedule
step execute inside one compiled XLA program; only the planning dry-run and
the final evaluation touch the host.  A cross-check re-runs the first
rounds on the host wave-batched engine and asserts the arrival sequences
agree.

    PYTHONPATH=src python examples/mega_fleet.py                # fleet-k1000
    PYTHONPATH=src python examples/mega_fleet.py platoon-burst-k500
"""
import sys
import time

from repro.core import run_simulation
from repro.core.scenarios import build_world, get_scenario


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "fleet-k1000"
    sc = get_scenario(name)
    vehicles, te_i, te_l, p = build_world(sc, seed=0)
    sizes = [v.size for v in vehicles]
    print(f"{name}: K={p.K}, shards {min(sizes)}..{max(sizes)} images, "
          f"{sc.rounds} rounds, l={sc.l_iters}")

    t0 = time.time()
    r = run_simulation(vehicles, te_i, te_l, scheme=sc.scheme,
                       rounds=sc.rounds, l_iters=sc.l_iters, lr=sc.lr,
                       params=p, seed=0, eval_every=10, engine="jit")
    dt = time.time() - t0
    print(f"jit engine: {sc.rounds} rounds in {dt:.1f}s "
          f"({dt * 1e3 / sc.rounds:.1f} ms/round incl. compile)")
    for rd, acc in r.acc_history:
        print(f"  round {rd:3d}: acc={acc:.3f}")
    uniq = len({rec.vehicle for rec in r.rounds})
    print(f"{uniq} distinct vehicles contributed uploads")

    # cross-check against the host wave engine on a short prefix
    cross = min(10, sc.rounds)
    rb = run_simulation(vehicles, te_i, te_l, scheme=sc.scheme,
                        rounds=cross, l_iters=sc.l_iters, lr=sc.lr,
                        params=p, seed=0, eval_every=cross,
                        engine="batched")
    assert ([(x.round, x.vehicle) for x in rb.rounds]
            == [(x.round, x.vehicle) for x in r.rounds[:cross]]), \
        "engines disagree on the arrival sequence"
    print(f"host-engine cross-check OK ({cross} rounds, identical arrivals)")


if __name__ == "__main__":
    main()

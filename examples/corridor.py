"""Rush hour on the mega-corridor: eight RSUs, 4000 vehicles, end-to-end
through the device-resident corridor engine (DESIGN.md §10).

Builds ``corridor-rush-hour-r8-k4000`` — platoons of 50 packed into the
westmost coverage cell at t=0, a density wave rolling east — and runs it
with ``engine="corridor"``: per-RSU slot queues with vectorized handover
migration, wave-hoisted training, and the periodic cloud tier reconciling
the eight cohort models, all inside one compiled program.  Per-RSU
accuracy curves (from the engine's cohort snapshots) show the cells the
wave has reached learning ahead of the still-empty ones until the cloud
tier pulls the cohorts together.

    PYTHONPATH=src python examples/corridor.py                 # rush hour
    PYTHONPATH=src python examples/corridor.py corridor-r8-k4000
"""
import sys
import time

import numpy as np

from repro.core.mafl import evaluate
from repro.core.scenarios import build_world, get_scenario
from repro.corridor.engine import run_corridor_simulation


def main():
    name = (sys.argv[1] if len(sys.argv) > 1
            else "corridor-rush-hour-r8-k4000")
    sc = get_scenario(name)
    vehicles, te_i, te_l, p = build_world(sc, seed=0)
    sizes = [v.size for v in vehicles]
    print(f"{name}: K={p.K}, R={sc.n_rsus} RSUs, shards "
          f"{min(sizes)}..{max(sizes)} images, {sc.rounds} rounds, "
          f"entry={sc.corridor_entry!r}, reconcile every "
          f"{sc.reconcile_every} ({sc.reconcile_mode})")

    t0 = time.time()
    # eval cadence deliberately offset from the reconcile cadence so the
    # per-RSU snapshots show cohorts *between* cloud-tier reconciles —
    # the cells receiving the wave's uploads diverge, then get pulled back
    r = run_corridor_simulation(sc, vehicles, te_i, te_l, p, seed=0,
                                eval_every=5, record_cohorts=True)
    dt = time.time() - t0
    print(f"corridor engine: {sc.rounds} rounds in {dt:.1f}s "
          f"({dt * 1e3 / sc.rounds:.1f} ms/round incl. compile)")

    from repro.channel import CorridorMobility
    up_rsu = np.asarray(r.extras["up_rsu"])
    print("\nuploads per RSU cell:",
          np.bincount(up_rsu, minlength=sc.n_rsus).tolist())
    corr = CorridorMobility(p, sc.n_rsus, entry=sc.corridor_entry)
    t_end = r.rounds[-1].time
    occ = np.bincount(corr.serving_cells(t_end), minlength=sc.n_rsus)
    print(f"fleet occupancy per cell at t={t_end:.0f}s (the density "
          f"wave): {occ.tolist()}")
    crossed = int(np.sum(corr.serving_cells(t_end)
                         != corr.serving_cells(0.0)))
    print(f"{crossed} of {p.K} vehicles have crossed a coverage boundary "
          "(handover) by then")
    last, re_handovers = {}, 0
    for rec in r.rounds:
        if rec.vehicle in last and last[rec.vehicle] != rec.rsu:
            re_handovers += 1
        last[rec.vehicle] = rec.rsu
    print(f"{re_handovers} consumed uploads landed on a different RSU "
          "than the same vehicle's previous upload")

    print("\nconsensus accuracy:")
    for rd, acc in r.acc_history:
        print(f"  round {rd:3d}: acc={acc:.3f}")

    # per-RSU accuracy curves from the cohort snapshots
    print("\nper-RSU cohort accuracy (rows = eval rounds):")
    header = "  round " + "".join(f"  rsu{j}" for j in range(sc.n_rsus))
    print(header)
    import jax
    for rd, snap in zip(r.extras["eval_rounds"],
                        r.extras["cohort_snapshots"]):
        accs = []
        for j in range(sc.n_rsus):
            cohort = jax.tree_util.tree_map(lambda x, j=j: x[j], snap)
            acc, _ = evaluate(cohort, te_i, te_l)
            accs.append(acc)
        print(f"  {rd:5d} " + "".join(f" {a:.3f}" for a in accs))


if __name__ == "__main__":
    main()

"""Quickstart: the paper in miniature.

The ``paper-k10`` scenario from the registry (DESIGN.md §8) — ten vehicles
with Table-I heterogeneity training the paper's CNN on private shards of a
synthetic-MNIST substitute, the RSU aggregating asynchronously on the
vehicle-batched wave engine.  Compares MAFL (the paper) against
conventional AFL (the baseline) for a few rounds and prints both accuracy
curves.  Any registered world works the same way, e.g.::

    PYTHONPATH=src python examples/quickstart.py            # paper-k10
    PYTHONPATH=src python examples/quickstart.py fleet-k100
"""
import sys
import time

from repro.core.scenarios import build_world, get_scenario, list_scenarios
from repro.core import run_simulation


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "paper-k10"
    print("registered scenarios:", ", ".join(list_scenarios()))
    sc = get_scenario(name)
    t0 = time.time()
    vehicles, te_i, te_l, p = build_world(sc, seed=0)
    print(f"{name}: K={p.K}, per-vehicle D_i:",
          [v.size for v in vehicles[:12]],
          "..." if p.K > 12 else "")

    for scheme in ("mafl", "afl"):
        r = run_simulation(vehicles, te_i, te_l, scheme=scheme, rounds=12,
                           l_iters=8, lr=0.05, eval_every=4, seed=0,
                           params=p)
        curve = ", ".join(f"r{rd}={a:.3f}" for rd, a in r.acc_history)
        print(f"{scheme:5s}: {curve}")
    print(f"done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()

"""Quickstart: the paper in miniature.

Ten vehicles with Table-I heterogeneity train the paper's CNN on private
shards of a synthetic-MNIST substitute; the RSU aggregates asynchronously.
Compares MAFL (the paper) against conventional AFL (the baseline) for a few
rounds and prints both accuracy curves.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.channel.params import ChannelParams
from repro.core import run_simulation
from repro.data import partition_vehicles, synth_mnist


def main():
    t0 = time.time()
    tr_i, tr_l, te_i, te_l = synth_mnist(n_train=4000, n_test=500, seed=0,
                                         noise=0.5)
    p = ChannelParams()
    vehicles = partition_vehicles(tr_i, tr_l, p, seed=0, scale=0.01)
    print("per-vehicle D_i:", [v.size for v in vehicles])

    for scheme in ("mafl", "afl"):
        r = run_simulation(vehicles, te_i, te_l, scheme=scheme, rounds=12,
                           l_iters=8, lr=0.05, eval_every=4, seed=0)
        curve = ", ".join(f"r{rd}={a:.3f}" for rd, a in r.acc_history)
        print(f"{scheme:5s}: {curve}")
    print(f"done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()

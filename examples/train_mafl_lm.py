"""End-to-end driver: federated training of a transformer LM under MAFL.

The aggregation layer is architecture-agnostic, so the same Algorithm-1 loop
that trains the paper's CNN trains any assigned arch; this example runs the
smollm family (the realistic on-vehicle size) reduced to CPU scale.

    PYTHONPATH=src python examples/train_mafl_lm.py [--arch rwkv6-1.6b]
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:] or []
    if "--arch" not in argv:
        argv += ["--arch", "smollm-360m"]
    argv += ["--reduced", "--rounds", "15", "--l-iters", "3",
             "--batch", "8", "--seq-len", "64", "--use-kernel"]
    main(argv)

"""Batched serving example: prefill a prompt batch, decode with a KV cache.

Runs the attention-free rwkv6 family (O(1) decode state) by default; pass
--arch to pick any assigned architecture.

    PYTHONPATH=src python examples/serve_batched.py [--arch jamba-v0.1-52b]
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:] or []
    if "--arch" not in argv:
        argv += ["--arch", "rwkv6-1.6b"]
    argv += ["--reduced", "--batch", "4", "--prompt-len", "32", "--gen", "12"]
    main(argv)

"""Determinism regression: the same (seed, scenario) must reproduce the
simulation bit-for-bit — identical round traces and bitwise-equal final
parameters across two runs — for every engine, plus clear errors for
unknown engine/scenario names."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.client as client_mod
from repro.channel.params import ChannelParams
from repro.core import run_simulation
from repro.core.scenarios import run_scenario
from repro.data import partition_vehicles, synth_mnist

ENGINES = ("serial", "batched", "jit")


def _fake_local_scan(params, images, labels, lr):
    h = (jnp.mean(images.astype(jnp.float32))
         + jnp.mean(labels.astype(jnp.float32)))
    out = jax.tree_util.tree_map(
        lambda w: w * (1.0 - lr * 0.01) + 1e-3 * h, params)
    return out, h


@pytest.fixture()
def stub_trainer(monkeypatch):
    monkeypatch.setattr(client_mod, "_local_scan", _fake_local_scan)
    monkeypatch.setattr(client_mod, "_local_scan_jit", _fake_local_scan)
    monkeypatch.setattr(
        client_mod, "_local_scan_vmap",
        jax.vmap(_fake_local_scan, in_axes=(0, 0, 0, None)))


@pytest.fixture(scope="module")
def k4_world():
    tr_i, tr_l, te_i, te_l = synth_mnist(n_train=600, n_test=120, seed=0,
                                         noise=0.35)
    p = dataclasses.replace(ChannelParams(), K=4)
    veh = partition_vehicles(tr_i, tr_l, p, seed=0, scale=0.012)
    return veh, te_i, te_l, p


def _trace(r):
    return [(rec.round, rec.vehicle, rec.time, rec.upload_delay,
             rec.train_delay, rec.weight) for rec in r.rounds]


@pytest.mark.parametrize("engine", ENGINES)
def test_same_seed_bitwise_identical(engine, k4_world, stub_trainer):
    veh, te_i, te_l, p = k4_world
    runs = [run_simulation(veh, te_i, te_l, scheme="mafl", rounds=7,
                           l_iters=2, lr=0.05, eval_every=7, seed=3,
                           params=p, engine=engine) for _ in range(2)]
    assert _trace(runs[0]) == _trace(runs[1])       # bitwise: == on floats
    assert runs[0].acc_history == runs[1].acc_history
    for x, y in zip(jax.tree_util.tree_leaves(runs[0].final_params),
                    jax.tree_util.tree_leaves(runs[1].final_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_jit_engine_real_cnn_bitwise_identical(k4_world):
    """Un-stubbed double run of the compiled engine (the cached program is
    replayed, so this also guards the program-cache keying)."""
    veh, te_i, te_l, p = k4_world
    runs = [run_simulation(veh, te_i, te_l, scheme="mafl", rounds=4,
                           l_iters=1, lr=0.05, eval_every=4, seed=0,
                           params=p, engine="jit") for _ in range(2)]
    assert _trace(runs[0]) == _trace(runs[1])
    for x, y in zip(jax.tree_util.tree_leaves(runs[0].final_params),
                    jax.tree_util.tree_leaves(runs[1].final_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_different_seeds_differ(k4_world, stub_trainer):
    veh, te_i, te_l, p = k4_world
    a, b = (run_simulation(veh, te_i, te_l, scheme="mafl", rounds=7,
                           l_iters=2, lr=0.05, eval_every=7, seed=s,
                           params=p, engine="jit") for s in (0, 1))
    assert _trace(a) != _trace(b)


def test_unknown_engine_rejected_with_clear_error(k4_world):
    veh, te_i, te_l, p = k4_world
    with pytest.raises(ValueError, match="unknown engine 'warp'"):
        run_simulation(veh, te_i, te_l, rounds=2, params=p, engine="warp")
    with pytest.raises(ValueError, match="expected one of.*'jit'"):
        run_scenario("quick-k5", engine="warp")


def test_unknown_scenario_rejected_with_known_names():
    with pytest.raises(KeyError, match="unknown scenario 'nope'.*quick-k5"):
        run_scenario("nope")

"""Property-based cross-engine conformance (DESIGN.md §9): for randomly
drawn worlds — fleet size, rounds, data heterogeneity, channel coherence —
the serial, batched, and jit engines must produce the same
(round, vehicle) arrival sequence, event times equal to f32 tolerance (the
jit engine carries time in ``f32[K]`` slot arrays; the host engines use
f64), and allclose final global parameters.

Property cases run under the ``_hypothesis_compat`` shim, so without
``hypothesis`` they degrade to deterministic bound/midpoint sweeps instead
of being skipped.  The fast lane drives the orchestration with the stubbed
trainer from ``test_engine_equivalence``; one small real-CNN world runs
un-stubbed, and the heavier real-CNN world is slow-marked.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

import repro.core.client as client_mod
from repro.channel import RayleighAR1, slot_gain_table
from repro.channel.params import ChannelParams
from repro.core import run_simulation
from repro.data import partition_vehicles, synth_mnist

ENGINES = ("serial", "batched", "jit")


def _fake_local_scan(params, images, labels, lr):
    """Pure-jnp trainer stub (shared with test_engine_equivalence): folds
    the exact minibatch stream into the parameters so any divergence in
    payload snapshots, batch pairing, or RNG order shows up in the
    result."""
    h = (jnp.mean(images.astype(jnp.float32))
         + jnp.mean(labels.astype(jnp.float32)))
    out = jax.tree_util.tree_map(
        lambda w: w * (1.0 - lr * 0.01) + 1e-3 * h, params)
    return out, h


@pytest.fixture()
def stub_trainer(monkeypatch):
    monkeypatch.setattr(client_mod, "_local_scan", _fake_local_scan)
    monkeypatch.setattr(client_mod, "_local_scan_jit", _fake_local_scan)
    monkeypatch.setattr(
        client_mod, "_local_scan_vmap",
        jax.vmap(_fake_local_scan, in_axes=(0, 0, 0, None)))


_WORLD_CACHE = {}


def _world(K: int, scale: float, rho: float, noniid: bool = False):
    key = (K, scale, rho, noniid)
    if key not in _WORLD_CACHE:
        tr_i, tr_l, te_i, te_l = synth_mnist(n_train=600, n_test=120,
                                             seed=0, noise=0.35)
        p = dataclasses.replace(ChannelParams(), K=K, fading_rho=rho)
        veh = partition_vehicles(tr_i, tr_l, p, seed=0, scale=scale,
                                 dirichlet_alpha=0.3 if noniid else None)
        _WORLD_CACHE[key] = (veh, te_i, te_l, p)
    return _WORLD_CACHE[key]


def _run(world, engine, rounds, l_iters=2, scheme="mafl", **kw):
    veh, te_i, te_l, p = world
    return run_simulation(veh, te_i, te_l, scheme=scheme, rounds=rounds,
                          l_iters=l_iters, lr=0.05, eval_every=max(rounds, 1),
                          seed=0, params=p, engine=engine, **kw)


def _assert_conformant(results: dict, param_atol=1e-5):
    ref = results["serial"]
    ref_seq = [(r.round, r.vehicle) for r in ref.rounds]
    ref_t = np.array([r.time for r in ref.rounds])
    ref_w = np.array([r.weight for r in ref.rounds])
    for name, res in results.items():
        seq = [(r.round, r.vehicle) for r in res.rounds]
        assert seq == ref_seq, f"{name}: arrival sequence diverged"
        t = np.array([r.time for r in res.rounds])
        np.testing.assert_allclose(t, ref_t, rtol=2e-5, atol=1e-3,
                                   err_msg=f"{name}: event times")
        w = np.array([r.weight for r in res.rounds])
        np.testing.assert_allclose(w, ref_w, rtol=1e-4, atol=1e-4,
                                   err_msg=f"{name}: delay weights")
        for x, y in zip(jax.tree_util.tree_leaves(ref.final_params),
                        jax.tree_util.tree_leaves(res.final_params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=param_atol,
                                       err_msg=f"{name}: final params")


@given(st.integers(2, 6), st.integers(3, 10), st.floats(0.008, 0.03),
       st.floats(0.6, 0.99))
@settings(max_examples=5, deadline=None)
def test_random_worlds_conform(K, rounds, scale, rho):
    """The property: any (K, rounds, heterogeneity, coherence) world gives
    identical traces and allclose params across all three engines."""
    # fixture-free stubbing: @given composes awkwardly with fixtures under
    # the shim, so patch manually around the body
    saved = (client_mod._local_scan, client_mod._local_scan_jit,
             client_mod._local_scan_vmap)
    client_mod._local_scan = _fake_local_scan
    client_mod._local_scan_jit = _fake_local_scan
    client_mod._local_scan_vmap = jax.vmap(_fake_local_scan,
                                           in_axes=(0, 0, 0, None))
    try:
        world = _world(K, scale, rho)
        results = {e: _run(world, e, rounds) for e in ENGINES}
        _assert_conformant(results)
    finally:
        (client_mod._local_scan, client_mod._local_scan_jit,
         client_mod._local_scan_vmap) = saved


def test_noniid_world_conforms(stub_trainer):
    world = _world(4, 0.015, 0.95, noniid=True)
    results = {e: _run(world, e, 8) for e in ENGINES}
    _assert_conformant(results)


def test_afl_and_fedasync_conform(stub_trainer):
    world = _world(3, 0.015, 0.95)
    for scheme in ("afl", "fedasync"):
        results = {e: _run(world, e, 6, scheme=scheme) for e in ENGINES}
        _assert_conformant(results)


def test_literal_interpretation_conforms(stub_trainer):
    world = _world(3, 0.015, 0.95)
    results = {e: _run(world, e, 6, interpretation="literal")
               for e in ENGINES}
    _assert_conformant(results)


def test_kernel_aggregation_conforms(stub_trainer):
    """use_kernel=True routes aggregation through the Pallas weighted_agg
    kernel inside the jit engine's scan as well as the host path."""
    world = _world(3, 0.015, 0.95)
    results = {e: _run(world, e, 5, use_kernel=True) for e in ENGINES}
    _assert_conformant(results, param_atol=1e-4)


def test_real_cnn_small_world_conforms():
    """Un-stubbed end-to-end conformance on a small world: real CNN local
    training through all three engines."""
    world = _world(3, 0.01, 0.95)
    results = {e: _run(world, e, 5, l_iters=1) for e in ENGINES}
    _assert_conformant(results, param_atol=2e-3)
    accs = {e: [a for _, a in r.acc_history] for e, r in results.items()}
    np.testing.assert_allclose(accs["jit"], accs["serial"], atol=0.05)


@pytest.mark.slow
def test_real_cnn_k5_world_conforms():
    world = _world(5, 0.02, 0.95)
    results = {e: _run(world, e, 10, l_iters=2) for e in ENGINES}
    _assert_conformant(results, param_atol=5e-3)


def test_jit_mesh_shard_map_matches_unsharded(stub_trainer):
    """Wave training sharded over the (data, model) host mesh via
    shard_map must agree with the unsharded program (DESIGN.md §5, §9)."""
    from repro.core.jit_engine import run_simulation_jit
    from repro.launch.mesh import make_host_mesh
    veh, te_i, te_l, p = _world(3, 0.015, 0.95)
    kw = dict(scheme="mafl", rounds=5, l_iters=1, lr=0.05, eval_every=5,
              seed=0, params=p)
    r0 = run_simulation_jit(veh, te_i, te_l, **kw)
    r1 = run_simulation_jit(veh, te_i, te_l, mesh=make_host_mesh(), **kw)
    assert ([(x.round, x.vehicle) for x in r0.rounds]
            == [(x.round, x.vehicle) for x in r1.rounds])
    for x, y in zip(jax.tree_util.tree_leaves(r0.final_params),
                    jax.tree_util.tree_leaves(r1.final_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_slot_gain_table_matches_sequential_cache():
    """The vectorized prefix-scan table must reproduce the sequential
    AR(1) chain (same RNG bitstream, f64 round-off only)."""
    p = dataclasses.replace(ChannelParams(), K=7)
    table = slot_gain_table(p, seed=3, n_slots=50)
    ref = RayleighAR1(p, seed=3)
    seq = ref.steps_block(50)
    np.testing.assert_allclose(table, seq, rtol=1e-10, atol=1e-12)
    assert table.shape == (50, 7)
    assert slot_gain_table(p, seed=3, n_slots=0).shape == (0, 7)


def test_platoon_params_share_leader_delays():
    """platoon=n gives convoys identical Table-I compute/data (bursty
    arrivals for platoon-burst-k500)."""
    from repro.channel import training_delay
    p = dataclasses.replace(ChannelParams(), K=9, platoon=3)
    delays = [training_delay(p, i) for i in range(1, 10)]
    assert delays[0] == delays[1] == delays[2]
    assert delays[3] == delays[4] == delays[5]
    assert delays[0] != delays[3] != delays[6]
    # platoon=0 keeps per-vehicle heterogeneity
    p0 = dataclasses.replace(ChannelParams(), K=9)
    d0 = [training_delay(p0, i) for i in range(1, 10)]
    assert len(set(d0)) == 9


def test_jit_rejects_fedbuff():
    world = _world(2, 0.015, 0.95)
    with pytest.raises(ValueError, match="fedbuff"):
        _run(world, "jit", 3, scheme="fedbuff")


# ---------------------------------------------------------------------------
# corridor conformance: serial handover reference vs engine="corridor"
# (DESIGN.md §10) — identical event traces, allclose final models
# ---------------------------------------------------------------------------
def _assert_corridor_conformant(ref, res, param_atol=1e-5):
    assert res.scheme.endswith("+corridor")
    # identical arrival traces: (per-RSU round, vehicle, serving RSU)
    assert ([(r.round, r.vehicle, r.rsu) for r in res.rounds]
            == [(r.round, r.vehicle, r.rsu) for r in ref.rounds]), \
        "corridor: arrival sequence diverged"
    np.testing.assert_allclose([r.time for r in res.rounds],
                               [r.time for r in ref.rounds],
                               rtol=2e-5, atol=1e-3,
                               err_msg="corridor: event times")
    np.testing.assert_allclose([r.weight for r in res.rounds],
                               [r.weight for r in ref.rounds],
                               rtol=1e-4, atol=1e-4,
                               err_msg="corridor: delay weights")
    assert [rd for rd, _ in res.acc_history] == \
           [rd for rd, _ in ref.acc_history]
    for x, y in zip(jax.tree_util.tree_leaves(ref.final_params),
                    jax.tree_util.tree_leaves(res.final_params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=param_atol,
                                   err_msg="corridor: final params")


def _corridor_pair(name, param_atol=1e-5, **kw):
    from repro.core.scenarios import run_scenario
    ref = run_scenario(name, engine="serial", seed=0, **kw)
    res = run_scenario(name, engine="corridor", seed=0, **kw)
    _assert_corridor_conformant(ref, res, param_atol=param_atol)
    return ref, res


def test_corridor_conforms_highway_k40(stub_trainer):
    """The acceptance world: engine='corridor' reproduces the serial
    handover trace exactly on highway-k40-handover."""
    ref, res = _corridor_pair("highway-k40-handover", rounds=12,
                              eval_every=6, l_iters=1)
    # handover actually exercised: uploads land on several RSUs
    assert len({r.rsu for r in ref.rounds}) > 1


def test_corridor_conforms_r4_k400(stub_trainer):
    """Conformance-sized mega-corridor world (400 vehicles, 4 RSUs)."""
    _corridor_pair("corridor-r4-k400", rounds=10, eval_every=5)


def test_corridor_conforms_ema_mode(stub_trainer):
    """EMA cloud tier: cohorts keep identity between reconciliations on
    both engines."""
    _corridor_pair("corridor-quick-r2-k8", rounds=8, eval_every=4,
                   reconcile_mode="ema", reconcile_tau=0.3)


def test_corridor_conforms_afl_fedasync(stub_trainer):
    for scheme in ("afl", "fedasync"):
        _corridor_pair("corridor-quick-r2-k8", rounds=6, eval_every=6,
                       scheme=scheme)


def test_corridor_real_cnn_small_world_conforms():
    """Un-stubbed end-to-end corridor conformance: real CNN training
    through both engines, accuracy histories equal."""
    ref, res = _corridor_pair("corridor-quick-r2-k8", rounds=6,
                              eval_every=3, param_atol=2e-3)
    np.testing.assert_allclose([a for _, a in res.acc_history],
                               [a for _, a in ref.acc_history], atol=0.05)


@pytest.mark.slow
def test_corridor_real_cnn_rush_hour_conforms():
    """Rush-hour entry profile (platoon bursts at the west end) through
    a shrunken r2 world, un-stubbed."""
    _corridor_pair("corridor-quick-r2-k8", rounds=8, eval_every=4,
                   corridor_entry="rush", param_atol=5e-3,
                   channel_overrides=(("platoon", 4),))

"""Unit tests for the channel/mobility substrate (Eqs. 3-8, Table I)."""
import numpy as np
import pytest

from repro.channel import (ChannelParams, Mobility, RayleighAR1,
                           shannon_rate, training_delay, upload_delay)


@pytest.fixture
def p():
    return ChannelParams()


def test_table1_constants(p):
    assert p.K == 10 and p.v == 20.0 and p.H == 10.0 and p.d_y == 10.0
    assert p.B == 1e5 and p.p_m == 0.1 and p.alpha == 2.0
    assert p.sigma2 == 1e-14                       # 1e-11 mW in W
    assert p.beta == 0.5 and p.zeta == 0.9 and p.gamma == 0.9


def test_delta_and_data_profile(p):
    # Section V-A: delta_i = 1.5 (i+5) 1e8 ; D_i = 2250 + 3750 i
    assert p.delta(1) == pytest.approx(9e8)
    assert p.delta(10) == pytest.approx(2.25e9)
    assert p.data_count(1) == 6000 and p.data_count(10) == 39750


def test_mobility_eq3_eq4(p):
    mob = Mobility(p, x0=np.zeros(p.K))
    # at t: d_x = v*t ; distance includes d_y and H offsets (Eq. 4)
    pos = mob.position(0, 3.0)
    assert pos[0] == pytest.approx(60.0)
    d = mob.distance(0, 3.0)
    assert d == pytest.approx(np.sqrt(60.0 ** 2 + 10 ** 2 + 10 ** 2))


def test_mobility_wraparound(p):
    mob = Mobility(p, x0=np.full(p.K, p.coverage - 1.0))
    d1 = mob.position(0, 0.0)[0]
    d2 = mob.position(0, 1.0)[0]           # crosses the coverage edge
    assert d1 == pytest.approx(p.coverage - 1.0)
    assert -p.coverage <= d2 <= p.coverage


def test_shannon_rate_monotonic_in_distance(p):
    r_near = shannon_rate(p, 1.0, 20.0)
    r_far = shannon_rate(p, 1.0, 200.0)
    assert r_near > r_far > 0


def test_upload_delay_eq6(p):
    rate = shannon_rate(p, 1.0, 50.0)
    assert upload_delay(p, rate) == pytest.approx(p.model_bits / rate)


def test_training_delay_eq8(p):
    # C_l = D_i C_y / delta_i
    assert training_delay(p, 1) == pytest.approx(6000 * 1e5 / 9e8)
    assert training_delay(p, 10) == pytest.approx(39750 * 1e5 / 2.25e9)
    # slower, data-heavier vehicles train longer
    delays = [training_delay(p, i) for i in range(1, 11)]
    assert delays == sorted(delays)


def test_rayleigh_ar1_statistics(p):
    fad = RayleighAR1(p, seed=0)
    gains = np.array([fad.step() for _ in range(2000)])
    # |CN(0,1)|^2 is Exp(1): mean 1
    assert gains.mean() == pytest.approx(1.0, abs=0.15)
    # AR(1) correlation across one slot ~ rho^2
    x = gains[:-1].ravel()
    y = gains[1:].ravel()
    corr = np.corrcoef(x, y)[0, 1]
    assert corr > 0.5

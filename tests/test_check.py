"""repro.check analyzer-suite tests (DESIGN.md §13):

- grid-race classification of the production kernels and the known-racy
  fixture; the per-backend legality verdict `select_impl` derives from it;
- boundary lint: engine modules clean, the leaky fixture flagged on the
  right rules, seeded f64/.item() injections into real engine source
  caught, the planner fixture's PLN hits;
- dtype-flow: synthetic bf16 dot/arithmetic flagged, storage-only clean;
- waiver mechanics and the in-process CLI (exit codes, --list-rules,
  --format=json).
"""
import json
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.check import config
from repro.check.boundary import check_file, check_source
from repro.check.findings import RULES, Finding, apply_waivers
from repro.check.pallas_race import all_reports, analyze_callable, get_report
from repro.kernels.dispatch import resolve_interpret, select_impl

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "src/repro/check/fixtures"


# ---------------------------------------------------------------------------
# grid-race detector
# ---------------------------------------------------------------------------
EXPECTED_CLASSIFICATION = {
    "weighted_agg.weighted_agg_2d": ("parallel-safe", ()),
    "weighted_agg.ring_agg_2d": ("sequential-axis-required", (1,)),
    "cross_entropy.cross_entropy_tiled": ("sequential-axis-required", (1,)),
    "decode_attention.decode_attention_bkv": (
        "sequential-axis-required", (1,)),
    "swa_attention.swa_attention_bhsd": ("sequential-axis-required", (2,)),
}


def test_production_kernels_classify_as_documented():
    for rep in all_reports():
        cls, axes = EXPECTED_CLASSIFICATION[rep.kernel_id]
        assert rep.classification == cls, rep
        assert rep.revisit_axes == axes, rep


def test_legality_verdict_follows_classification():
    safe = get_report("weighted_agg.weighted_agg_2d")
    seq = get_report("weighted_agg.ring_agg_2d")
    # interpreter-only on cpu (no Mosaic lowering), gpu needs parallel-safe,
    # tpu sequentialises the revisited axis
    assert safe.compiled_legal == {"cpu": False, "gpu": True, "tpu": True}
    assert seq.compiled_legal == {"cpu": False, "gpu": False, "tpu": True}


def test_racy_fixture_classifies_racy_and_illegal_everywhere():
    from repro.check.fixtures.racy_kernel import invoke

    rep = analyze_callable("fixtures.racy_sum", "racy_sum", invoke)
    assert rep.classification == "racy"
    assert rep.compiled_legal == {"cpu": False, "gpu": False, "tpu": False}


def test_select_impl_truth_table():
    seq = get_report("weighted_agg.ring_agg_2d")
    safe = get_report("weighted_agg.weighted_agg_2d")
    # explicit interpret bool always wins
    assert select_impl(seq, "tpu", interpret=True) == "interpret"
    assert select_impl(seq, "cpu", interpret=False) == "compiled"
    # None resolves from the verdict
    assert select_impl(seq, "tpu") == "compiled"
    assert select_impl(seq, "gpu") == "interpret"
    assert select_impl(seq, "gpu", fallback="ref") == "fallback"
    assert select_impl(seq, "gpu", fallback="ref",
                       force_kernel=True) == "interpret"
    assert select_impl(safe, "gpu", fallback="ref") == "compiled"
    assert select_impl(safe, "cpu", fallback="ref") == "fallback"


def test_resolve_interpret_matches_backend_verdict():
    # on this host (cpu) compiled pallas is illegal -> interpreter
    assert jax.default_backend() == "cpu"
    assert resolve_interpret("weighted_agg.ring_agg_2d") is True
    assert resolve_interpret("weighted_agg.ring_agg_2d", False) is False
    assert resolve_interpret("weighted_agg.ring_agg_2d", True) is True


# ---------------------------------------------------------------------------
# boundary lint
# ---------------------------------------------------------------------------
def test_engine_modules_lint_clean():
    for suffix in config.ENGINE_MODULES:
        path = REPO / "src" / suffix
        live = [f for f in check_file(path) if not f.waived]
        assert not live, [f.format() for f in live]


def test_leaky_fixture_hits_every_bnd_rule():
    findings = check_file(FIXTURES / "leaky_engine.py")
    rules = {f.rule for f in findings}
    assert {"BND001", "BND002", "BND003", "BND004", "BND005"} <= rules, \
        [f.format() for f in findings]
    # the Python-branch and for-loop hits land on distinct lines
    bnd2_lines = {f.line for f in findings if f.rule == "BND002"}
    assert len(bnd2_lines) >= 2


def test_bad_planner_fixture_hits_pln_rules():
    src = (FIXTURES / "bad_planner.py").read_text()
    # feed it through under a planner path so the planner dual applies
    findings = check_source("src/repro/corridor/plan.py", src)
    rules = {f.rule for f in findings}
    assert "PLN001" in rules and "PLN002" in rules, \
        [f.format() for f in findings]


def test_seeded_injection_into_real_engine_is_caught():
    src = (REPO / "src/repro/core/jit_engine.py").read_text()
    anchor = "i = jnp.argmin(qt)                          # pop"
    assert anchor in src
    inject = (anchor
              + "\n                    bad64 = qt.astype(jnp.float64)"
              + "\n                    badhost = qt[0].item()")
    findings = check_source("src/repro/core/jit_engine.py",
                            src.replace(anchor, inject, 1))
    rules = {f.rule for f in findings if not f.waived}
    assert "BND004" in rules and "BND003" in rules, \
        [f.format() for f in findings]


def test_static_argnames_are_not_tainted():
    src = textwrap.dedent("""
        import functools, jax
        import jax.numpy as jnp

        @functools.partial(jax.jit, static_argnames=("block",))
        def f(x, block):
            if block > 4:
                x = x * 2
            return x

        g = jax.checkpoint(f, static_argnums=(1,))
    """)
    assert check_source("t.py", src) == []


# ---------------------------------------------------------------------------
# dtype flow
# ---------------------------------------------------------------------------
def test_dtype_flow_flags_bf16_compute():
    from repro.check.dtype_flow import check_jaxpr

    def bad(a, b):
        return (a @ b).astype(jnp.float32), a + a

    x = jnp.ones((4, 4), jnp.bfloat16)
    jaxpr = jax.make_jaxpr(bad)(x, x)
    rules = {f.rule for f in check_jaxpr(jaxpr, allow_bf16=True, path="<t>")}
    assert rules == {"DTF001", "DTF002"}
    rules = {f.rule for f in check_jaxpr(jaxpr, allow_bf16=False, path="<t>")}
    assert rules == {"DTF003"}


def test_dtype_flow_allows_bf16_storage_roles():
    from repro.check.dtype_flow import check_jaxpr

    def ok(a, b):
        wide = a.astype(jnp.float32) @ b.astype(jnp.float32)
        return wide.astype(jnp.bfloat16).reshape(-1)

    x = jnp.ones((4, 4), jnp.bfloat16)
    jaxpr = jax.make_jaxpr(ok)(x, x)
    assert check_jaxpr(jaxpr, allow_bf16=True, path="<t>") == []


def test_engine_dtype_probes_clean():
    from repro.check.dtype_flow import probe_dtype_flow

    assert [f.format() for f in probe_dtype_flow()] == []


def test_plan_shape_probe_clean():
    from repro.check.plan_shapes import probe_plan_shapes

    assert [f.format() for f in probe_plan_shapes()] == []


# ---------------------------------------------------------------------------
# waivers + CLI
# ---------------------------------------------------------------------------
def test_waiver_suppresses_matching_rule_only():
    src = ("x = 1\n"
           "y = 2  # repro-check: waive[BND004] fixture data is f64\n"
           "z = 3\n")
    fs = [Finding("BND004", "w.py", 2, "m"),
          Finding("BND003", "w.py", 2, "m"),
          Finding("BND004", "w.py", 3, "m")]   # line below comment: waived
    out = apply_waivers(fs, {"w.py": src})
    assert [f.waived for f in out] == [True, False, True]
    assert out[0].waive_reason == "fixture data is f64"


def test_waiver_without_reason_is_ignored():
    from repro.check.findings import load_waivers

    assert load_waivers("x  # repro-check: waive[BND004]\n") == {}


def test_cli_list_rules_and_json(capsys):
    from repro.check.runner import main

    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    assert all(rid in out for rid in RULES)

    assert main(["src/repro/check/findings.py", "--no-probes",
                 "--format=json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert {r["kernel_id"] for r in payload["kernels"]} == set(
        EXPECTED_CLASSIFICATION)
    assert payload["findings"] == []


def test_cli_strict_exit_codes(tmp_path, capsys):
    from repro.check.runner import main

    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return float(x)
    """))
    assert main([str(bad), "--no-probes", "--strict"]) == 1
    assert "BND003" in capsys.readouterr().out
    bad.write_text(bad.read_text().replace(
        "return float(x)",
        "return float(x)  # repro-check: waive[BND003] test waiver"))
    assert main([str(bad), "--no-probes", "--strict"]) == 0


def test_fixture_corpus_is_excluded_from_default_scans():
    from repro.check.runner import collect_files

    files = collect_files(["src"])
    assert files, "scan set must not be empty"
    assert not any("check/fixtures" in f.as_posix() for f in files)

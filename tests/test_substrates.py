"""Substrate tests: optimizers, checkpointing, data pipeline, sharding specs."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel.params import ChannelParams
from repro.checkpointing import (latest_checkpoint, load_checkpoint,
                                 save_checkpoint)
from repro.data import TokenPipeline, partition_vehicles, synth_mnist, synth_tokens
from repro.optim import (adam, apply_updates, clip_by_global_norm,
                         cosine_decay, momentum_sgd, sgd)


# ---------------------------------------------------------------------------
# optim
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("make_opt", [lambda: sgd(0.1),
                                      lambda: momentum_sgd(0.05),
                                      lambda: adam(0.1)])
def test_optimizers_converge_on_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.tree_util.tree_map(lambda w: 2 * w, params)  # d/dw w^2
        upd, state = opt.update(grads, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_sgd_is_paper_eq2():
    opt = sgd(0.5)
    params = {"w": jnp.array([2.0])}
    state = opt.init(params)
    upd, _ = opt.update({"w": jnp.array([1.0])}, state, params)
    assert float(apply_updates(params, upd)["w"][0]) == pytest.approx(1.5)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-4)


def test_cosine_schedule_endpoints():
    fn = cosine_decay(1.0, 100)
    assert float(fn(jnp.int32(0))) == pytest.approx(1.0)
    assert float(fn(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"layer": {"w": jnp.arange(12.0).reshape(3, 4),
                      "b": jnp.ones((4,), jnp.bfloat16)},
            "stack": [jnp.zeros((2, 2)), jnp.full((1,), 7.0)]}
    d = str(tmp_path)
    save_checkpoint(d, 3, tree, meta={"round": 3})
    path = latest_checkpoint(d)
    assert path and path.endswith("ckpt_00000003.npz")
    restored = load_checkpoint(path, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_checkpoint_retention(tmp_path):
    d = str(tmp_path)
    for step in range(6):
        save_checkpoint(d, step, {"x": jnp.zeros(1)}, keep=2)
    files = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
    assert files == ["ckpt_00000004.npz", "ckpt_00000005.npz"]


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
def test_partition_follows_di_profile():
    p = ChannelParams()
    imgs, labels, _, _ = synth_mnist(n_train=5000, n_test=10, seed=0)
    veh = partition_vehicles(imgs, labels, p, seed=0, scale=0.01)
    sizes = [v.size for v in veh]
    # D_i = (2250 + 3750 i) * scale
    expect = [int((2250 + 3750 * i) * 0.01) for i in range(1, 11)]
    assert sizes == expect
    assert veh[0].index == 1 and veh[-1].index == 10


def test_synth_mnist_is_learnably_separable():
    tr_i, tr_l, te_i, te_l = synth_mnist(n_train=512, n_test=128, seed=0,
                                         noise=0.3)
    assert tr_i.shape == (512, 28, 28, 1) and tr_i.min() >= 0
    # nearest-prototype classification should beat chance by a wide margin
    protos = np.stack([tr_i[tr_l == c].mean(0) for c in range(10)])
    d = ((te_i[:, None] - protos[None]) ** 2).sum((2, 3, 4))
    acc = (d.argmin(1) == te_l).mean()
    assert acc > 0.6


def test_token_pipeline_batches():
    corpus = synth_tokens(16, 64, vocab=100, seed=0)
    pipe = TokenPipeline(corpus, batch=4, seq_len=32, seed=0)
    b1 = next(pipe)
    assert b1.shape == (4, 33) and b1.dtype == np.int32
    assert (b1 >= 0).all() and (b1 < 100).all()


def test_synth_tokens_have_bigram_signal():
    toks = synth_tokens(64, 128, vocab=50, seed=0)
    # repeated bigrams far above uniform chance
    big = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            big[(a, b)] = big.get((a, b), 0) + 1
    top = sorted(big.values())[-20:]
    assert sum(top) > len(toks) * 128 * 20 / (50 * 50) * 3


# ---------------------------------------------------------------------------
# sharding specs (AbstractMesh — no devices needed)
# ---------------------------------------------------------------------------
def test_param_specs_structure_and_rules():
    from jax.sharding import AbstractMesh, PartitionSpec as P
    from repro.configs import get_config
    from repro.launch.steps import param_shapes
    from repro.sharding import param_specs

    mesh = AbstractMesh((("data", 16), ("model", 16)))
    cfg = get_config("llama3-405b")
    specs = param_specs(cfg, mesh, fsdp=True)
    shapes = param_shapes(cfg)
    assert jax.tree_util.tree_structure(specs) == \
        jax.tree_util.tree_structure(shapes)
    # embed [V, d]: vocab on model
    assert specs["embed"]["table"][0] == "model"
    # stacked leaves never shard the leading period axis
    stack_specs = jax.tree_util.tree_leaves(
        specs["stack"], is_leaf=lambda x: isinstance(x, P))
    assert all(s[0] is None for s in stack_specs)


def test_param_specs_degrade_on_indivisible():
    from jax.sharding import AbstractMesh
    from repro.configs import get_config
    from repro.sharding import param_specs

    mesh = AbstractMesh((("data", 16), ("model", 16)))
    cfg = get_config("smollm-360m")          # 15 heads: not divisible by 16
    specs = param_specs(cfg, mesh, fsdp=False)
    wq_spec = specs["stack"]["sub0"]["mixer"]["wq"]
    assert wq_spec[2] is None                # heads dim (after period axis)
    mlp_spec = specs["stack"]["sub0"]["mlp"]["w_gate"]
    assert mlp_spec[2] == "model"            # 2560 % 16 == 0 -> sharded


def test_cache_specs_shard_batch_and_seq():
    from jax.sharding import AbstractMesh
    from repro.configs import get_config
    from repro.sharding import cache_specs

    mesh = AbstractMesh((("data", 16), ("model", 16)))
    cfg = get_config("mistral-nemo-12b")
    specs = cache_specs(cfg, mesh, batch=128, max_seq=32768)
    kspec = specs["stack"]["sub0"]["mixer"]["k"]
    assert kspec[0] is None                  # leading period axis
    # PartitionSpec entries may be bare axis names or 1-tuples of them
    unwrap = lambda e: e[0] if isinstance(e, tuple) and len(e) == 1 else e
    assert unwrap(kspec[1]) == "data" and unwrap(kspec[2]) == "model"

"""Flat-vs-pytree conformance over the golden traces (DESIGN.md §12).

The packed flat fast path is the device engines' default layout, so the
golden-trace suite already pins it; this module additionally pins the
*relationship*: on every golden fixture the flat path must produce the
bit-identical final model of the legacy pytree path (use_kernel=False,
admit-all — the configurations where XLA:CPU's context-dependent FMA
contraction is pinned by the fixtures; see DESIGN.md §12 for why bitwise
equality across program structures cannot be promised universally on this
backend).  fedasync / active-selection flat runs are pinned to the pytree
path at ulp tolerance with exact event traces instead.

Also covers the bf16 ring mode: explicit opt-in, exact timeline, bounded
accuracy drift, and the host-engine / pytree gates that refuse it.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro.checkpointing.checkpoint import tree_digest
from repro.core.codegen import codegen_matches
from repro.core.scenarios import run_scenario

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
FIXTURES = ("paper-k10", "highway-k40-handover", "corridor-quick-r2-k8")


def _load(name):
    with open(os.path.join(GOLDEN_DIR, f"{name}.json")) as f:
        return json.load(f)


_RUNS = {}


def _run(name, engine, flat, **kw):
    key = (name, engine, flat, tuple(sorted(kw.items())))
    if key not in _RUNS:
        fx = _load(name)
        _RUNS[key] = run_scenario(name, engine=engine, seed=fx["seed"],
                                  eval_every=fx["eval_every"], flat=flat,
                                  **dict(fx["overrides"]), **kw)
    return _RUNS[key]


def _versions_match(fx) -> bool:
    """Digest-comparison gate: library versions AND codegen environment
    must both match the fixture (``repro.core.codegen``) — the committed
    digests are pinned to the fixture machine's hardware-dependent f32
    codegen.  The flat==pytree and trace assertions stay unconditional."""
    return (fx["versions"] == {"jax": jax.__version__,
                               "numpy": np.__version__}
            and codegen_matches(fx.get("codegen")))


def _device_engines(name):
    fx = _load(name)
    return [e for e in fx["engines"] if e in ("jit", "corridor")]


def _trace(r):
    return [(rec.round, rec.vehicle, rec.rsu, rec.time) for rec in r.rounds]


@pytest.mark.parametrize("name,engine", [
    (n, e) for n in FIXTURES for e in _device_engines(n)])
def test_flat_bitwise_matches_pytree_on_golden_world(name, engine):
    fx = _load(name)
    flat = _run(name, engine, True)
    pyt = _run(name, engine, False)
    assert _trace(flat) == _trace(pyt)
    assert tree_digest(flat.final_params) == tree_digest(pyt.final_params)
    if _versions_match(fx):
        # and both equal the committed fixture — the PR-4 goldens pin the
        # flat path for free
        assert tree_digest(flat.final_params) == \
            fx["engines"][engine]["digest"]


@pytest.mark.parametrize("name,engine", [
    ("paper-k10", "jit"), ("corridor-quick-r2-k8", "corridor")])
def test_flat_admit_all_selection_is_bitwise_noop(name, engine):
    base = _run(name, engine, True)
    sel = _run(name, engine, True, selection="admit-all")
    assert tree_digest(sel.final_params) == tree_digest(base.final_params)
    assert _trace(sel) == _trace(base)


def test_flat_fedasync_matches_pytree_to_ulp_tolerance():
    """fedasync's staleness coefficient is a pow/mul chain whose FMA
    contraction XLA:CPU picks per program — exact trace, ulp-level
    parameter tolerance (DESIGN.md §12)."""
    a = run_scenario("quick-k5", engine="jit", seed=1, eval_every=4,
                     rounds=12, scheme="fedasync", flat=False)
    b = run_scenario("quick-k5", engine="jit", seed=1, eval_every=4,
                     rounds=12, scheme="fedasync", flat=True)
    assert _trace(a) == _trace(b)
    for k in a.final_params:
        np.testing.assert_allclose(
            np.asarray(a.final_params[k]), np.asarray(b.final_params[k]),
            rtol=2e-6, atol=1e-7, err_msg=k)


def test_flat_selection_matches_pytree_to_ulp_tolerance():
    a = run_scenario("quick-k5", engine="jit", seed=1, eval_every=4,
                     rounds=12, selection="weighted-topk", selection_k=3,
                     resel_every=4, flat=False)
    b = run_scenario("quick-k5", engine="jit", seed=1, eval_every=4,
                     rounds=12, selection="weighted-topk", selection_k=3,
                     resel_every=4, flat=True)
    assert _trace(a) == _trace(b)
    assert a.report.selection == b.report.selection
    for k in a.final_params:
        np.testing.assert_allclose(
            np.asarray(a.final_params[k]), np.asarray(b.final_params[k]),
            rtol=2e-6, atol=1e-7, err_msg=k)


# ---------------------------------------------------------------------------
# bf16 ring mode
# ---------------------------------------------------------------------------
def test_bf16_ring_exact_timeline_bounded_drift_jit():
    f32 = run_scenario("quick-k5", engine="jit", seed=1, eval_every=4,
                       rounds=12)
    b16 = run_scenario("quick-k5", engine="jit", seed=1, eval_every=4,
                       rounds=12, ring_dtype="bf16")
    assert _trace(f32) == _trace(b16)        # timeline never sees params
    assert abs(f32.final_accuracy() - b16.final_accuracy()) <= 0.05
    for k in f32.final_params:
        np.testing.assert_allclose(
            np.asarray(f32.final_params[k]),
            np.asarray(b16.final_params[k]), atol=3e-2, err_msg=k)


def test_bf16_ring_exact_timeline_bounded_drift_corridor():
    f32 = run_scenario("corridor-quick-r2-k8", engine="corridor", seed=0,
                       eval_every=4)
    b16 = run_scenario("corridor-quick-r2-k8", engine="corridor", seed=0,
                       eval_every=4, ring_dtype="bf16")
    assert _trace(f32) == _trace(b16)
    assert abs(f32.final_accuracy() - b16.final_accuracy()) <= 0.05


def test_bf16_requires_flat_device_engine():
    with pytest.raises(ValueError, match="bf16"):
        run_scenario("quick-k5", engine="batched", ring_dtype="bf16")
    with pytest.raises(ValueError, match="bf16"):
        run_scenario("quick-k5", engine="serial", ring_dtype="bf16")
    with pytest.raises(ValueError, match="flat"):
        run_scenario("quick-k5", engine="jit", ring_dtype="bf16",
                     flat=False)


def test_fleet_k10000_scenario_registered_with_bf16_ring():
    from repro.core.scenarios import get_scenario
    sc = get_scenario("fleet-k10000")
    assert sc.K == 10000 and sc.ring_dtype == "bf16"


# ---------------------------------------------------------------------------
# fused-chain variant (use_kernel routes aggregation through ring_agg)
# ---------------------------------------------------------------------------
def test_fused_chain_matches_default_to_tolerance():
    a = run_scenario("quick-k5", engine="jit", seed=1, eval_every=4,
                     rounds=12)
    b = run_scenario("quick-k5", engine="jit", seed=1, eval_every=4,
                     rounds=12, use_kernel=True)
    assert _trace(a) == _trace(b)
    for k in a.final_params:
        np.testing.assert_allclose(
            np.asarray(a.final_params[k]), np.asarray(b.final_params[k]),
            rtol=2e-5, atol=1e-5, err_msg=k)


def test_fused_chain_matches_default_to_tolerance_corridor():
    a = run_scenario("corridor-quick-r2-k8", engine="corridor", seed=0,
                     eval_every=4)
    b = run_scenario("corridor-quick-r2-k8", engine="corridor", seed=0,
                     eval_every=4, use_kernel=True)
    assert _trace(a) == _trace(b)
    for k in a.final_params:
        np.testing.assert_allclose(
            np.asarray(a.final_params[k]), np.asarray(b.final_params[k]),
            rtol=2e-5, atol=1e-5, err_msg=k)

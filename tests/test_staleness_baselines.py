"""Dedicated coverage for the staleness-aware baselines the paper is
compared against: FedAsync's polynomial staleness discount and FedBuff's
buffered flushes (``core/aggregation.py``)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (FedBuffAggregator, _ema,
                                    fedasync_update)


def _alpha_of(g_val, l_val, out_val):
    """Recover the effective mixing alpha from out = (1-a) g + a l."""
    return float((out_val - g_val) / (l_val - g_val))


def _scalar_trees(g_val=1.0, l_val=3.0):
    return ({"w": jnp.full((4,), g_val)}, {"w": jnp.full((4,), l_val)})


# ---------------------------------------------------------------------------
# FedAsync (Xie et al. 2019): alpha = base_mix * (staleness + 1)^-a
# ---------------------------------------------------------------------------
def test_fedasync_zero_staleness_recovers_plain_mixing():
    g, l = _scalar_trees()
    out = fedasync_update(g, l, base_mix=0.5, staleness=0.0)
    expect = _ema(g, l, 1.0 - 0.5)
    np.testing.assert_allclose(out["w"], expect["w"], atol=1e-7)
    assert _alpha_of(1.0, 3.0, float(out["w"][0])) == pytest.approx(0.5)


def test_fedasync_alpha_monotonically_decreasing_in_staleness():
    g, l = _scalar_trees()
    alphas = []
    for s in [0.0, 0.5, 1.0, 2.0, 4.0, 8.0, 32.0]:
        out = fedasync_update(g, l, base_mix=0.5, staleness=s)
        alphas.append(_alpha_of(1.0, 3.0, float(out["w"][0])))
    assert all(a > b for a, b in zip(alphas, alphas[1:]))
    assert all(0.0 < a <= 0.5 for a in alphas)


def test_fedasync_polynomial_discount_exact():
    g, l = _scalar_trees()
    for s, a_exp in [(0.0, 0.5), (3.0, 0.25), (24.0, 0.1)]:
        out = fedasync_update(g, l, base_mix=0.5, staleness=s, a=0.5)
        assert _alpha_of(1.0, 3.0, float(out["w"][0])) == pytest.approx(
            0.5 * (s + 1.0) ** -0.5) == pytest.approx(a_exp)


def test_fedasync_preserves_dtype_and_structure():
    g = {"a": jnp.ones((3, 2), jnp.bfloat16), "b": [jnp.zeros(5)]}
    l = {"a": jnp.full((3, 2), 2.0, jnp.bfloat16), "b": [jnp.ones(5)]}
    out = fedasync_update(g, l, base_mix=0.4, staleness=1.0)
    assert out["a"].dtype == jnp.bfloat16 and out["b"][0].shape == (5,)


# ---------------------------------------------------------------------------
# FedBuff (Nguyen et al. 2022): buffer deltas, flush at buffer_size
# ---------------------------------------------------------------------------
def test_fedbuff_flushes_exactly_at_buffer_size():
    agg = FedBuffAggregator(buffer_size=3, lr=1.0)
    g = {"w": jnp.zeros(2)}
    flushed = []
    for k in range(7):
        l = {"w": jnp.full(2, float(k + 1))}
        g_new, did = agg.add(g, l)
        flushed.append(did)
        if not did:
            # no flush: the global model must be returned unchanged
            np.testing.assert_array_equal(g_new["w"], g["w"])
        g = g_new
    # flushes at the 3rd and 6th add, nowhere else
    assert flushed == [False, False, True, False, False, True, False]


def test_fedbuff_mean_delta_correctness():
    agg = FedBuffAggregator(buffer_size=3, lr=1.0)
    g = {"w": jnp.full(3, 10.0)}
    for v in (13.0, 16.0, 19.0):               # deltas 3, 6, 9 -> mean 6
        g_out, did = agg.add(g, {"w": jnp.full(3, v)})
    assert did
    np.testing.assert_allclose(g_out["w"], np.full(3, 16.0), atol=1e-6)
    # buffer cleared after the flush: next adds count from zero again
    _, did = agg.add(g_out, {"w": jnp.full(3, 0.0)})
    assert not did


def test_fedbuff_server_lr_scales_flush():
    agg = FedBuffAggregator(buffer_size=2, lr=0.5)
    g = {"w": jnp.zeros(1)}
    agg.add(g, {"w": jnp.full(1, 4.0)})
    g_out, did = agg.add(g, {"w": jnp.full(1, 8.0)})
    assert did
    np.testing.assert_allclose(g_out["w"], [3.0], atol=1e-6)   # 0.5 * 6


def test_fedbuff_through_server_scheme():
    """RSUServer('fedbuff') path: rounds advance every arrival, the model
    only at flush arrivals."""
    from repro.channel.params import ChannelParams
    from repro.core.server import RSUServer
    p = ChannelParams()
    g0 = {"w": jnp.zeros(2)}
    srv = RSUServer(g0, p, scheme="fedbuff", fedbuff_size=2)
    srv.receive({"w": jnp.full(2, 2.0)}, time=1.0, vehicle=0,
                upload_delay=0.1, train_delay=0.1, download_time=0.0)
    np.testing.assert_array_equal(np.asarray(srv.global_params["w"]),
                                  np.zeros(2))
    srv.receive({"w": jnp.full(2, 4.0)}, time=2.0, vehicle=1,
                upload_delay=0.1, train_delay=0.1, download_time=0.0)
    np.testing.assert_allclose(np.asarray(srv.global_params["w"]),
                               np.full(2, 3.0), atol=1e-6)
    assert srv.round == 2

"""Roofline machinery: HLO parsing with trip-count correction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import parse_hlo_module
from repro.roofline.analysis import V5E, roofline_terms
from repro.roofline.hlo_parse import shape_bytes


def test_shape_bytes():
    assert shape_bytes("f32[64,256]{1,0}") == 64 * 256 * 4
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("(s32[], f32[8])") == 4 + 32
    assert shape_bytes("pred[]") == 1


def test_scan_trip_count_correction():
    """The parser must multiply while-body dot flops by the trip count
    (XLA cost_analysis counts the body once — verified undercount)."""
    def f(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h
    xs = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(xs, ws).compile()
    stats = parse_hlo_module(compiled.as_text())
    expect = 7 * 2 * 32 * 64 * 64
    assert stats.dot_flops == pytest.approx(expect, rel=0.01)
    assert 7 in stats.while_trips.values()


def test_nested_scan_trips_multiply():
    def f(x, w):
        def outer(h, wi):
            def inner(h2, _):
                return jnp.tanh(h2 @ wi), None
            h2, _ = jax.lax.scan(inner, h, jnp.arange(3))
            return h2, None
        h, _ = jax.lax.scan(outer, x, w)
        return h
    xs = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 32, 32), jnp.float32)
    compiled = jax.jit(f).lower(xs, ws).compile()
    stats = parse_hlo_module(compiled.as_text())
    expect = 5 * 3 * 2 * 16 * 32 * 32
    assert stats.dot_flops == pytest.approx(expect, rel=0.01)


def test_unrolled_flops_exact():
    def f(x, w):
        return x @ w
    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 512), jnp.float32)).compile()
    stats = parse_hlo_module(compiled.as_text())
    assert stats.dot_flops == pytest.approx(2 * 128 * 256 * 512, rel=0.01)


def test_roofline_terms_bottleneck():
    class Mem:
        argument_size_in_bytes = 10 * 2 ** 30
        output_size_in_bytes = 2 ** 30
        temp_size_in_bytes = 2 ** 30
        alias_size_in_bytes = 0

    class Stats:
        dot_flops = 1e15
        collective_bytes = {"all-gather": 1e9}
        total_collective_bytes = 1e9
        while_trips = {}

    t = roofline_terms(arch="x", shape="train_4k", mesh_name="m",
                       n_chips=256, hlo_stats=Stats(), memory_stats=Mem(),
                       cost_flops=1.0, model_flops=2.56e17, tokens=1)
    assert t.bottleneck == "compute"          # 5.08s compute dominates
    assert t.compute_s == pytest.approx(1e15 / V5E.peak_flops)
    assert t.fits_hbm == (13 * 2 ** 30 <= V5E.hbm_bytes)
    assert t.useful_flops_ratio == pytest.approx(1.0)

"""Selection-layer invariants and cross-engine selection conformance
(DESIGN.md §11).

Property tests (under the ``_hypothesis_compat`` shim, so they degrade to
deterministic bound/midpoint sweeps without ``hypothesis``):

- the admitted set is always a subset of the in-coverage set
- ``admit-all``'s mask is all-ones (over coverage)
- ``budget`` never exceeds the per-RSU upload-slot budget
- ``weighted-topk`` is permutation-equivariant in the vehicle order
- ``eps-bandit`` state updates and decisions are deterministic under a
  fixed seed

Conformance: for every policy, the serial, batched, and jit engines (and
the corridor pair for multi-RSU worlds) must produce identical admission
masks, identical arrival traces, and allclose final models — the selection
extension of ``tests/test_engine_conformance.py``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

import repro.core.client as client_mod
from repro.channel.params import ChannelParams
from repro.core import run_simulation
from repro.data import partition_vehicles, synth_mnist
from repro.selection import (POLICIES, SelectionContext, SelectionSpec,
                             make_policy)
from repro.selection.runtime import SelectionState, scenario_spec

ENGINES = ("serial", "batched", "jit")


# ---------------------------------------------------------------------------
# pure-policy property tests on synthetic contexts
# ---------------------------------------------------------------------------
def _ctx(K, n_rsus=1, seed=0, coverage_frac=1.0):
    """Synthetic decision context with distinct random features."""
    rng = np.random.default_rng(seed)
    in_cov = np.ones(K, bool)
    n_out = int(round((1.0 - coverage_frac) * K))
    if n_out:
        in_cov[rng.choice(K, n_out, replace=False)] = False
    return SelectionContext(
        t=0.0,
        data=rng.uniform(100.0, 5000.0, K),
        compute=rng.uniform(1e8, 2e9, K),
        residence=rng.uniform(1.0, 80.0, K),
        upload_cost=rng.uniform(1e-3, 5e-3, K),
        in_coverage=in_cov,
        serving=rng.integers(0, n_rsus, K),
        n_rsus=n_rsus,
        rng=np.random.default_rng([seed, 1]))


def _specs(K):
    return [SelectionSpec(policy="admit-all"),
            SelectionSpec(policy="weighted-topk", k=max(1, K // 3)),
            SelectionSpec(policy="budget", budget=4e-3),
            SelectionSpec(policy="eps-bandit", k=max(1, K // 3), eps=0.3,
                          resel_every=4)]


@given(st.integers(2, 40), st.floats(0.3, 1.0))
@settings(max_examples=8, deadline=None)
def test_admitted_subset_of_coverage(K, coverage_frac):
    """No policy may ever admit an out-of-coverage vehicle."""
    for n_rsus in (1, 3):
        ctx = _ctx(K, n_rsus=n_rsus, seed=K, coverage_frac=coverage_frac)
        for spec in _specs(K):
            pol = make_policy(spec)
            mask = pol.mask(ctx, pol.init_state(K))
            assert not np.any(mask & ~ctx.in_coverage), spec.policy


@given(st.integers(1, 50))
@settings(max_examples=6, deadline=None)
def test_admit_all_mask_is_all_ones(K):
    pol = make_policy(SelectionSpec(policy="admit-all"))
    ctx = _ctx(K)
    assert np.array_equal(pol.mask(ctx, None), np.ones(K, bool))
    # ... and exactly the coverage set when some vehicles are outside
    ctx = _ctx(K, seed=K + 1, coverage_frac=0.5)
    assert np.array_equal(pol.mask(ctx, None), ctx.in_coverage)


@given(st.integers(3, 40), st.floats(1e-3, 2e-2))
@settings(max_examples=8, deadline=None)
def test_budget_never_exceeds_slot_budget(K, budget):
    """Per RSU, the summed estimated upload airtime of the admitted set
    stays within the budget."""
    for n_rsus in (1, 4):
        ctx = _ctx(K, n_rsus=n_rsus, seed=K)
        pol = make_policy(SelectionSpec(policy="budget", budget=budget))
        mask = pol.mask(ctx, None)
        for j in range(n_rsus):
            grp = mask & (ctx.serving == j)
            assert ctx.upload_cost[grp].sum() <= budget + 1e-12


@given(st.integers(3, 30))
@settings(max_examples=6, deadline=None)
def test_weighted_topk_permutation_equivariant(K):
    """Permuting the vehicle order permutes the admitted set the same way
    (scores drawn continuous, so ties have measure zero)."""
    ctx = _ctx(K, n_rsus=2, seed=K)
    spec = SelectionSpec(policy="weighted-topk", k=max(1, K // 3))
    pol = make_policy(spec)
    mask = pol.mask(ctx, None)
    perm = np.random.default_rng(K).permutation(K)
    ctx_p = SelectionContext(
        t=ctx.t, data=ctx.data[perm], compute=ctx.compute[perm],
        residence=ctx.residence[perm], upload_cost=ctx.upload_cost[perm],
        in_coverage=ctx.in_coverage[perm], serving=ctx.serving[perm],
        n_rsus=ctx.n_rsus, rng=np.random.default_rng(0))
    mask_p = pol.mask(ctx_p, None)
    assert np.array_equal(mask_p, mask[perm])


def test_bandit_updates_deterministic_under_seed():
    """Two identically seeded bandit states fed the same reward stream
    make identical decisions at every epoch."""
    p = dataclasses.replace(ChannelParams(), K=8)
    from repro.channel import Mobility
    spec = SelectionSpec(policy="eps-bandit", k=3, eps=0.5, resel_every=3)
    runs = []
    for _ in range(2):
        sel = SelectionState(spec, p, Mobility(p), seed=7, rounds=30)
        log = [tuple(sel.admit0)]
        rng = np.random.default_rng(0)
        for total in range(1, 25):
            v = int(rng.integers(0, p.K))
            sel.on_arrival(v, float(rng.uniform(0.5, 2.0)),
                           float(rng.uniform(0.5, 2.0)))
            newly = sel.maybe_reselect(total, float(total))
            log.append((tuple(sel.mask), tuple(newly)))
        log.append((tuple(sel.state.rew_sum), tuple(sel.state.rew_cnt)))
        runs.append(log)
    assert runs[0] == runs[1]


def test_bandit_prefers_rewarding_vehicles_when_exploiting():
    """With eps=0 (pure exploitation) and every arm tried, the admitted
    set is exactly the top-k by mean reward."""
    K = 6
    spec = SelectionSpec(policy="eps-bandit", k=2, eps=0.0, resel_every=1)
    pol = make_policy(spec)
    state = pol.init_state(K)
    rewards = [0.1, 0.9, 0.5, 0.95, 0.2, 0.3]
    for v, r in enumerate(rewards):
        pol.observe(state, v, r)
    mask = pol.mask(_ctx(K, seed=3), state)
    assert set(np.flatnonzero(mask)) == {1, 3}


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="unknown selection policy"):
        SelectionSpec(policy="nope").validate()
    with pytest.raises(ValueError, match="needs k"):
        SelectionSpec(policy="weighted-topk").validate()
    with pytest.raises(ValueError, match="budget"):
        SelectionSpec(policy="budget").validate()
    with pytest.raises(ValueError, match="eps"):
        SelectionSpec(policy="eps-bandit", k=2, eps=1.5).validate()
    assert set(POLICIES) == {"admit-all", "weighted-topk", "budget",
                             "eps-bandit"}


def test_bandit_without_epoch_raises():
    p = dataclasses.replace(ChannelParams(), K=4)
    from repro.channel import Mobility
    with pytest.raises(ValueError, match="resel_every"):
        SelectionState(SelectionSpec(policy="eps-bandit", k=2), p,
                       Mobility(p), seed=0, rounds=10)


def test_scenario_spec_reads_scenario_fields():
    from repro.core.scenarios import get_scenario
    sc = get_scenario("fleet-k1000-topk")
    spec = scenario_spec(sc)
    assert spec.policy == "weighted-topk" and spec.k == 250
    assert scenario_spec(get_scenario("fleet-k1000")) is None
    sc = get_scenario("corridor-r4-k400-bandit")
    spec = sc.selection_spec()
    assert spec.policy == "eps-bandit" and spec.k == 25


# ---------------------------------------------------------------------------
# cross-engine conformance with selection active (stubbed trainer)
# ---------------------------------------------------------------------------
def _fake_local_scan(params, images, labels, lr):
    h = (jnp.mean(images.astype(jnp.float32))
         + jnp.mean(labels.astype(jnp.float32)))
    out = jax.tree_util.tree_map(
        lambda w: w * (1.0 - lr * 0.01) + 1e-3 * h, params)
    return out, h


@pytest.fixture()
def stub_trainer(monkeypatch):
    monkeypatch.setattr(client_mod, "_local_scan", _fake_local_scan)
    monkeypatch.setattr(client_mod, "_local_scan_jit", _fake_local_scan)
    monkeypatch.setattr(
        client_mod, "_local_scan_vmap",
        jax.vmap(_fake_local_scan, in_axes=(0, 0, 0, None)))


_WORLD_CACHE = {}


def _world(K):
    if K not in _WORLD_CACHE:
        tr_i, tr_l, te_i, te_l = synth_mnist(n_train=600, n_test=120,
                                             seed=0, noise=0.35)
        p = dataclasses.replace(ChannelParams(), K=K, fading_rho=0.95)
        veh = partition_vehicles(tr_i, tr_l, p, seed=0, scale=0.012)
        _WORLD_CACHE[K] = (veh, te_i, te_l, p)
    return _WORLD_CACHE[K]


def _run(world, engine, rounds, selection, **kw):
    veh, te_i, te_l, p = world
    return run_simulation(veh, te_i, te_l, scheme="mafl", rounds=rounds,
                          l_iters=1, lr=0.05, eval_every=rounds, seed=0,
                          params=p, engine=engine, selection=selection,
                          **kw)


def _assert_selection_conformant(results):
    ref = results["serial"]
    for name, res in results.items():
        assert ([(r.round, r.vehicle) for r in res.rounds]
                == [(r.round, r.vehicle) for r in ref.rounds]), \
            f"{name}: arrival sequence diverged"
        np.testing.assert_allclose([r.time for r in res.rounds],
                                   [r.time for r in ref.rounds],
                                   rtol=2e-5, atol=1e-3)
        # identical admission masks and decisions across engines
        assert res.report.selection == ref.report.selection, \
            f"{name}: admission decisions diverged"
        for x, y in zip(jax.tree_util.tree_leaves(ref.final_params),
                        jax.tree_util.tree_leaves(res.final_params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-5, err_msg=name)


@pytest.mark.parametrize("spec", [
    SelectionSpec(policy="weighted-topk", k=3),
    SelectionSpec(policy="budget", budget=0.008),
    SelectionSpec(policy="eps-bandit", k=2, eps=0.3, resel_every=4),
], ids=lambda s: s.policy)
def test_engines_conform_under_selection(stub_trainer, spec):
    world = _world(6)
    results = {e: _run(world, e, 10, spec) for e in ENGINES}
    _assert_selection_conformant(results)
    # the policy actually parked somebody (the world is bigger than k)
    assert not all(results["serial"].report.selection["admit0"])


def test_unselected_vehicles_never_appear(stub_trainer):
    """Parked vehicles occupy no slot, no wave, and no arrival."""
    world = _world(6)
    spec = SelectionSpec(policy="weighted-topk", k=2)
    r = _run(world, "jit", 10, spec)
    admitted = {v for v, m in enumerate(r.report.selection["admit0"])
                if m}
    assert {rec.vehicle for rec in r.rounds} <= admitted


def test_jit_selection_plan_masks_match_host(stub_trainer):
    """The jit engine's compiled masks are exactly the host replay's."""
    from repro.core.jit_engine import plan_fleet
    world = _world(5)
    _, _, _, p = world
    spec = SelectionSpec(policy="eps-bandit", k=2, eps=0.3, resel_every=3)
    plan = plan_fleet(p, 0, 9, spec)
    host = _run(world, "serial", 9, spec)
    assert plan.sel.summary() == host.report.selection
    # bandit expectation is the f64 reward accumulation over the 9 pops
    rew_sum, rew_cnt = plan.sel_bandit
    assert rew_cnt.sum() == 9


def test_corridor_engines_conform_under_selection(stub_trainer):
    from repro.core.scenarios import run_scenario
    for spec in (SelectionSpec(policy="weighted-topk", k=3),
                 SelectionSpec(policy="eps-bandit", k=2, eps=0.4)):
        ref = run_scenario("corridor-quick-r2-k8", engine="serial", seed=0,
                           rounds=12, eval_every=6,
                           selection=spec.policy,
                           selection_k=spec.k, selection_eps=spec.eps)
        res = run_scenario("corridor-quick-r2-k8", engine="corridor",
                           seed=0, rounds=12, eval_every=6,
                           selection=spec.policy,
                           selection_k=spec.k, selection_eps=spec.eps)
        assert ([(r.round, r.vehicle, r.rsu) for r in res.rounds]
                == [(r.round, r.vehicle, r.rsu) for r in ref.rounds])
        assert res.report.selection == ref.report.selection
        for x, y in zip(jax.tree_util.tree_leaves(ref.final_params),
                        jax.tree_util.tree_leaves(res.final_params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-5)


def test_corridor_engines_accept_policy_name_string(stub_trainer):
    """The documented ``selection='admit-all'`` string form must work on
    the direct engine entry points too (run_scenario normalizes via
    Scenario fields, so only a direct call exercises this)."""
    from repro.core.scenarios import build_world, get_scenario
    from repro.corridor.engine import run_corridor_simulation
    from repro.corridor.reference import run_handover_simulation
    sc = get_scenario("corridor-quick-r2-k8")
    veh, te_i, te_l, p = build_world(sc)
    dev = run_corridor_simulation(sc, veh, te_i, te_l, p,
                                  selection="admit-all", eval_every=10 ** 9)
    ref = run_handover_simulation(sc, veh, te_i, te_l, p,
                                  selection="admit-all", eval_every=10 ** 9)
    assert ([(r.round, r.vehicle, r.rsu) for r in dev.rounds]
            == [(r.round, r.vehicle, r.rsu) for r in ref.rounds])


def test_corridor_bandit_rescores_at_reconcile(stub_trainer):
    """The corridor re-scores per reconcile segment: with a 2-RSU world
    and per-RSU caps, decisions exist at every reconcile boundary."""
    from repro.core.scenarios import run_scenario
    r = run_scenario("corridor-quick-r2-k8", engine="corridor", seed=0,
                     rounds=12, eval_every=12, reconcile_every=4,
                     selection="eps-bandit", selection_k=2,
                     selection_eps=0.5)
    decisions = r.report.selection["decisions"]
    assert [b for b, _, _ in decisions] == [4, 8]


def test_selection_with_ema_reconcile_raises(stub_trainer):
    from repro.core.scenarios import run_scenario
    with pytest.raises(ValueError, match="ema"):
        run_scenario("corridor-quick-r2-k8", engine="corridor", seed=0,
                     rounds=6, reconcile_mode="ema",
                     selection="weighted-topk", selection_k=2)
    with pytest.raises(ValueError, match="ema"):
        run_scenario("corridor-quick-r2-k8", engine="serial", seed=0,
                     rounds=6, reconcile_mode="ema",
                     selection="weighted-topk", selection_k=2)
    # admit-all under EMA stays allowed (provable no-op)
    run_scenario("corridor-quick-r2-k8", engine="corridor", seed=0,
                 rounds=6, eval_every=6, reconcile_mode="ema",
                 selection="admit-all")


def test_selection_scenarios_registered_and_run(stub_trainer):
    from repro.core.scenarios import get_scenario, list_scenarios, \
        run_scenario
    names = list_scenarios()
    for n in ("fleet-k1000-topk", "fleet-k1000-budget",
              "corridor-r4-k400-bandit"):
        assert n in names
    # shrunken smoke of the topk mega-fleet scenario through the jit path
    r = run_scenario("fleet-k1000-topk", engine="jit", seed=0, K=40,
                     rounds=6, eval_every=6, selection_k=10,
                     n_train=600, n_test=120)
    assert r.report.selection["n_admitted_final"] == 10
    assert len(r.rounds) == 6

"""The tentpole invariant (DESIGN.md §3): the vehicle-batched wave engine
must reproduce the serial engine's event semantics exactly — same
(round, vehicle, time) sequence, same stale-snapshot payloads — with the
parameters agreeing to float tolerance.

The fast lane proves the *orchestration* equivalent with a stubbed trainer
(compiles nothing); the slow lane re-proves it with the real CNN and the
vmapped wave path engaged."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.client as client_mod
from repro.channel import RayleighAR1, SlotGainCache
from repro.channel.params import ChannelParams
from repro.core import run_simulation
from repro.core.mafl import _eval_step, evaluate
from repro.data import partition_vehicles, synth_mnist


@pytest.fixture(scope="module")
def k5_world():
    tr_i, tr_l, te_i, te_l = synth_mnist(n_train=1500, n_test=300, seed=0,
                                         noise=0.35)
    p = dataclasses.replace(ChannelParams(), K=5)
    veh = partition_vehicles(tr_i, tr_l, p, seed=0, scale=0.03)
    return veh, te_i, te_l, p


def _run(world, engine, **kw):
    veh, te_i, te_l, p = world
    return run_simulation(veh, te_i, te_l, scheme="mafl", rounds=10,
                          l_iters=2, lr=0.05, eval_every=5, seed=0,
                          params=p, engine=engine, **kw)


def _sequences(r):
    return [(rec.round, rec.vehicle, rec.time, rec.weight)
            for rec in r.rounds]


def _fake_local_scan(params, images, labels, lr):
    """Deterministic stand-in for the CNN scan: folds the exact minibatch
    stream into the parameters so any divergence in payload snapshots or
    RNG draw order between engines changes the result.  (Pure jnp so the
    same function also works under vmap.)"""
    h = (jnp.mean(images.astype(jnp.float32))
         + jnp.mean(labels.astype(jnp.float32)))
    out = jax.tree_util.tree_map(
        lambda w: w * (1.0 - lr * 0.01) + 1e-3 * h, params)
    return out, h


def test_batched_matches_serial_with_stub_trainer(k5_world, monkeypatch):
    monkeypatch.setattr(client_mod, "_local_scan_jit", _fake_local_scan)
    monkeypatch.setattr(
        client_mod, "_local_scan_vmap",
        jax.vmap(_fake_local_scan, in_axes=(0, 0, 0, None)))
    r_s = _run(k5_world, "serial")
    r_b = _run(k5_world, "batched")
    assert _sequences(r_s) == _sequences(r_b)
    for x, y in zip(jax.tree_util.tree_leaves(r_s.final_params),
                    jax.tree_util.tree_leaves(r_b.final_params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


def test_batched_wave_chunking_matches_stub(k5_world, monkeypatch):
    """Tiny wave_chunk engages the vmapped chunk path; results must not
    depend on how waves are sliced."""
    monkeypatch.setattr(client_mod, "_local_scan_jit", _fake_local_scan)
    monkeypatch.setattr(
        client_mod, "_local_scan_vmap",
        jax.vmap(_fake_local_scan, in_axes=(0, 0, 0, None)))
    r_loop = _run(k5_world, "batched", wave_chunk=1)   # pure scan loop
    r_vmap = _run(k5_world, "batched", wave_chunk=2)   # vmapped pairs
    assert _sequences(r_loop) == _sequences(r_vmap)
    for x, y in zip(jax.tree_util.tree_leaves(r_loop.final_params),
                    jax.tree_util.tree_leaves(r_vmap.final_params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)


@pytest.mark.slow
def test_batched_matches_serial_real_cnn(k5_world):
    r_s = _run(k5_world, "serial")
    r_b = _run(k5_world, "batched", wave_chunk=4)      # vmap path engaged
    assert _sequences(r_s) == _sequences(r_b)          # bit-identical order
    for x, y in zip(jax.tree_util.tree_leaves(r_s.final_params),
                    jax.tree_util.tree_leaves(r_b.final_params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=2e-5)
    assert ([rd for rd, _ in r_s.acc_history]
            == [rd for rd, _ in r_b.acc_history])
    np.testing.assert_allclose([a for _, a in r_s.acc_history],
                               [a for _, a in r_b.acc_history], atol=1e-5)


def test_unknown_engine_rejected(k5_world):
    with pytest.raises(ValueError):
        _run(k5_world, "warp-drive")


def test_fading_block_bit_identical_to_steps():
    p = ChannelParams()
    f1, f2 = RayleighAR1(p, seed=3), RayleighAR1(p, seed=3)
    scalar = np.stack([f1.step() for _ in range(9)])
    block = np.concatenate([f2.steps_block(5), f2.steps_block(4)])
    np.testing.assert_array_equal(scalar, block)


def test_evaluate_pads_ragged_batch_without_retrace():
    """The ragged final slice must not trace a second program, and the
    masked-pad metrics must equal the unpadded computation."""
    from repro.models.cnn import accuracy, cnn_forward, cross_entropy_loss, \
        init_cnn
    _, _, te_i, te_l = synth_mnist(n_train=8, n_test=300, seed=0,
                                   noise=0.35)
    params = init_cnn(jax.random.PRNGKey(0))

    n0 = _eval_step._cache_size()
    acc, loss = evaluate(params, te_i, te_l, batch=128)   # 300 = 2*128 + 44
    assert _eval_step._cache_size() == n0 + 1
    evaluate(params, te_i[:200], te_l[:200], batch=128)   # different ragged n
    assert _eval_step._cache_size() == n0 + 1             # still one program

    logits = cnn_forward(params, jnp.asarray(te_i))
    ref_acc = float(accuracy(logits, jnp.asarray(te_l)))
    ref_loss = float(cross_entropy_loss(logits, jnp.asarray(te_l)))
    assert acc == pytest.approx(ref_acc, abs=1e-6)
    assert loss == pytest.approx(ref_loss, rel=1e-5)


def test_gain_cache_prunes_to_live_window():
    """The per-slot gain cache must hold only [earliest pending, last
    generated] — the seed kept one vector per slot forever."""
    p = ChannelParams()
    gains = SlotGainCache(RayleighAR1(p, seed=0))
    ref = RayleighAR1(p, seed=0)
    expect = {s: g for s, g in enumerate(ref.steps_block(1000))}
    np.testing.assert_array_equal(gains.at(999.7), expect[999])
    assert len(gains) == 1000
    gains.prune_below(990.0)
    assert len(gains) == 10                      # slots 990..999 survive
    np.testing.assert_array_equal(gains.at(995.2), expect[995])
    # advancing after a prune continues the same AR(1) stream
    ref2 = ref.steps_block(5)
    np.testing.assert_array_equal(gains.at(1004.1), ref2[-1])
    gains.prune_below(1004)
    assert len(gains) == 1


def test_long_horizon_run_stays_time_ordered(k5_world, monkeypatch):
    monkeypatch.setattr(client_mod, "_local_scan_jit", _fake_local_scan)
    monkeypatch.setattr(
        client_mod, "_local_scan_vmap",
        jax.vmap(_fake_local_scan, in_axes=(0, 0, 0, None)))
    veh, te_i, te_l, p = k5_world
    # heavy model + narrow band -> long uploads -> events span many slots
    slow = dataclasses.replace(p, B=1e3, model_bits=5e6)
    r = run_simulation(veh, te_i, te_l, scheme="afl", rounds=8, l_iters=1,
                       lr=0.05, eval_every=8, seed=0, params=slow)
    times = [rec.time for rec in r.rounds]
    assert times == sorted(times) and times[-1] > 100

"""The paper's core math: delay weights (Eqs. 7, 9, 10) and aggregation
(Eq. 11) + baselines, including hypothesis property tests (deterministic
example sweeps via ``_hypothesis_compat`` when hypothesis is absent)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.channel.params import ChannelParams
from repro.core import (FedBuffAggregator, afl_update, fedasync_update,
                        fedavg_update, mafl_update)
from repro.core.weights import (combined_weight, training_weight,
                                upload_weight, weighted_local_model)

P = ChannelParams()


def test_upload_weight_eq7():
    assert upload_weight(P, 1.0) == pytest.approx(1.0)       # gamma^0
    assert upload_weight(P, 2.0) == pytest.approx(0.9)       # gamma^1
    assert upload_weight(P, 0.0) == pytest.approx(1.0 / 0.9)


def test_training_weight_eq9():
    assert training_weight(P, 1.0) == pytest.approx(1.0)
    assert training_weight(P, 11.0) == pytest.approx(0.9 ** 10)


@given(st.floats(0.0, 50.0), st.floats(0.0, 50.0))
@settings(max_examples=50, deadline=None)
def test_weights_monotone_decreasing(d1, d2):
    """Eq. 7/9: larger delay => smaller weight (staleness discount)."""
    if d1 < d2:
        assert upload_weight(P, d1) >= upload_weight(P, d2)
        assert training_weight(P, d1) >= training_weight(P, d2)
    assert combined_weight(P, d1, d2) == pytest.approx(
        upload_weight(P, d1) * training_weight(P, d2), rel=1e-6)


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (4, 3)) * scale,
            "b": {"c": jax.random.normal(k2, (7,)) * scale}}


def test_weighted_local_model_eq10():
    t = _tree(jax.random.PRNGKey(0))
    w = weighted_local_model(t, 0.7)
    np.testing.assert_allclose(w["a"], 0.7 * t["a"], rtol=1e-6)


def test_afl_update_eq11():
    g, l = _tree(jax.random.PRNGKey(0)), _tree(jax.random.PRNGKey(1))
    out = afl_update(g, l, beta=0.5)
    np.testing.assert_allclose(out["b"]["c"], 0.5 * g["b"]["c"] +
                               0.5 * l["b"]["c"], rtol=1e-6)


def test_mafl_update_literal_matches_equations():
    g, l = _tree(jax.random.PRNGKey(0)), _tree(jax.random.PRNGKey(1))
    out = mafl_update(g, l, beta=0.5, weight=0.8, interpretation="literal")
    np.testing.assert_allclose(out["a"], 0.5 * g["a"] + 0.5 * 0.8 * l["a"],
                               rtol=1e-6)


def test_mafl_update_mixing_is_convex():
    g, l = _tree(jax.random.PRNGKey(0)), _tree(jax.random.PRNGKey(1))
    out = mafl_update(g, l, beta=0.5, weight=0.8)
    alpha = 0.5 * 0.8
    np.testing.assert_allclose(out["a"], (1 - alpha) * g["a"] +
                               alpha * l["a"], rtol=1e-6)


def test_mafl_kernel_path_matches_jnp():
    g, l = _tree(jax.random.PRNGKey(2)), _tree(jax.random.PRNGKey(3))
    for interp in ("literal", "mixing"):
        a = mafl_update(g, l, 0.5, 0.93, use_kernel=False,
                        interpretation=interp)
        b = mafl_update(g, l, 0.5, 0.93, use_kernel=True,
                        interpretation=interp)
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)):
            np.testing.assert_allclose(x, y, atol=1e-6)


@given(st.floats(0.05, 0.95), st.floats(0.1, 1.2))
@settings(max_examples=30, deadline=None)
def test_mixing_update_stays_in_hull(beta, weight):
    """Convex mixing keeps every coordinate inside [min(g,l), max(g,l)]."""
    g, l = _tree(jax.random.PRNGKey(4)), _tree(jax.random.PRNGKey(5))
    out = mafl_update(g, l, beta, weight)
    for og, ol, oo in zip(jax.tree_util.tree_leaves(g),
                          jax.tree_util.tree_leaves(l),
                          jax.tree_util.tree_leaves(out)):
        lo = np.minimum(og, ol) - 1e-6
        hi = np.maximum(og, ol) + 1e-6
        assert ((oo >= lo) & (oo <= hi)).all()


def test_fedavg_weighted_mean():
    trees = [_tree(jax.random.PRNGKey(i)) for i in range(3)]
    out = fedavg_update(trees[0], trees, sizes=[1, 1, 2])
    expect = (trees[0]["a"] + trees[1]["a"] + 2 * trees[2]["a"]) / 4
    np.testing.assert_allclose(out["a"], expect, rtol=1e-5)


def test_fedasync_staleness_discount():
    g, l = _tree(jax.random.PRNGKey(0)), _tree(jax.random.PRNGKey(1))
    fresh = fedasync_update(g, l, 0.5, staleness=0.0)
    stale = fedasync_update(g, l, 0.5, staleness=100.0)
    # stale update moves less far from g
    d_fresh = np.abs(fresh["a"] - g["a"]).sum()
    d_stale = np.abs(stale["a"] - g["a"]).sum()
    assert d_stale < d_fresh


def test_fedbuff_aggregates_every_k():
    g = _tree(jax.random.PRNGKey(0))
    agg = FedBuffAggregator(buffer_size=2)
    out1, fired1 = agg.add(g, _tree(jax.random.PRNGKey(1)))
    assert not fired1
    out2, fired2 = agg.add(g, _tree(jax.random.PRNGKey(2)))
    assert fired2
    assert not np.allclose(out2["a"], g["a"])

"""Checkpointing round-trips (bit-exact, including optimizer state and a
mid-simulation resume) and the FedBuff partial-buffer edge cases — the two
modules that had no dedicated coverage before DESIGN.md §11 landed.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel.params import ChannelParams
from repro.checkpointing import (latest_checkpoint, load_checkpoint,
                                 save_checkpoint)
from repro.checkpointing.checkpoint import tree_digest
from repro.core import run_simulation
from repro.core.aggregation import FedBuffAggregator
from repro.core.client import _local_scan_jit
from repro.data import partition_vehicles, synth_mnist
from repro.models.cnn import init_cnn
from repro.optim import adam


def _optimizer_tree():
    """A realistic driver-state pytree: CNN params + Adam moments + step
    counter + a bf16 leaf (the npz-unfriendly dtype)."""
    params = init_cnn(jax.random.PRNGKey(0))
    opt = adam(1e-3)
    state = opt.init(params)
    return {
        "params": params,
        "opt": state,
        "ema": jax.tree_util.tree_map(
            lambda x: x.astype(jnp.bfloat16), params),
    }


def test_save_load_round_trip_is_bit_exact(tmp_path):
    tree = _optimizer_tree()
    path = save_checkpoint(str(tmp_path), 3, tree)
    assert os.path.exists(path)
    restored = load_checkpoint(path, tree)
    assert tree_digest(restored) == tree_digest(tree)
    # structure preserved leaf-for-leaf, dtypes included
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            jax.tree_util.tree_flatten_with_path(restored)[0]):
        assert pa == pb
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    tree = {"w": np.arange(4.0, dtype=np.float32)}
    for step in range(5):
        save_checkpoint(str(tmp_path), step, tree, keep=2,
                        meta={"step": step})
    kept = sorted(f for f in os.listdir(tmp_path) if f.endswith(".npz"))
    assert kept == ["ckpt_00000003.npz", "ckpt_00000004.npz"]
    assert latest_checkpoint(str(tmp_path)).endswith("ckpt_00000004.npz")
    # metadata of retained checkpoints survives; pruned ones are gone
    assert os.path.exists(os.path.join(tmp_path, "ckpt_00000004.npz.json"))
    assert not os.path.exists(
        os.path.join(tmp_path, "ckpt_00000000.npz.json"))
    assert latest_checkpoint(str(tmp_path / "nope")) is None


def test_local_training_resumes_bit_exact_from_checkpoint(tmp_path):
    """Mid-training resume: l iterations straight through == first half,
    checkpoint, reload, second half — bit-exact."""
    params = init_cnn(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    imgs = rng.normal(size=(4, 16, 28, 28, 1)).astype(np.float32)
    labs = rng.integers(0, 10, size=(4, 16))
    full, _ = _local_scan_jit(params, jnp.asarray(imgs), jnp.asarray(labs),
                              0.05)
    half, _ = _local_scan_jit(params, jnp.asarray(imgs[:2]),
                              jnp.asarray(labs[:2]), 0.05)
    path = save_checkpoint(str(tmp_path), 0, half)
    restored = load_checkpoint(path, half)
    resumed, _ = _local_scan_jit(restored, jnp.asarray(imgs[2:]),
                                 jnp.asarray(labs[2:]), 0.05)
    assert tree_digest(resumed) == tree_digest(full)


def test_mid_simulation_resume_restores_global_model_bit_exact(tmp_path):
    """The FL-level resume: checkpoint the global model between rounds,
    reload it, and continue the simulation — identical to continuing from
    the in-memory model."""
    tr_i, tr_l, te_i, te_l = synth_mnist(n_train=400, n_test=80, seed=0,
                                         noise=0.35)
    p = dataclasses.replace(ChannelParams(), K=4)
    veh = partition_vehicles(tr_i, tr_l, p, seed=0, scale=0.01)
    kw = dict(scheme="mafl", l_iters=1, lr=0.05, params=p, engine="serial")
    first = run_simulation(veh, te_i, te_l, rounds=4, seed=0,
                           eval_every=4, **kw)
    path = save_checkpoint(str(tmp_path), 4, first.final_params,
                           meta={"round": 4})
    restored = load_checkpoint(path, first.final_params)
    assert tree_digest(restored) == tree_digest(first.final_params)
    cont_mem = run_simulation(veh, te_i, te_l, rounds=3, seed=1,
                              eval_every=3,
                              init_params=first.final_params, **kw)
    cont_ckpt = run_simulation(veh, te_i, te_l, rounds=3, seed=1,
                               eval_every=3, init_params=restored, **kw)
    assert tree_digest(cont_ckpt.final_params) == \
        tree_digest(cont_mem.final_params)


def test_kill_mid_write_never_corrupts_latest(tmp_path, monkeypatch):
    """Atomic publication: a writer killed mid-npz-write leaves only a
    ``.tmp`` sibling — ``latest_checkpoint`` still returns the previous
    intact checkpoint, which still loads bit-exactly, and the next
    successful save sweeps the debris."""
    import repro.checkpointing.checkpoint as ckpt_mod
    tree = {"w": np.arange(8.0, dtype=np.float32)}
    good = save_checkpoint(str(tmp_path), 0, tree, meta={"round": 0})
    digest = tree_digest(load_checkpoint(good, tree))

    real_savez = np.savez

    def dying_savez(f, **arrays):
        f.write(b"PK\x03\x04 truncated mid-write")
        raise KeyboardInterrupt          # the kill lands inside the write

    monkeypatch.setattr(ckpt_mod.np, "savez", dying_savez)
    with pytest.raises(KeyboardInterrupt):
        save_checkpoint(str(tmp_path), 1, tree, meta={"round": 1})
    monkeypatch.setattr(ckpt_mod.np, "savez", real_savez)

    # the half-written step-1 checkpoint was never published: no npz, no
    # sidecar json, latest still the intact step-0 file
    assert not os.path.exists(os.path.join(tmp_path, "ckpt_00000001.npz"))
    assert not os.path.exists(
        os.path.join(tmp_path, "ckpt_00000001.npz.json"))
    assert latest_checkpoint(str(tmp_path)) == good
    assert tree_digest(load_checkpoint(good, tree)) == digest
    assert any(f.endswith(".tmp") for f in os.listdir(tmp_path))

    # the next save publishes normally and sweeps the orphaned .tmp
    save_checkpoint(str(tmp_path), 2, tree, meta={"round": 2})
    assert latest_checkpoint(str(tmp_path)).endswith("ckpt_00000002.npz")
    assert not any(f.endswith(".tmp") for f in os.listdir(tmp_path))


def test_kill_mid_sidecar_write_withholds_the_npz(tmp_path, monkeypatch):
    """The npz replace is the commit point and it happens after the
    sidecar: a kill during the json write publishes neither file."""
    import repro.checkpointing.checkpoint as ckpt_mod
    tree = {"w": np.ones(3, np.float32)}

    def dying_dump(obj, f):
        raise KeyboardInterrupt

    monkeypatch.setattr(ckpt_mod.json, "dump", dying_dump)
    with pytest.raises(KeyboardInterrupt):
        save_checkpoint(str(tmp_path), 0, tree, meta={"round": 0})
    assert latest_checkpoint(str(tmp_path)) is None
    assert not os.path.exists(os.path.join(tmp_path, "ckpt_00000000.npz"))


# ---------------------------------------------------------------------------
# FedBuff partial-buffer edge cases
# ---------------------------------------------------------------------------
def test_flat_checkpoint_roundtrip_f32(tmp_path):
    """Flat-buffer checkpoint (DESIGN.md §12): buffer + layout round-trip
    bit-exactly and the restored layout unpacks without a template."""
    from repro.checkpointing import load_flat_checkpoint, save_flat_checkpoint
    from repro.core.flat import ParamLayout
    params = init_cnn(jax.random.PRNGKey(2))
    layout = ParamLayout.from_tree(params)
    flat = layout.pack(params)
    path = save_flat_checkpoint(str(tmp_path), 7, flat, layout,
                                meta={"round": 7})
    flat2, layout2 = load_flat_checkpoint(path)
    assert layout2 == layout
    np.testing.assert_array_equal(np.asarray(flat), flat2)
    restored = layout2.unpack(jnp.asarray(flat2))
    assert tree_digest(restored) == tree_digest(params)


def test_flat_checkpoint_roundtrip_bf16(tmp_path):
    """The bf16 ring rows round-trip bit-exactly through the ::bf16 npz
    view mechanism."""
    from repro.checkpointing import load_flat_checkpoint, save_flat_checkpoint
    from repro.core.flat import ParamLayout
    params = init_cnn(jax.random.PRNGKey(3))
    layout = ParamLayout.from_tree(params)
    flat = layout.pack(params, dtype=jnp.bfloat16)
    path = save_flat_checkpoint(str(tmp_path), 1, flat, layout)
    flat2, layout2 = load_flat_checkpoint(path)
    assert str(np.asarray(flat2).dtype) == "bfloat16"
    np.testing.assert_array_equal(np.asarray(flat).view(np.uint16),
                                  np.asarray(flat2).view(np.uint16))
    assert layout2.P == layout.P


def test_flat_checkpoint_shares_retention_with_pytree(tmp_path):
    from repro.checkpointing import save_flat_checkpoint
    from repro.core.flat import ParamLayout
    params = init_cnn(jax.random.PRNGKey(0))
    layout = ParamLayout.from_tree(params)
    flat = layout.pack(params)
    for step in range(4):
        save_flat_checkpoint(str(tmp_path), step, flat, layout, keep=2)
    kept = sorted(f for f in os.listdir(tmp_path) if f.endswith(".npz"))
    assert kept == ["ckpt_00000002.npz", "ckpt_00000003.npz"]
    assert latest_checkpoint(str(tmp_path)).endswith("ckpt_00000003.npz")


def _tree(val):
    return {"a": np.full((3,), val, np.float32),
            "b": np.full((2, 2), val * 2.0, np.float32)}


def test_fedbuff_partial_buffer_does_not_flush():
    agg = FedBuffAggregator(buffer_size=3)
    g = _tree(1.0)
    for local in (_tree(2.0), _tree(3.0)):
        out, flushed = agg.add(g, local)
        assert not flushed
        # the global model is returned untouched until the buffer fills
        assert tree_digest(out) == tree_digest(g)
    assert len(agg._buf) == 2


def test_fedbuff_flush_applies_mean_delta_and_resets():
    agg = FedBuffAggregator(buffer_size=3, lr=1.0)
    g = _tree(1.0)
    locals_ = [_tree(2.0), _tree(4.0), _tree(9.0)]
    out = g
    for i, local in enumerate(locals_):
        out, flushed = agg.add(g, local)
        assert flushed == (i == 2)
    # mean delta = mean(local - g) = ((1 + 3 + 8) / 3) for leaf "a"
    np.testing.assert_allclose(out["a"], np.full(3, 1.0 + 4.0), rtol=1e-6)
    np.testing.assert_allclose(out["b"], np.full((2, 2), 2.0 + 8.0),
                               rtol=1e-6)
    # buffer reset: the next add starts a fresh partial buffer
    _, flushed = agg.add(out, _tree(5.0))
    assert not flushed and len(agg._buf) == 1


def test_fedbuff_trailing_partial_buffer_is_dropped_by_scheme():
    """The fedbuff scheme's documented semantics: deltas still buffered
    when the run ends are never applied to the global model."""
    agg = FedBuffAggregator(buffer_size=4)
    g = _tree(0.0)
    for v in (1.0, 2.0, 3.0):                # never fills the buffer
        out, flushed = agg.add(g, _tree(v))
        assert not flushed
    assert tree_digest(out) == tree_digest(g)


def test_fedbuff_buffer_size_one_flushes_every_add():
    agg = FedBuffAggregator(buffer_size=1, lr=0.5)
    g = _tree(1.0)
    out, flushed = agg.add(g, _tree(3.0))
    assert flushed
    # lr=0.5 halves the applied delta
    np.testing.assert_allclose(out["a"], np.full(3, 2.0), rtol=1e-6)
    out2, flushed = agg.add(out, _tree(3.0))
    assert flushed


def test_fedbuff_scheme_runs_through_serial_engine():
    """End-to-end: the fedbuff scheme still runs the serial loop (the jit
    engine rejects it) and aggregates only on buffer flushes."""
    tr_i, tr_l, te_i, te_l = synth_mnist(n_train=300, n_test=60, seed=0,
                                         noise=0.35)
    p = dataclasses.replace(ChannelParams(), K=3)
    veh = partition_vehicles(tr_i, tr_l, p, seed=0, scale=0.01)
    r = run_simulation(veh, te_i, te_l, scheme="fedbuff", rounds=5,
                       l_iters=1, lr=0.05, params=p, seed=0, eval_every=5,
                       engine="serial")
    assert len(r.rounds) == 5
    assert np.isfinite(r.final_accuracy())

"""Scenario registry (DESIGN.md §8): named worlds, fleet-scale builds, and
the multi-RSU handover engine."""
import dataclasses

import numpy as np
import pytest

from repro.core.scenarios import (Scenario, _Corridor, build_world,
                                  get_scenario, list_scenarios, register,
                                  run_scenario)


def test_registry_contents():
    names = list_scenarios()
    assert "paper-k10" in names and "fleet-k100" in names
    assert "highway-k40-handover" in names
    for name in ("corridor-quick-r2-k8", "corridor-r4-k400",
                 "corridor-r8-k4000", "corridor-rush-hour-r8-k4000"):
        assert name in names
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("nope")
    with pytest.raises(ValueError, match="duplicate"):
        register(get_scenario("paper-k10"))


def test_corridor_scenarios_dwarf_the_fleet():
    sc = get_scenario("corridor-r8-k4000")
    assert sc.K == 4000 and sc.n_rsus == 8
    assert sc.K > 4 * get_scenario("fleet-k1000").K - 1


def test_paper_world_matches_table_one():
    sc = get_scenario("paper-k10")
    veh, te_i, te_l, p = build_world(sc)
    assert p.K == 10 and len(veh) == 10
    # Table-I heterogeneity preserved proportionally: D_i increasing in i
    sizes = [v.size for v in veh]
    assert sizes == sorted(sizes) and sizes[0] < sizes[-1]


def test_fleet_k100_world_builds_with_capped_shards():
    sc = get_scenario("fleet-k100")
    veh, te_i, te_l, p = build_world(sc)
    assert p.K == 100 and len(veh) == 100
    assert max(v.size for v in veh) <= 512
    # delays still use the uncapped Table-I D_i: strictly increasing in i
    from repro.channel import training_delay
    delays = [training_delay(p, i) for i in range(1, 101)]
    assert all(a < b for a, b in zip(delays, delays[1:]))


def test_quick_scenario_runs_batched():
    r = run_scenario("quick-k5", rounds=4, eval_every=2)
    assert len(r.rounds) == 4
    assert all(np.isfinite(a) for _, a in r.acc_history)
    times = [rec.time for rec in r.rounds]
    assert times == sorted(times)


def test_quick_scenario_runs_jit():
    r = run_scenario("quick-k5", rounds=4, eval_every=2, engine="jit",
                     l_iters=1)
    assert len(r.rounds) == 4
    assert all(np.isfinite(a) for _, a in r.acc_history)
    times = [rec.time for rec in r.rounds]
    assert times == sorted(times)


def test_mega_fleet_scenarios_registered():
    names = list_scenarios()
    for name in ("fleet-k1000", "fleet-k1000-noniid", "platoon-burst-k500"):
        assert name in names
    sc = get_scenario("fleet-k1000")
    assert sc.K == 1000 and sc.rounds == 30


def test_platoon_burst_world_has_convoy_delays():
    from repro.channel import training_delay
    sc = get_scenario("platoon-burst-k500")
    p = sc.channel()
    assert p.platoon == 25 and p.K == 500
    # convoy members share the leader's training delay
    assert training_delay(p, 1) == training_delay(p, 25)
    assert training_delay(p, 26) == training_delay(p, 50)
    assert training_delay(p, 1) != training_delay(p, 26)


def test_scenario_overrides_replace_fields():
    sc = get_scenario("fleet-k100")
    r = dataclasses.replace(sc, rounds=7)
    assert r.rounds == 7 and r.K == sc.K


def test_corridor_handover_geometry():
    from repro.channel.params import ChannelParams
    p = dataclasses.replace(ChannelParams(), K=4)
    c = _Corridor(p, n_rsus=4)
    # 4 segments of width 2*coverage: a vehicle in segment j is served by j
    for j in range(4):
        x_center_time = (c.centers[j] - c.x0[0]) / p.v
        assert c.serving_rsu(0, x_center_time) == j
    # distance at a segment center is the overhead distance
    t0 = (c.centers[2] - c.x0[0]) / p.v
    assert c.distance(0, t0) == pytest.approx(
        np.sqrt(p.d_y ** 2 + p.H ** 2))
    # wrap-around re-entry keeps x inside the corridor
    assert abs(c.x(0, 1e6)) <= c.span / 2


@pytest.mark.slow
def test_handover_scenario_runs():
    # default engine for multi-RSU worlds is now the device-resident
    # corridor engine; the retired serial loop stays reachable by name
    r = run_scenario("highway-k40-handover", rounds=16, eval_every=8)
    assert len(r.rounds) == 16
    assert r.scheme == "mafl+corridor"
    assert all(np.isfinite(a) for _, a in r.acc_history)
    rs = run_scenario("highway-k40-handover", rounds=16, eval_every=8,
                      engine="serial")
    assert rs.scheme == "mafl+handover"
    assert [(x.vehicle, x.rsu) for x in rs.rounds] == \
           [(x.vehicle, x.rsu) for x in r.rounds]


@pytest.mark.slow
def test_fleet_k100_scenario_completes():
    r = run_scenario("fleet-k100", rounds=30, eval_every=15, l_iters=2)
    assert len(r.rounds) == 30
    assert all(np.isfinite(a) for _, a in r.acc_history)
    # fleet diversity: multiple distinct vehicles contribute
    assert len({rec.vehicle for rec in r.rounds}) > 5

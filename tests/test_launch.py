"""Launcher-layer tests: input_specs per shape kind, mesh factory contracts.

(`repro.launch.dryrun` itself is exercised end-to-end by the recorded matrix
— importing it here would force 512 host devices onto the test process.)
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, get_shape
from repro.configs.shapes import SHAPES
from repro.launch.steps import cache_shapes, input_specs, param_shapes


def test_shapes_registry_matches_brief():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_train_input_specs_are_structs():
    cfg = get_config("smollm-360m")
    specs = input_specs(cfg, get_shape("train_4k"))
    assert isinstance(specs["tokens"], jax.ShapeDtypeStruct)
    assert specs["tokens"].shape == (256, 4097)          # +1 for targets


def test_vlm_input_specs_reserve_frontend_tokens():
    cfg = get_config("internvl2-2b")
    specs = input_specs(cfg, get_shape("train_4k"))
    # text tokens + 256 patch embeds == seq_len
    assert specs["tokens"].shape == (256, 4096 - 256 + 1)
    assert specs["patch_embeds"].shape == (256, 256, 2048)


def test_decode_input_specs_have_full_cache():
    cfg = get_config("mistral-nemo-12b")
    specs = input_specs(cfg, get_shape("decode_32k"))
    assert specs["token"].shape == (128, 1)
    k = specs["cache"]["stack"]["sub0"]["mixer"]["k"]
    assert k.shape == (40, 128, 32768, 8, 128)           # periods leading
    assert specs["pos"].shape == ()


def test_swa_variant_cache_is_window_sized():
    from repro.configs.mistral_nemo_12b import sliding_window_variant
    cfg = sliding_window_variant(4096)
    specs = input_specs(cfg, get_shape("long_500k"))
    k = specs["cache"]["stack"]["sub0"]["mixer"]["k"]
    assert k.shape[2] == 4096                            # ring, not 524288


def test_rwkv_long_cache_is_constant_size():
    cfg = get_config("rwkv6-1.6b")
    specs = input_specs(cfg, get_shape("long_500k"))
    wkv = specs["cache"]["stack"]["sub0"]["mixer"]["wkv"]
    assert wkv.shape == (24, 1, 32, 64, 64)              # O(1) in seq_len
    # total state bytes are tiny vs a KV cache
    total = sum(s.size for s in jax.tree_util.tree_leaves(specs["cache"]))
    assert total < 50_000_000


def test_param_shapes_eval_only():
    """llama3-405b param shapes must come back instantly (no allocation)."""
    shapes = param_shapes(get_config("llama3-405b"))
    leaves = jax.tree_util.tree_leaves(shapes)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    import math
    n = sum(math.prod(l.shape) for l in leaves)
    assert n > 3.8e11


def test_mesh_factory_is_lazy():
    """Importing mesh.py must not construct device meshes."""
    import importlib
    import repro.launch.mesh as m
    importlib.reload(m)                                  # no exception = ok
    host = m.make_host_mesh()
    assert host.shape == {"data": 1, "model": 1}

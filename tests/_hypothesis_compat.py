"""Optional-``hypothesis`` shim (tier-1 unbreak).

The container image does not ship ``hypothesis`` (it is declared as an
optional test dependency in ``pyproject.toml``).  Importing it at module
scope made the whole suite fail at *collection*.  This shim re-exports the
real library when present; otherwise it substitutes a deterministic
fallback: each strategy contributes a small fixed set of representative
samples (bounds + midpoint) and ``@given`` runs the test body over them —
so the property tests keep running as deterministic example-based cases
instead of being skipped.
"""
from __future__ import annotations


try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                           # fallback
    HAVE_HYPOTHESIS = False

    class _Samples:
        def __init__(self, values):
            self.values = list(values)

    class st:  # noqa: N801 - mimics `hypothesis.strategies` module
        @staticmethod
        def integers(min_value, max_value):
            mid = (min_value + max_value) // 2
            return _Samples(dict.fromkeys([min_value, mid, max_value]))

        @staticmethod
        def floats(min_value, max_value):
            mid = (min_value + max_value) / 2.0
            return _Samples(dict.fromkeys([min_value, mid, max_value]))

    def settings(**_kw):
        def deco(fn):
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            # no functools.wraps: copying __wrapped__ would make pytest
            # read the original signature and demand fixtures for the
            # drawn arguments
            def wrapper():
                n = max(len(s.values) for s in strats)
                for i in range(n):
                    drawn = [s.values[i % len(s.values)] for s in strats]
                    fn(*drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

import os
import sys

# smoke tests and benches must see ONE device (the dry-run alone forces 512,
# in its own process) — per the brief, never set the device-count flag here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Persistent XLA compilation cache: the suite is compile-dominated on a
# 2-core CPU host, and every process re-paid every trace before this.
# Warm re-runs of the tier-1 lane skip most compile time; cold runs are
# unaffected except for writing the cache.
try:
    import jax

    jax.config.update(
        "jax_compilation_cache_dir",
        os.path.join(os.path.dirname(__file__), "..", ".jax_cache"))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
except Exception:                                    # pragma: no cover
    pass

import os
import sys

# smoke tests and benches must see ONE device (the dry-run alone forces 512,
# in its own process) — per the brief, never set the device-count flag here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

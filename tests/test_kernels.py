"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode),
plus hypothesis property tests — deliverable (c).  When ``hypothesis`` is
absent the property tests fall back to deterministic example sweeps via
``_hypothesis_compat`` instead of breaking collection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels.cross_entropy import ops as ce_ops, ref as ce_ref
from repro.kernels.decode_attention import ops as dec_ops, ref as dec_ref
from repro.kernels.swa_attention import ops as swa_ops, ref as swa_ref
from repro.kernels.weighted_agg import ops as agg_ops, ref as agg_ref


# ---------------------------------------------------------------------------
# weighted_agg (the paper's Eq. 10+11 fused)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(128,), (1000,), (513, 7), (32, 128),
                                   (100,), (4, 4, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_weighted_agg_shapes_dtypes(shape, dtype):
    g = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    l = jax.random.normal(jax.random.PRNGKey(1), shape).astype(dtype)
    out_k = agg_ops.weighted_agg_leaf(g, l, 0.5, 0.93)
    out_r = agg_ref.weighted_agg(g, l, 0.5, 0.93)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=tol)
    assert out_k.dtype == g.dtype and out_k.shape == g.shape


@given(st.integers(1, 2000), st.floats(0.05, 0.95), st.floats(0.0, 1.3))
@settings(max_examples=20, deadline=None)
def test_weighted_agg_property(n, beta, weight):
    g = jnp.linspace(-2, 2, n)
    l = jnp.linspace(3, -1, n)
    out = agg_ops.weighted_agg_leaf(g, l, beta, weight)
    expect = beta * g + (1 - beta) * weight * l
    np.testing.assert_allclose(out, expect, atol=1e-5)


@pytest.mark.parametrize("rows", [1, 7, 255, 300])      # != 0 mod block_rows
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("interpret", [True, None])
def test_weighted_agg_2d_rows_dtypes_interpret(rows, dtype, interpret):
    """Direct [R, 128] kernel parity vs the jnp oracle for row counts that
    are not multiples of the block size, both dtypes, and both the forced
    interpreter and the backend-resolved default."""
    from repro.kernels.weighted_agg.kernel import weighted_agg_2d
    g = jax.random.normal(jax.random.PRNGKey(0), (rows, 128)).astype(dtype)
    l = jax.random.normal(jax.random.PRNGKey(1), (rows, 128)).astype(dtype)
    scalars = jnp.asarray([[0.5, 0.93]], jnp.float32)
    out = weighted_agg_2d(g, l, scalars, block_rows=64, interpret=interpret)
    expect = agg_ref.weighted_agg(g, l, 0.5, 0.93)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)
    assert out.dtype == g.dtype


@pytest.mark.skipif(jax.default_backend() == "cpu",
                    reason="compiled (non-interpret) Pallas needs TPU/GPU")
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_weighted_agg_2d_compiled_mode(dtype):
    """On an accelerator the backend-resolved default must agree with the
    explicitly compiled kernel and the oracle."""
    from repro.kernels.weighted_agg.kernel import weighted_agg_2d
    g = jax.random.normal(jax.random.PRNGKey(0), (300, 128)).astype(dtype)
    l = jax.random.normal(jax.random.PRNGKey(1), (300, 128)).astype(dtype)
    scalars = jnp.asarray([[0.5, 0.93]], jnp.float32)
    out = weighted_agg_2d(g, l, scalars, interpret=False)
    expect = agg_ref.weighted_agg(g, l, 0.5, 0.93)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)


def test_weighted_agg_default_resolves_by_backend():
    """interpret=None must pick the interpreter exactly on CPU."""
    from repro.kernels.weighted_agg import ops as agg_ops_mod
    g = jnp.ones((5, 100))           # non-multiple-of-128 leaf: tail path
    l = jnp.full((5, 100), 3.0)
    out = agg_ops_mod.weighted_agg_leaf(g, l, 0.5, 1.0)
    np.testing.assert_allclose(out, 2.0 * jnp.ones((5, 100)), atol=1e-6)


def test_weighted_agg_tree_matches_treemap():
    tree_g = {"a": jnp.ones((300,)), "b": {"c": jnp.full((5, 40), 2.0)}}
    tree_l = {"a": jnp.full((300,), 3.0), "b": {"c": jnp.ones((5, 40))}}
    out = agg_ops.weighted_agg_tree(tree_g, tree_l, 0.5, 1.0)
    np.testing.assert_allclose(out["a"], 2.0 * jnp.ones(300), atol=1e-6)
    np.testing.assert_allclose(out["b"]["c"], 1.5 * jnp.ones((5, 40)),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# cross_entropy (Eq. 1 over large vocab)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("R,V", [(128, 2048), (64, 4096), (100, 3000),
                                 (8, 512), (256, 1111)])
def test_cross_entropy_vs_ref(R, V):
    logits = (jax.random.normal(jax.random.PRNGKey(0), (R, V)) * 3)
    labels = jax.random.randint(jax.random.PRNGKey(1), (R,), 0, V)
    np.testing.assert_allclose(ce_ops.cross_entropy(logits, labels),
                               ce_ref.cross_entropy(logits, labels),
                               atol=1e-4)


def test_cross_entropy_extreme_logits_stable():
    logits = jnp.array([[1e4, -1e4, 0.0, 5.0] * 128] * 8)
    labels = jnp.zeros((8,), jnp.int32)
    out = ce_ops.cross_entropy(logits, labels)
    assert jnp.isfinite(out).all()
    np.testing.assert_allclose(out, ce_ref.cross_entropy(logits, labels),
                               atol=1e-3)


@given(st.integers(2, 64), st.integers(16, 600))
@settings(max_examples=15, deadline=None)
def test_cross_entropy_property(R, V):
    logits = jax.random.normal(jax.random.PRNGKey(R * V), (R, V))
    labels = jnp.arange(R) % V
    out = ce_ops.cross_entropy(logits, labels)
    # NLL is non-negative and finite
    assert (np.asarray(out) >= 0).all() and np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(out, ce_ref.cross_entropy(logits, labels),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# swa_attention
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("B,S,H,Kv,hd,W,bq,bk", [
    (1, 128, 2, 2, 32, 32, 32, 32),
    (2, 256, 4, 2, 64, 64, 64, 64),
    (1, 128, 4, 1, 32, 64, 64, 32),
    (1, 256, 2, 2, 32, 96, 32, 32),
    (1, 64, 2, 2, 32, 33, 32, 32),       # W not a multiple of block
    (1, 128, 2, 1, 32, 200, 64, 32),     # W > S
])
def test_swa_attention_vs_ref(B, S, H, Kv, hd, W, bq, bk):
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Kv, hd))
    out_k = swa_ops.swa_attention(q, k, v, W, block_q=bq, block_k=bk)
    out_r = swa_ref.swa_attention(q, k, v, W)
    np.testing.assert_allclose(out_k, out_r, atol=1e-4)


@pytest.mark.slow
def test_swa_kernel_bf16():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 128, 2, 32),
                          jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 2, 32),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 2, 32),
                          jnp.bfloat16)
    out_k = swa_ops.swa_attention(q, k, v, 64, block_q=64, block_k=64)
    out_r = swa_ref.swa_attention(q, k, v, 64)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=3e-2)


# ---------------------------------------------------------------------------
# decode_attention (one token vs KV cache — the decode-shape hot-spot)
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("B,S,H,Kv,hd,bs,pos", [
    (2, 256, 4, 2, 32, 64, 200),
    (1, 512, 8, 8, 64, 128, 511),
    (2, 128, 6, 2, 32, 32, 5),        # mostly-masked cache
    (1, 1024, 4, 1, 64, 256, 700),    # MQA grouping
])
def test_decode_attention_vs_ref(B, S, H, Kv, hd, bs, pos):
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Kv, hd))
    out_k = dec_ops.decode_attention(q, k, v, pos, block_s=bs)
    out_r = dec_ref.decode_attention(q, k, v, pos)
    np.testing.assert_allclose(out_k, out_r, atol=1e-4)


@pytest.mark.slow
def test_decode_attention_matches_model_decode_path():
    """Kernel == the model's jnp full-attention decode (same math)."""
    from repro.configs import get_config
    from repro.models import attention as model_attn
    from repro.models.modules import apply_rope
    cfg = get_config("internvl2-2b").reduced()
    key = jax.random.PRNGKey(0)
    p = model_attn.init_attention(cfg, key, jnp.float32)
    cache = model_attn.init_attn_cache(cfg, 2, 64, "full", 0, jnp.float32)
    # pre-fill a few slots
    for t in range(5):
        x = jax.random.normal(jax.random.PRNGKey(10 + t),
                              (2, 1, cfg.d_model)) * 0.3
        y_model, cache = model_attn.attention_decode(cfg, p, x, cache,
                                                     jnp.int32(t))
    # compare the final step's attention against the kernel
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = apply_rope(q, jnp.array([4]), cfg.rope_theta)[:, 0]
    out = dec_ops.decode_attention(q, cache["k"], cache["v"], 4, block_s=32)
    y_kernel = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
    np.testing.assert_allclose(y_model, y_kernel, atol=2e-4)


@pytest.mark.slow
def test_swa_kernel_agrees_with_model_swa_path():
    """Kernel == the model's jnp SWA attention (same math, two impls)."""
    from repro.configs import get_config
    from repro.models import attention as model_attn
    cfg = get_config("mistral-nemo-12b").reduced().variant(sliding_window=64)
    key = jax.random.PRNGKey(0)
    p = model_attn.init_attention(cfg, key, jnp.float32)
    x = jax.random.normal(key, (1, 128, cfg.d_model)) * 0.3
    pos = jnp.arange(128, dtype=jnp.int32)
    y_model, _ = model_attn.attention_fwd(cfg, p, x, pos, "swa", 64)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    from repro.models.modules import apply_rope
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    out = swa_ops.swa_attention(q, k, v, 64, block_q=64, block_k=64)
    y_kernel = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    np.testing.assert_allclose(y_model, y_kernel, atol=2e-4)

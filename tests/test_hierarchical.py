"""Hierarchical multi-pod MAFL (beyond paper): pod-local aggregation +
cross-pod reconciliation, run on a small in-process device mesh via a
subprocess with forced host devices (tests must normally see ONE device, so
the multi-device check runs isolated)."""
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hierarchical import pod_local_mafl, reconcile_models


def test_reconcile_models_is_mean_of_cohorts():
    models = [{"w": jnp.full((3,), float(v))} for v in (1.0, 2.0, 6.0)]
    out = reconcile_models(models)
    np.testing.assert_allclose(np.asarray(out["w"]), 3.0, rtol=1e-6)
    assert out["w"].dtype == models[0]["w"].dtype


def test_pod_local_update_matches_mixing_rule():
    g = {"w": jnp.ones((4,))}
    l = {"w": jnp.full((4,), 3.0)}
    out = pod_local_mafl(g, l, beta=0.5, weight=0.8)
    alpha = 0.5 * 0.8
    np.testing.assert_allclose(out["w"], (1 - alpha) * 1 + alpha * 3,
                               rtol=1e-6)


@pytest.mark.slow
def test_cross_pod_reconcile_on_multidevice_mesh():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.hierarchical import (cross_pod_reconcile,
                                             make_hierarchical_round)

        mesh = jax.make_mesh((2, 2), ("pod", "data"))
        # per-pod models differ: pod 0 holds 1.0, pod 1 holds 3.0
        arr = jnp.concatenate([jnp.ones((2, 4)), jnp.full((2, 4), 3.0)])
        sharded = jax.device_put(arr,
                                 NamedSharding(mesh, P(("pod", "data"))))
        with jax.set_mesh(mesh):
            rec = cross_pod_reconcile({"w": sharded}, mesh)
        np.testing.assert_allclose(np.asarray(rec["w"]), 2.0)

        # a full round with reconcile_every=1 must also average
        with jax.set_mesh(mesh):
            round_fn = make_hierarchical_round(mesh, beta=0.5,
                                               reconcile_every=1)
            out = jax.jit(round_fn)(jnp.int32(0), {"w": sharded},
                                    {"w": sharded}, jnp.float32(1.0))
        np.testing.assert_allclose(np.asarray(out["w"]), 2.0)
        print("HIERARCHICAL_OK")
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "HIERARCHICAL_OK" in res.stdout, res.stderr[-2000:]

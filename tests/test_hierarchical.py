"""Hierarchical multi-pod MAFL (beyond paper): pod-local aggregation +
cross-pod reconciliation, run on a small in-process device mesh via a
subprocess with forced host devices (tests must normally see ONE device, so
the multi-device check runs isolated)."""
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hierarchical import pod_local_mafl, reconcile_models

# Environment for the forced-host-device subprocesses.  JAX_PLATFORMS=cpu
# is load-bearing: this container carries libtpu, and without the pin jax's
# device init blocks for minutes probing for a TPU before falling back —
# which is a subprocess-timeout, not a test failure, and wastes the whole
# slow-lane budget.  /usr/local/bin on PATH matches the interpreter.
SUBPROC_ENV = {"PYTHONPATH": "src", "PATH": "/usr/local/bin:/usr/bin:/bin",
               "JAX_PLATFORMS": "cpu"}


def test_reconcile_models_is_mean_of_cohorts():
    models = [{"w": jnp.full((3,), float(v))} for v in (1.0, 2.0, 6.0)]
    out = reconcile_models(models)
    np.testing.assert_allclose(np.asarray(out["w"]), 3.0, rtol=1e-6)
    assert out["w"].dtype == models[0]["w"].dtype


def test_pod_local_update_matches_mixing_rule():
    g = {"w": jnp.ones((4,))}
    l = {"w": jnp.full((4,), 3.0)}
    out = pod_local_mafl(g, l, beta=0.5, weight=0.8)
    alpha = 0.5 * 0.8
    np.testing.assert_allclose(out["w"], (1 - alpha) * 1 + alpha * 3,
                               rtol=1e-6)


@pytest.mark.slow
def test_cross_pod_reconcile_on_multidevice_mesh():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.hierarchical import (cross_pod_reconcile,
                                             make_hierarchical_round)

        mesh = jax.make_mesh((2, 2), ("pod", "data"))
        # per-pod models differ: pod 0 holds 1.0, pod 1 holds 3.0
        arr = jnp.concatenate([jnp.ones((2, 4)), jnp.full((2, 4), 3.0)])
        sharded = jax.device_put(arr,
                                 NamedSharding(mesh, P(("pod", "data"))))
        # mesh is passed explicitly throughout (jax.set_mesh no longer
        # exists in this jax version)
        rec = cross_pod_reconcile({"w": sharded}, mesh)
        np.testing.assert_allclose(np.asarray(rec["w"]), 2.0)

        # a full round with reconcile_every=1 must also average
        round_fn = make_hierarchical_round(mesh, beta=0.5,
                                           reconcile_every=1)
        out = jax.jit(round_fn)(jnp.int32(0), {"w": sharded},
                                {"w": sharded}, jnp.float32(1.0))
        np.testing.assert_allclose(np.asarray(out["w"]), 2.0)
        print("HIERARCHICAL_OK")
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300, env=SUBPROC_ENV)
    assert "HIERARCHICAL_OK" in res.stdout, res.stderr[-2000:]


@pytest.mark.slow
def test_cross_pod_reconcile_eight_devices_ema():
    """Eight forced host devices, one pod axis: FedAvg equals the mean of
    the eight per-pod cohorts, EMA (tau<1) lands each pod's model at the
    right intermediate, and the kernel-routed EMA agrees (corridor cloud
    tier, DESIGN.md §10)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.hierarchical import cross_pod_reconcile

        mesh = jax.make_mesh((8,), ("pod",))
        # pod j holds the constant model j (leaf rows sharded over pod)
        arr = jnp.repeat(jnp.arange(8.0)[:, None], 256, axis=1)
        sharded = jax.device_put(arr, NamedSharding(mesh, P("pod")))
        spec = P("pod")
        rec = cross_pod_reconcile({"w": sharded}, mesh, shard_spec=spec)
        ema = cross_pod_reconcile({"w": sharded}, mesh, shard_spec=spec,
                                  tau=0.5)
        emak = cross_pod_reconcile({"w": sharded}, mesh, shard_spec=spec,
                                   tau=0.5, use_kernel=True)
        np.testing.assert_allclose(np.asarray(rec["w"]), 3.5)
        want = 0.5 * np.arange(8.0)[:, None] + 0.5 * 3.5
        np.testing.assert_allclose(np.asarray(ema["w"]),
                                   np.broadcast_to(want, (8, 256)))
        np.testing.assert_allclose(np.asarray(emak["w"]),
                                   np.asarray(ema["w"]), atol=1e-6)
        print("HIERARCHICAL8_OK")
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300, env=SUBPROC_ENV)
    assert "HIERARCHICAL8_OK" in res.stdout, res.stderr[-2000:]

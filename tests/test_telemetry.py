"""Telemetry subsystem invariants (DESIGN.md §14).

The two contracts pinned here:

1. **Off is a bitwise no-op.**  ``metrics='off'`` (or None) must produce
   bit-identical models and identical arrival traces to a run with no
   telemetry argument at all, on every engine — and on the device engines
   it must not even stage a new program (cache identity, rule TEL001).
   All comparisons are fresh-run vs fresh-run in this process, never
   against stored fixtures, so they hold on any host/BLAS combination.

2. **Channels conform to the f64 replay.**  The device accumulators (f32,
   in-scan) must reproduce the host f64 oracle exactly for the staleness
   histogram, occupancy, and handover counters (safe-margin edges make
   exact equality achievable), and to divergence-guard tolerance for the
   pop-wait trace.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.channel import ChannelParams
from repro.core import run_simulation
from repro.core.scenarios import build_world, get_scenario, run_scenario
from repro.checkpointing.checkpoint import tree_digest
from repro.data import partition_vehicles, synth_mnist
from repro.telemetry import RunReport, metrics_requested
from repro.telemetry.replay import (replay_corridor_channels,
                                    replay_fleet_channels)
from repro.telemetry.report import SCHEMA, wave_stats
from repro.telemetry.runlog import append, diff, load, render
from repro.telemetry.spec import (MetricsSpec, bucket_indices,
                                  plan_stale_edges, resolve_metrics,
                                  stale_histogram, stale_margin)

ROUNDS = 8


@pytest.fixture(scope="module")
def small_world():
    tr_i, tr_l, te_i, te_l = synth_mnist(n_train=256, n_test=64, seed=0)
    p = dataclasses.replace(ChannelParams(), K=4)
    veh = partition_vehicles(tr_i, tr_l, p, seed=0, scale=0.03)
    return veh, te_i, te_l, p


def _run(world, engine, **kw):
    veh, te_i, te_l, p = world
    return run_simulation(veh, te_i, te_l, scheme="mafl", rounds=ROUNDS,
                          l_iters=1, lr=0.05, params=p, seed=0,
                          eval_every=ROUNDS, engine=engine, batch_size=32,
                          **kw)


def _trace(result):
    return [(r.round, r.vehicle, r.time, r.upload_delay, r.train_delay)
            for r in result.rounds]


# ---------------------------------------------------------------------------
# contract 1: off is a bitwise no-op
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("engine", ["serial", "batched", "jit"])
def test_metrics_off_is_bitwise_noop(small_world, engine):
    base = _run(small_world, engine)
    off = _run(small_world, engine, metrics="off")
    assert tree_digest(off.final_params) == tree_digest(base.final_params)
    assert _trace(off) == _trace(base)
    assert off.report is not None and not off.report.metrics_on
    assert off.report.channels == {} and off.report.spec is None


@pytest.mark.parametrize("engine", ["serial", "batched", "jit"])
def test_metrics_on_does_not_change_models(small_world, engine):
    """Telemetry rides in dead-code-free extra carries/columns: turning it
    on must not perturb the aggregation arithmetic."""
    base = _run(small_world, engine)
    on = _run(small_world, engine, metrics="on")
    assert tree_digest(on.final_params) == tree_digest(base.final_params)
    assert _trace(on) == _trace(base)
    assert on.report.metrics_on and on.report.spec["enabled"]


def test_metrics_off_reuses_jit_program(small_world):
    from repro.core.jit_engine import _PROGRAM_CACHE

    _run(small_world, "jit")
    n = len(_PROGRAM_CACHE)
    _run(small_world, "jit", metrics="off")
    assert len(_PROGRAM_CACHE) == n, \
        "metrics='off' staged a new jit program (TEL001)"


def test_telemetry_off_probe_clean():
    """The repro.check TEL001 probe sees no findings on the live tree."""
    from repro.check.telemetry_off import probe_telemetry_off

    assert probe_telemetry_off() == []


@pytest.mark.parametrize("engine", ["corridor", "serial"])
def test_corridor_metrics_off_is_bitwise_noop(engine):
    sc = get_scenario("corridor-quick-r2-k8")
    base = run_scenario(sc, seed=0, engine=engine, eval_every=sc.rounds)
    off = run_scenario(sc, seed=0, engine=engine, eval_every=sc.rounds,
                       metrics="off")
    on = run_scenario(sc, seed=0, engine=engine, eval_every=sc.rounds,
                      metrics="on")
    assert tree_digest(off.final_params) == tree_digest(base.final_params)
    assert tree_digest(on.final_params) == tree_digest(base.final_params)
    assert _trace(off) == _trace(base)
    assert _trace(on) == _trace(base)
    assert on.report.scenario == sc.name


# ---------------------------------------------------------------------------
# contract 2: channels conform to the f64 replay
# ---------------------------------------------------------------------------
def _fleet_channels_vs_replay(result, p, rounds, selection=None):
    rep = replay_fleet_channels(p, 0, rounds, selection=selection)
    spec = resolve_metrics("on", stale=rep["stale"], times=rep["times"])
    ch = {k: np.asarray(v) for k, v in result.report.channels.items()}
    assert result.report.spec["edges"] == list(spec.edges)
    assert np.array_equal(ch["stale_hist"],
                          stale_histogram(spec.edges, rep["stale"]))
    assert np.array_equal(ch["occupancy"], rep["occupancy"])
    assert np.allclose(ch["gap"], rep["gap"], rtol=1e-4, atol=1e-3)
    assert len(ch["reward"]) == rounds and np.all(ch["reward"] > 0)


@pytest.mark.parametrize("engine", ["serial", "batched", "jit"])
def test_small_fleet_channels_match_replay(small_world, engine):
    on = _run(small_world, engine, metrics="on")
    _fleet_channels_vs_replay(on, small_world[3], ROUNDS)


def test_fleet_k100_jit_channels_match_replay():
    sc = dataclasses.replace(get_scenario("fleet-k100"), rounds=12,
                             l_iters=1)
    _, _, _, p = build_world(sc, seed=0)
    on = run_scenario(sc, seed=0, engine="jit", eval_every=sc.rounds,
                      metrics="on")
    _fleet_channels_vs_replay(on, p, sc.rounds,
                              selection=sc.selection_spec())
    # K=100, one upload in flight per vehicle: occupancy is pinned at K
    assert np.all(np.asarray(on.report.channels["occupancy"]) == sc.K)
    assert on.report.waves["total_trained"] == sc.rounds


def _corridor_channels_vs_replay(result, sc, p):
    from repro.selection import scenario_spec

    rep = replay_corridor_channels(
        p, sc.n_rsus, 0, sc.rounds,
        entry=getattr(sc, "corridor_entry", "uniform"),
        selection=scenario_spec(sc), reconcile_every=sc.reconcile_every)
    spec = resolve_metrics("on", stale=rep["stale"], times=rep["times"],
                           n_rsus=sc.n_rsus)
    ch = {k: np.asarray(v) for k, v in result.report.channels.items()}
    assert np.array_equal(
        ch["stale_hist"],
        stale_histogram(spec.edges, rep["stale"], rsu=rep["up_rsu"],
                        n_rsus=sc.n_rsus))
    assert np.array_equal(ch["occupancy"], rep["occupancy"])
    assert np.array_equal(ch["handover"].astype(bool), rep["handover"])
    assert np.array_equal(ch["handover_count"], rep["handover_count"])
    assert np.allclose(ch["gap"], rep["gap"], rtol=1e-4, atol=1e-3)
    return rep


@pytest.mark.parametrize("engine", ["corridor", "serial"])
def test_corridor_channels_match_replay(engine):
    sc = get_scenario("corridor-quick-r2-k8")
    _, _, _, p = build_world(sc, seed=0)
    on = run_scenario(sc, seed=0, engine=engine, eval_every=sc.rounds,
                      metrics="on")
    _corridor_channels_vs_replay(on, sc, p)


def test_highway_handover_channel_counts():
    """A corridor world whose vehicles actually cross coverage boundaries:
    the handover counters must match the replay and be non-trivial."""
    # 24 pops is the earliest this world crosses a cell boundary (the f64
    # replay puts the first handover at pop 22)
    sc = dataclasses.replace(get_scenario("highway-k40-handover"),
                             rounds=24, l_iters=1)
    _, _, _, p = build_world(sc, seed=0)
    on = run_scenario(sc, seed=0, engine="corridor", eval_every=sc.rounds,
                      metrics="on")
    rep = _corridor_channels_vs_replay(on, sc, p)
    assert int(rep["handover_count"].sum()) > 0


def test_jit_bf16_ring_guard(small_world):
    on = _run(small_world, "jit", ring_dtype="bf16", metrics="on")
    ch = on.report.channels
    assert int(ch["ring_nonfinite"]) == 0
    assert float(ch["ring_max_abs"]) > 0.0
    assert on.report.spec["ring_guard"]


# ---------------------------------------------------------------------------
# planner: safe-margin edges
# ---------------------------------------------------------------------------
def test_edges_keep_safe_margin_from_samples():
    rng = np.random.default_rng(7)
    for trial in range(20):
        times = np.sort(rng.uniform(0.0, 3000.0, 64))
        stale = rng.uniform(0.0, 50.0, 64)
        edges = plan_stale_edges(stale, times)
        margin = stale_margin(times)
        for e in edges:
            assert np.min(np.abs(stale - e)) > margin
        # the margin guarantee is exactly what makes f32 and f64
        # staleness bucket identically
        f32_stale = np.float64(np.float32(stale))
        assert np.array_equal(bucket_indices(edges, stale),
                              bucket_indices(edges, f32_stale))
        assert np.all(np.diff(edges) > 0)


def test_metrics_requested_normalization():
    assert not metrics_requested(None)
    assert not metrics_requested(False)
    assert not metrics_requested("off")
    assert metrics_requested("on") and metrics_requested(True)
    assert metrics_requested(MetricsSpec(enabled=True))
    assert not metrics_requested(MetricsSpec(enabled=False))
    with pytest.raises(ValueError):
        metrics_requested("sometimes")
    assert resolve_metrics("off", stale=np.ones(3), times=np.ones(3)) is None


def test_wave_stats():
    waves = (((0, 1, 2), 0, 3), ((3, 4), 3, 5))
    s = wave_stats(waves, k=4)
    assert s["n_waves"] == 2 and s["sizes"] == [3, 2]
    assert s["total_trained"] == 5 and s["max_fill"] == 3
    assert s["utilization_vs_fleet"] == pytest.approx(5 / 8)


# ---------------------------------------------------------------------------
# run log + report schema + CLI
# ---------------------------------------------------------------------------
def test_report_json_roundtrip(small_world):
    on = _run(small_world, "jit", metrics="on")
    d = on.report.to_json()
    json.dumps(d)                      # fully serializable
    back = RunReport.from_json(d)
    assert back.engine == "jit" and back.metrics_on
    assert back.channels["stale_hist"] == d["channels"]["stale_hist"]
    bad = dict(d, schema="repro.telemetry/v0")
    with pytest.raises(ValueError):
        RunReport.from_json(bad)
    assert d["schema"] == SCHEMA


def test_runlog_roundtrip_and_diff(small_world, tmp_path):
    on = _run(small_world, "jit", metrics="on")
    off = _run(small_world, "jit", metrics="off")
    log = tmp_path / "runs.jsonl"
    append(log, on.report)
    append(log, off.report)
    runs = load(log)                   # schema-checked dicts
    assert len(runs) == 2
    assert runs[0]["metrics_on"] and not runs[1]["metrics_on"]
    text = render(runs)
    assert "jit" in text and "staleness hist" in text
    dtext = diff(runs[0], runs[1])
    assert "metrics_on" in dtext


def test_cli_report_and_diff(small_world, tmp_path, capsys):
    from repro.telemetry.__main__ import main

    on = _run(small_world, "jit", metrics="on")
    log = tmp_path / "runs.jsonl"
    append(log, on.report)
    assert main(["report", str(log)]) == 0
    assert "jit" in capsys.readouterr().out
    assert main(["diff", str(log), str(log)]) == 0
    capsys.readouterr()


def test_phase_timers_and_memory(small_world):
    on = _run(small_world, "jit", metrics="on")
    phases = on.report.phases
    assert {"plan", "stage", "run", "eval"} <= set(phases)
    assert all(v >= 0.0 for v in phases.values())
    assert on.report.memory.get("peak_rss_bytes", 0) > 0

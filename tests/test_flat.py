"""Flat-parameter fast-path unit tests (DESIGN.md §12):

- ``ParamLayout`` pack/unpack round-trip property tests (bitwise, batch
  axes, lane alignment, bf16 cast behavior, json serialization);
- ``ring_agg`` vs sequential ``mix_update`` parity across U, dtypes, and
  interpret/compiled modes;
- the prefix-weight algebra (``ops.prefix_weights``) against the chain;
- ``chain_coeffs`` against the engines' per-scheme mix expressions;
- the ``weighted_agg_leaf`` padded-tail path (satellite: no more
  jnp-oracle + concatenate remainder).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.aggregation import chain_coeffs, mix_update_donated
from repro.core.flat import LANE, ParamLayout
from repro.kernels.weighted_agg import ops as agg_ops, ref as agg_ref
from repro.models.cnn import init_cnn


def _tree(seed=0):
    return init_cnn(jax.random.PRNGKey(seed))


# ---------------------------------------------------------------------------
# ParamLayout
# ---------------------------------------------------------------------------
def test_layout_offsets_lane_aligned_and_disjoint():
    lay = ParamLayout.from_tree(_tree())
    assert lay.P % LANE == 0
    prev_end = 0
    for off, size in zip(lay.offsets, lay.sizes):
        assert off % LANE == 0, "leaf offsets must be lane-aligned"
        assert off >= prev_end, "leaf slices must not overlap"
        prev_end = off + size
    assert lay.P >= prev_end


def test_pack_unpack_bitwise_roundtrip():
    w = _tree()
    lay = ParamLayout.from_tree(w)
    back = lay.unpack(lay.pack(w))
    for k in w:
        assert np.array_equal(np.asarray(w[k]), np.asarray(back[k])), k
        assert back[k].dtype == w[k].dtype


def test_pack_unpack_batched_roundtrip():
    w = _tree()
    lay = ParamLayout.from_tree(w)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.stack([x, 2.0 * x, -x]), w)
    buf = lay.pack(stacked)
    assert buf.shape == (3, lay.P)
    back = lay.unpack(buf)
    for k in w:
        assert np.array_equal(np.asarray(stacked[k]), np.asarray(back[k]))


def test_pack_pads_gaps_with_zeros():
    w = _tree()
    lay = ParamLayout.from_tree(w)
    buf = np.asarray(lay.pack(w))
    mask = np.zeros(lay.P, bool)
    for off, size in zip(lay.offsets, lay.sizes):
        mask[off:off + size] = True
    assert np.all(buf[~mask] == 0.0)


def test_bf16_pack_unpack_casts_back_to_template_dtype():
    w = _tree()
    lay = ParamLayout.from_tree(w)
    buf = lay.pack(w, dtype=jnp.bfloat16)
    assert buf.dtype == jnp.bfloat16
    back = lay.unpack(buf)
    for k in w:
        assert back[k].dtype == w[k].dtype           # f32 restored
        expect = np.asarray(w[k].astype(jnp.bfloat16).astype(w[k].dtype))
        assert np.array_equal(expect, np.asarray(back[k])), k


def test_layout_json_roundtrip_unpacks_without_template():
    w = _tree()
    lay = ParamLayout.from_tree(w)
    lay2 = ParamLayout.from_json(lay.to_json())
    assert lay2 == lay and hash(lay2) == hash(lay)
    back = lay2.unpack(lay.pack(w))
    for k in w:
        assert np.array_equal(np.asarray(w[k]), np.asarray(back[k]))


def test_layout_json_roundtrip_list_pytree_many_leaves():
    """Regression: a list pytree of >=10 leaves restores through json as
    a canonicalized dict ('0'..'10' keys) with every leaf's DATA intact —
    dict flattening sorts '10' before '2', which used to scramble the
    offset columns against the leaf order."""
    tree = [jnp.full((3,), float(i)) for i in range(11)]
    lay = ParamLayout.from_tree(tree)
    lay2 = ParamLayout.from_json(lay.to_json())
    back = lay2.unpack(lay.pack(tree))
    assert isinstance(back, dict) and set(back) == {str(i)
                                                    for i in range(11)}
    for i in range(11):
        np.testing.assert_array_equal(np.asarray(back[str(i)]),
                                      np.asarray(tree[i]))


@given(st.integers(1, 5), st.integers(1, 97))
@settings(max_examples=10, deadline=None)
def test_layout_roundtrip_property(n_leaves, base):
    rng = np.random.default_rng(base)
    tree = {f"p{i}": jnp.asarray(
        rng.standard_normal((base + i, 1 + (i % 3))).astype(np.float32))
        for i in range(n_leaves)}
    lay = ParamLayout.from_tree(tree)
    assert lay.P % LANE == 0
    back = lay.unpack(lay.pack(tree))
    for k in tree:
        assert np.array_equal(np.asarray(tree[k]), np.asarray(back[k]))


# ---------------------------------------------------------------------------
# ring_agg: fused multi-upload chain
# ---------------------------------------------------------------------------
def _chain_inputs(U, P, dtype, seed=0):
    kg, kl = jax.random.split(jax.random.PRNGKey(seed))
    g = jax.random.normal(kg, (P,), jnp.float32)
    locs = jax.random.normal(kl, (U, P)).astype(dtype)
    alphas = jnp.asarray(np.linspace(0.15, 0.85, U), jnp.float32)
    coeffs = jnp.stack([1.0 - alphas, alphas], axis=1)
    return g, locs, coeffs, alphas


def _sequential(g, locs, alphas):
    """U separate mix_update passes — the host/pytree semantics."""
    out = g
    for u in range(locs.shape[0]):
        out = mix_update_donated(out, locs[u].astype(jnp.float32),
                                 alphas[u])
    return out


@pytest.mark.parametrize("U", [1, 2, 7])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ring_agg_ref_matches_sequential_mixes(U, dtype):
    g, locs, coeffs, alphas = _chain_inputs(U, 4 * LANE, dtype)
    fused = agg_ref.ring_agg(g, locs, coeffs)
    seq = _sequential(g, locs, alphas)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(fused), np.asarray(seq),
                               atol=tol, rtol=1e-5)
    assert fused.dtype == jnp.float32


@pytest.mark.parametrize("U", [1, 2, 7])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ring_agg_pallas_interpret_matches_ref(U, dtype):
    g, locs, coeffs, _ = _chain_inputs(U, 4 * LANE, dtype)
    ref_out = agg_ref.ring_agg(g, locs, coeffs)
    pall = agg_ops.ring_agg(g, locs, coeffs, interpret=True)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(pall), np.asarray(ref_out),
                               atol=tol, rtol=1e-5)


@pytest.mark.skipif(jax.default_backend() == "cpu",
                    reason="compiled (non-interpret) Pallas needs TPU/GPU")
@pytest.mark.parametrize("U", [1, 7])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ring_agg_compiled_matches_ref(U, dtype):
    g, locs, coeffs, _ = _chain_inputs(U, 4 * LANE, dtype)
    ref_out = agg_ref.ring_agg(g, locs, coeffs)
    out = agg_ops.ring_agg(g, locs, coeffs, interpret=False)
    tol = 1e-5 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=tol, rtol=1e-5)


def test_ring_agg_u_tiling_matches_single_block():
    """The upload-chunked grid (block_u < U) must agree with one chunk —
    the f32 accumulator lives in the out tile across chunks."""
    from repro.kernels.weighted_agg.kernel import ring_agg_2d
    g, locs, coeffs, _ = _chain_inputs(11, 4 * LANE, jnp.float32)
    rows = g.shape[0] // LANE
    g2 = g.reshape(rows, LANE)
    l2 = locs.reshape(11, rows, LANE)
    one = ring_agg_2d(g2, l2, coeffs, block_u=11, interpret=True)
    chunked = ring_agg_2d(g2, l2, coeffs, block_u=3, interpret=True)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(chunked))


def test_ring_agg_empty_chain_is_identity():
    g = jnp.arange(2 * LANE, dtype=jnp.float32)
    out = agg_ops.ring_agg(g, jnp.zeros((0, 2 * LANE)),
                           jnp.zeros((0, 2), jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(g))


def test_prefix_weights_algebra():
    """ring_agg == w[0]*g + sum_u w[1+u]*locs[u] with the planner's f64
    prefix weights (algebraic identity, to f32 tolerance)."""
    g, locs, coeffs, _ = _chain_inputs(5, 4 * LANE, jnp.float32)
    w = agg_ops.prefix_weights(coeffs)
    lin = w[0] * np.asarray(g, np.float64) + sum(
        w[1 + u] * np.asarray(locs[u], np.float64) for u in range(5))
    fused = agg_ref.ring_agg(g, locs, coeffs)
    np.testing.assert_allclose(np.asarray(fused), lin, atol=1e-5)
    # conservation: for a pure mixing chain the weights sum to 1
    np.testing.assert_allclose(w.sum(), 1.0, atol=1e-12)


# ---------------------------------------------------------------------------
# chain_coeffs: the engines' per-scheme mix expressions, vectorized
# ---------------------------------------------------------------------------
def test_chain_coeffs_mafl_mixing_matches_engine_expr():
    w = jnp.asarray([0.3, 0.9, 1.4], jnp.float32)     # weights can exceed 1
    c, d = chain_coeffs("mafl", "mixing", 0.5, w)
    alpha = np.clip((1.0 - np.float32(0.5)) * np.asarray(w), 0.0, 1.0)
    np.testing.assert_array_equal(np.asarray(d), alpha)
    np.testing.assert_array_equal(np.asarray(c), 1.0 - alpha)


def test_chain_coeffs_literal_and_afl_and_fedasync():
    w = jnp.asarray([0.4, 1.1], jnp.float32)
    c, d = chain_coeffs("mafl", "literal", 0.5, w)
    np.testing.assert_allclose(np.asarray(c), [0.5, 0.5])
    np.testing.assert_allclose(np.asarray(d),
                               0.5 * np.asarray(w), rtol=1e-6)
    c, d = chain_coeffs("afl", "mixing", 0.5, w)
    np.testing.assert_allclose(np.asarray(c) + np.asarray(d), 1.0)
    t = jnp.asarray([5.0, 9.0], jnp.float32)
    dl = jnp.asarray([1.0, 8.5], jnp.float32)
    c, d = chain_coeffs("fedasync", "mixing", 0.5, w, t=t, dl_t=dl,
                        fedasync_mix=0.6)
    stale = np.maximum(np.asarray(t) - np.asarray(dl), 0.0)
    np.testing.assert_allclose(np.asarray(d),
                               0.6 * (stale + 1.0) ** -0.5, rtol=1e-6)
    with pytest.raises(ValueError):
        chain_coeffs("fedbuff", "mixing", 0.5, w)


# ---------------------------------------------------------------------------
# weighted_agg_leaf tail handling (satellite)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [LANE + 1, 2 * LANE - 1, 513, 1000])
def test_weighted_agg_leaf_padded_tail(n):
    """Ragged leaves now run the tiled kernel over a zero-padded final
    row (no jnp-oracle remainder, no whole-leaf concatenate); parity with
    the oracle must hold across the pad boundary."""
    g = jax.random.normal(jax.random.PRNGKey(0), (n,))
    l = jax.random.normal(jax.random.PRNGKey(1), (n,))
    out = agg_ops.weighted_agg_leaf(g, l, 0.45, 1.07)
    expect = agg_ref.weighted_agg(g, l, 0.45, 1.07)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-6)
    assert out.shape == g.shape


def test_weighted_agg_leaf_small_fallthrough():
    g = jnp.ones(LANE - 1)
    l = jnp.full(LANE - 1, 3.0)
    out = agg_ops.weighted_agg_leaf(g, l, 0.5, 1.0)
    np.testing.assert_allclose(np.asarray(out), 2.0, atol=1e-6)

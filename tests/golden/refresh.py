"""Regenerate the golden-trace fixtures (tests/golden/*.json).

    PYTHONPATH=src python tests/golden/refresh.py

Each fixture pins one scenario world: the exact consumed-arrival sequence
((round, vehicle, rsu) + f64 host timestamps from the serial engine) and a
per-engine sha256 digest of the final model parameters.
``tests/test_golden_traces.py`` asserts every engine still reproduces them
— and that admit-all selection is bitwise identical to no selection — so
engine edits cannot silently change the simulation semantics.

Digests are bitwise and therefore pinned to the (jax, numpy) versions AND
the codegen environment recorded in the fixture (XLA:CPU's f32 codegen is
hardware-dependent — ``repro.core.codegen``); the tests degrade the digest
check to an accuracy check when either differs (event traces stay strict —
they are pure host f64 and version-stable).
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpointing.checkpoint import tree_digest  # noqa: E402
from repro.core.codegen import codegen_fingerprint  # noqa: E402
from repro.core.scenarios import run_scenario  # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))

# cheap-but-real worlds: full CNN training, shortened rounds
FIXTURES = {
    "paper-k10": {
        "overrides": {"rounds": 12, "l_iters": 2},
        "eval_every": 12,
        "engines": ["serial", "batched", "jit"],
    },
    "highway-k40-handover": {
        "overrides": {"rounds": 12, "l_iters": 1},
        "eval_every": 6,
        "engines": ["serial", "corridor"],
    },
    "corridor-quick-r2-k8": {
        "overrides": {"rounds": 8},
        "eval_every": 4,
        "engines": ["serial", "corridor"],
    },
}


def build_fixture(name: str, cfg: dict) -> dict:
    out = {
        "scenario": name,
        "overrides": cfg["overrides"],
        "eval_every": cfg["eval_every"],
        "seed": 0,
        "versions": {"jax": jax.__version__, "numpy": np.__version__},
        "codegen": codegen_fingerprint(),
        "engines": {},
    }
    for engine in cfg["engines"]:
        print(f"  {name} / {engine} ...")
        r = run_scenario(name, engine=engine, seed=0,
                         eval_every=cfg["eval_every"], **cfg["overrides"])
        if engine == cfg["engines"][0]:
            # the canonical f64 host trace (serial engine first)
            out["trace"] = {
                "round": [rec.round for rec in r.rounds],
                "vehicle": [rec.vehicle for rec in r.rounds],
                "rsu": [rec.rsu for rec in r.rounds],
                "time": [rec.time for rec in r.rounds],
            }
        out["engines"][engine] = {
            "digest": tree_digest(r.final_params),
            "final_accuracy": float(r.final_accuracy()),
        }
    return out


def main():
    for name, cfg in FIXTURES.items():
        fx = build_fixture(name, cfg)
        path = os.path.join(HERE, f"{name}.json")
        with open(path, "w") as f:
            json.dump(fx, f, indent=1)
            f.write("\n")
        print(f"wrote {path}")


if __name__ == "__main__":
    main()

"""Multi-world vmap sweep conformance (DESIGN.md §15).

The contract under test: every world slice w of an ``engine="vmap"``
batch is BITWISE the run ``engine="jit"`` would produce for that world
solo — same final-parameter digest, same accuracy/loss history, same
event structure (pop order, rounds, vehicles).  That holds because the
sweep program splits its scan at the union of all worlds' boundaries
(scan splitting is carry-transparent), keeps batch-uniform channel
scalars as trace-time constants (varied ones become traced ``[W]``
inputs), and trains timeline-groups through the exact solo wave-train
closure (nested vmap for multi-world groups).

One carve-out, stated rather than hidden: the *reported delay floats*
in the event trace (upload/train delay, weight) are pinned to f32-ulp
closeness, not bit equality — the union segmentation compiles the scan
body in a different fusion context than the solo program, and XLA:CPU's
context-dependent FMA contraction can move those reported expressions
by one ulp (observed: 2e-10 relative on ``upload_delay``) while the
aggregation path itself stays bit-identical (the digest assertions
below are exact and would fail otherwise).

Also pinned here: the padded plan-table stacking contract (PLN003), the
SweepSpec grid order, and every unsupported-configuration gate.
"""
import dataclasses

import numpy as np
import pytest

from repro.checkpointing.checkpoint import tree_digest
from repro.core.scenarios import (SweepSpec, get_scenario, run_scenario,
                                  run_sweep)
from repro.core.sweep import stack_plan_tables


def _assert_world_matches_solo(vm_r, solo_r, label=""):
    assert tree_digest(vm_r.final_params) == tree_digest(
        solo_r.final_params), f"final params diverge {label}"
    # discrete event structure: exact
    assert [(rec.round, rec.vehicle) for rec in vm_r.rounds] == \
        [(rec.round, rec.vehicle) for rec in solo_r.rounds], \
        f"pop order diverges {label}"
    # reported delay floats: f32-ulp (see module docstring)
    for fld in ("time", "upload_delay", "train_delay", "weight"):
        a = np.array([getattr(rec, fld) for rec in vm_r.rounds])
        b = np.array([getattr(rec, fld) for rec in solo_r.rounds])
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=0,
                                   err_msg=f"{fld} diverges {label}")
    assert vm_r.acc_history == solo_r.acc_history, f"acc diverges {label}"
    assert vm_r.loss_history == solo_r.loss_history


# ---------------------------------------------------------------------------
# bitwise conformance
# ---------------------------------------------------------------------------
def test_w1_batch_is_bitwise_the_solo_jit_run():
    """A W=1 sweep degenerates to the solo program: same bits out."""
    solo = run_scenario("quick-k5", engine="jit", seed=1, eval_every=5,
                        rounds=10)
    vm = run_scenario("quick-k5", engine="vmap", seed=1, eval_every=5,
                      rounds=10)
    _assert_world_matches_solo(vm, solo, "(W=1 quick-k5)")
    assert vm.report.engine == "vmap"
    assert vm.report.channels["n_worlds"] == 1


def test_heterogeneous_beta_seed_batch_bitwise():
    """W=4 (2 betas x 2 seeds) — every slice matches its solo run, and
    same-seed worlds share a timeline group (beta never splits one)."""
    spec = SweepSpec(
        scenario="quick-k5", seeds=(0, 1),
        variants=tuple((("channel_overrides", (("beta", b),)),)
                       for b in (0.3, 0.7)),
        overrides=(("rounds", 8),), eval_every=4)
    vm = run_sweep(spec)
    solo = run_sweep(spec, engine="jit")
    assert len(vm) == len(solo) == 4
    for w, (v, s) in enumerate(zip(vm, solo)):
        _assert_world_matches_solo(v, s, f"(world {w})")
        assert v.report.channels["world_index"] == w
        assert v.report.channels["n_worlds"] == 4
    # worlds 0/2 are seed 0 at beta 0.3/0.7: identical timelines, one group
    groups = [r.report.channels["group"] for r in vm]
    assert groups[0] == groups[2] and groups[1] == groups[3]
    assert groups[0] != groups[1]


def test_selection_heterogeneous_batch_bitwise():
    """Admit-all and weighted-topk worlds coexist in one batch."""
    base = dataclasses.replace(get_scenario("quick-k5"), rounds=8)
    sel = dataclasses.replace(base, selection="weighted-topk",
                              selection_k=3, resel_every=4)
    spec = SweepSpec(scenario=base, seeds=(0,),
                     variants=((), (("selection", "weighted-topk"),
                                    ("selection_k", 3),
                                    ("resel_every", 4))),
                     eval_every=4)
    vm = run_sweep(spec)
    _assert_world_matches_solo(
        vm[0], run_scenario(base, engine="jit", seed=0, eval_every=4),
        "(admit-all)")
    _assert_world_matches_solo(
        vm[1], run_scenario(sel, engine="jit", seed=0, eval_every=4),
        "(weighted-topk)")
    # the selected world really ran under the k=3 admission cap
    assert len({r.vehicle for r in vm[1].rounds}) <= 3


@pytest.mark.slow
def test_paper_k10_grid_bitwise_vs_serial():
    """ISSUE acceptance pin: the Fig. 5-shaped grid on paper-k10."""
    spec = SweepSpec(
        scenario="paper-k10", seeds=(0, 1),
        variants=tuple((("channel_overrides", (("beta", b),)),)
                       for b in (0.1, 0.9)),
        overrides=(("rounds", 8), ("l_iters", 2)), eval_every=4)
    vm = run_sweep(spec)
    solo = run_sweep(spec, engine="jit")
    for w, (v, s) in enumerate(zip(vm, solo)):
        _assert_world_matches_solo(v, s, f"(paper-k10 world {w})")


@pytest.mark.slow
def test_fleet_k100_bitwise_vs_serial():
    spec = SweepSpec(scenario="fleet-k100", seeds=(0, 1),
                     overrides=(("rounds", 10), ("l_iters", 1)),
                     eval_every=5)
    vm = run_sweep(spec)
    solo = run_sweep(spec, engine="jit")
    for w, (v, s) in enumerate(zip(vm, solo)):
        _assert_world_matches_solo(v, s, f"(fleet-k100 world {w})")


# ---------------------------------------------------------------------------
# SweepSpec grid + plan-table stacking
# ---------------------------------------------------------------------------
def test_sweepspec_world_order_is_variant_major():
    spec = SweepSpec(scenario="quick-k5", seeds=(0, 1, 2),
                     variants=tuple((("channel_overrides", (("beta", b),)),)
                                    for b in (0.2, 0.8)),
                     overrides=(("rounds", 6),))
    worlds = spec.worlds()
    assert len(worlds) == 6
    assert [seed for _sc, seed in worlds] == [0, 1, 2, 0, 1, 2]
    betas = [dict(sc.channel_overrides)["beta"] for sc, _ in worlds]
    assert betas == [0.2, 0.2, 0.2, 0.8, 0.8, 0.8]
    assert all(sc.rounds == 6 for sc, _ in worlds)


def test_stack_plan_tables_accepts_uniform_rejects_ragged():
    a = {"veh": np.zeros((8,), np.int32),
         "times": np.ones((8,), np.float32)}
    b = {k: v.copy() for k, v in a.items()}
    out = stack_plan_tables([a, b])
    assert out["veh"].shape == (2, 8)
    # ragged shapes must be rejected with the PLN003 pointer, never
    # silently broadcast
    bad = dict(b, times=np.ones((9,), np.float32))
    with pytest.raises(ValueError, match="PLN003"):
        stack_plan_tables([a, bad])
    with pytest.raises(ValueError, match="PLN003"):
        stack_plan_tables([a, {"veh": a["veh"]}])
    with pytest.raises(ValueError):
        stack_plan_tables([])


# ---------------------------------------------------------------------------
# unsupported-configuration gates (clear errors, never silent fallback)
# ---------------------------------------------------------------------------
def test_run_sweep_rejects_unknown_engine():
    with pytest.raises(ValueError, match="vmap.*jit|jit.*vmap"):
        run_sweep(SweepSpec(scenario="quick-k5"), engine="batched")


def test_vmap_rejects_nonuniform_rounds():
    base = get_scenario("quick-k5")
    spec = SweepSpec(scenario=base, seeds=(0,),
                     variants=((("rounds", 6),), (("rounds", 8),)))
    with pytest.raises(ValueError, match="uniform rounds"):
        run_sweep(spec)


def test_vmap_rejects_corridor_and_fedbuff_and_varied_alpha():
    with pytest.raises(ValueError, match="multi-RSU"):
        run_scenario("corridor-quick-r2-k8", engine="vmap", seed=0)
    with pytest.raises(ValueError, match="fedbuff"):
        run_sweep(SweepSpec(scenario="quick-k5",
                            overrides=(("scheme", "fedbuff"),)))
    spec = SweepSpec(
        scenario="quick-k5", seeds=(0,),
        variants=tuple((("channel_overrides", (("alpha", a),)),)
                       for a in (2.0, 3.0)))
    with pytest.raises(ValueError, match="alpha"):
        run_sweep(spec)


def test_vmap_rejects_metrics_kernel_and_pytree():
    with pytest.raises(ValueError, match="telemetry|metrics"):
        run_scenario("quick-k5", engine="vmap", seed=0, metrics="on")
    with pytest.raises(ValueError, match="use_kernel"):
        run_scenario("quick-k5", engine="vmap", seed=0, use_kernel=True)
    with pytest.raises(ValueError, match="flat-only"):
        run_scenario("quick-k5", engine="vmap", seed=0, flat=False)

"""Model-zoo unit tests: attention variants, MoE, Mamba, RWKV6, assembly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import transformer as T
from repro.models.modules import apply_rope, chunked_scan


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def test_rope_preserves_norm_and_relativity():
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (1, 6, 2, 32))
    pos = jnp.arange(6)
    y = apply_rope(x, pos, 1e4)
    np.testing.assert_allclose(np.linalg.norm(x, axis=-1),
                               np.linalg.norm(y, axis=-1), rtol=1e-5)
    # relative property: <q_i, k_j> depends only on i-j
    q = jax.random.normal(k, (1, 1, 1, 32))
    kk = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    def dot_at(i, j):
        qi = apply_rope(jnp.broadcast_to(q, (1, 1, 1, 32)), jnp.array([i]), 1e4)
        kj = apply_rope(jnp.broadcast_to(kk, (1, 1, 1, 32)), jnp.array([j]), 1e4)
        return float(jnp.sum(qi * kj))
    assert dot_at(5, 3) == pytest.approx(dot_at(7, 5), rel=1e-4)


@pytest.mark.slow
def test_blocked_sdpa_matches_dense():
    """The q-blocked flash-style path must equal the dense path."""
    cfg = get_config("smollm-360m").reduced()
    key = jax.random.PRNGKey(0)
    p = attn.init_attention(cfg, key, jnp.float32)
    x = jax.random.normal(key, (2, 2048, cfg.d_model)) * 0.1
    pos = jnp.arange(2048, dtype=jnp.int32)
    y_blocked, _ = attn.attention_fwd(cfg, p, x, pos)      # S=2048 -> blocked
    old = attn.BLOCKED_SDPA_THRESHOLD
    attn.BLOCKED_SDPA_THRESHOLD = 10 ** 9                  # force dense
    try:
        y_dense, _ = attn.attention_fwd(cfg, p, x, pos)
    finally:
        attn.BLOCKED_SDPA_THRESHOLD = old
    np.testing.assert_allclose(y_blocked, y_dense, atol=2e-4)


def test_swa_masks_out_of_window():
    cfg = get_config("mistral-nemo-12b").reduced().variant(sliding_window=4)
    key = jax.random.PRNGKey(0)
    p = attn.init_attention(cfg, key, jnp.float32)
    x = jax.random.normal(key, (1, 12, cfg.d_model)) * 0.1
    pos = jnp.arange(12, dtype=jnp.int32)
    y1, _ = attn.attention_fwd(cfg, p, x, pos, "swa", 4)
    # perturbing a token >= window away must not change the output at t
    x2 = x.at[:, 0].add(10.0)
    y2, _ = attn.attention_fwd(cfg, p, x2, pos, "swa", 4)
    np.testing.assert_allclose(y1[:, 8:], y2[:, 8:], atol=1e-5)
    assert not np.allclose(y1[:, 0], y2[:, 0])


@pytest.mark.slow
def test_mla_absorbed_decode_matches_naive():
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    key = jax.random.PRNGKey(0)
    p = attn.init_mla(cfg, key, jnp.float32)
    cache = attn.init_mla_cache(cfg, 2, 16, jnp.float32)
    x = jax.random.normal(key, (2, 1, cfg.d_model)) * 0.1
    y_naive, c1 = attn.mla_decode(cfg, p, x, cache, jnp.int32(3))
    cfg2 = cfg.variant(mla_absorb=True)
    y_abs, c2 = attn.mla_decode(cfg2, p, x, cache, jnp.int32(3))
    np.testing.assert_allclose(y_naive, y_abs, atol=1e-4)
    np.testing.assert_allclose(c1["c_kv"], c2["c_kv"], atol=1e-6)


@pytest.mark.slow
def test_chunk_attention_blocks_cross_chunk():
    cfg = get_config("llama4-scout-17b-a16e").reduced().variant(attn_chunk=4)
    key = jax.random.PRNGKey(0)
    p = attn.init_attention(cfg, key, jnp.float32)
    x = jax.random.normal(key, (1, 8, cfg.d_model)) * 0.1
    pos = jnp.arange(8, dtype=jnp.int32)
    y1, _ = attn.attention_fwd(cfg, p, x, pos, "chunk", 4)
    x2 = x.at[:, 1].add(10.0)                  # chunk 0
    y2, _ = attn.attention_fwd(cfg, p, x2, pos, "chunk", 4)
    np.testing.assert_allclose(y1[:, 4:], y2[:, 4:], atol=1e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def test_moe_router_mass_conservation():
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    p = moe_mod.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    gates, idx, aux = moe_mod._router(cfg, p, x)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
    assert (np.asarray(idx) < cfg.n_routed_experts).all()
    assert float(aux) >= 0


@pytest.mark.slow
def test_moe_dispatch_equals_dense_at_high_capacity():
    """With no drops, sort-dispatch == dense masked combine."""
    cfg = get_config("deepseek-v2-lite-16b").reduced().variant(
        capacity_factor=16.0, n_shared_experts=0)
    p = moe_mod.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.3
    y_dispatch, _ = moe_mod.moe_fwd(cfg, p, x)
    y_dense = jnp.concatenate(
        [moe_mod.moe_decode(cfg, p, x[:, i:i + 1])[0] for i in range(8)],
        axis=1)
    np.testing.assert_allclose(y_dispatch, y_dense, atol=2e-4)


@pytest.mark.slow
def test_moe_capacity_drops_tokens():
    cfg = get_config("deepseek-v2-lite-16b").reduced().variant(
        capacity_factor=0.1, n_shared_experts=0)
    p = moe_mod.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
    y, _ = moe_mod.moe_fwd(cfg, p, x)
    assert jnp.isfinite(y).all()
    # with tiny capacity most tokens must be dropped (zero output rows)
    row_norms = jnp.linalg.norm(y[0], axis=-1)
    assert (row_norms < 1e-6).sum() >= 4


# ---------------------------------------------------------------------------
# SSM blocks
# ---------------------------------------------------------------------------
def test_chunked_scan_equals_plain_scan():
    def body(c, x):
        c = 0.9 * c + x
        return c, c * 2.0
    xs = jax.random.normal(jax.random.PRNGKey(0), (128, 3))
    c1, y1 = jax.lax.scan(body, jnp.zeros(3), xs)
    c2, y2 = chunked_scan(body, jnp.zeros(3), xs, 16)
    np.testing.assert_allclose(c1, c2, rtol=1e-6)
    np.testing.assert_allclose(y1, y2, rtol=1e-6)


@pytest.mark.slow
def test_mamba_fwd_decode_parity():
    cfg = get_config("jamba-v0.1-52b").reduced()
    p = mamba_mod.init_mamba(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model)) * 0.3
    y_full, cache_full = mamba_mod.mamba_fwd(cfg, p, x)
    cache = mamba_mod.init_mamba_cache(cfg, 2, jnp.float32)
    ys = []
    for t in range(6):
        y_t, cache = mamba_mod.mamba_decode(cfg, p, x[:, t:t + 1], cache)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_full, y_step, atol=1e-4)
    np.testing.assert_allclose(cache_full["ssm"], cache["ssm"], atol=1e-4)


@pytest.mark.slow
def test_rwkv_fwd_decode_parity():
    cfg = get_config("rwkv6-1.6b").reduced()
    p = rwkv_mod.init_time_mix(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, cfg.d_model)) * 0.3
    y_full, cache_full = rwkv_mod.time_mix_fwd(cfg, p, x)
    cache = {"wkv": jnp.zeros_like(cache_full["wkv"]),
             "shift": jnp.zeros((2, cfg.d_model))}
    ys = []
    for t in range(5):
        y_t, cache = rwkv_mod.time_mix_decode(cfg, p, x[:, t:t + 1], cache)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_full, y_step, atol=1e-4)
    np.testing.assert_allclose(cache_full["wkv"], cache["wkv"], atol=1e-4)


def test_rwkv_decay_in_unit_interval():
    cfg = get_config("rwkv6-1.6b").reduced()
    p = rwkv_mod.init_time_mix(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model))
    _, _, _, w, _ = rwkv_mod._tm_projections(cfg, p, x, jnp.zeros_like(x))
    assert (np.asarray(w) > 0).all() and (np.asarray(w) < 1).all()


# ---------------------------------------------------------------------------
# assembly
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("arch", ["smollm-360m", "rwkv6-1.6b",
                                  "jamba-v0.1-52b", "deepseek-v2-lite-16b"])
def test_prefill_decode_match_forward(arch):
    cfg = get_config(arch).reduced().variant(capacity_factor=16.0)
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 9), 0, cfg.vocab_size)
    full_logits, _ = T.forward(cfg, params, toks)
    _, cache = T.prefill(cfg, params, toks[:, :8])
    cache = T.grow_cache(cfg, cache, 2, 16)
    dl, _ = T.decode_step(cfg, params, toks[:, 8:9], cache, jnp.int32(8))
    np.testing.assert_allclose(dl[:, 0], full_logits[:, 8], atol=2e-3)


def test_param_count_sane():
    n = T.param_count(get_config("smollm-360m"))
    assert 3.4e8 < n < 4.1e8
    n405 = T.param_count(get_config("llama3-405b"))
    assert 3.8e11 < n405 < 4.3e11
    # active < total for MoE
    ds = get_config("deepseek-v2-lite-16b")
    assert T.param_count(ds, active_only=True) < T.param_count(ds)

"""End-to-end behaviour tests: the full MAFL simulation (Algorithm 1) and
the transformer-FL driver — deliverable (c) integration layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel.params import ChannelParams
from repro.core import run_simulation
from repro.data import partition_vehicles, synth_mnist
from repro.models.cnn import accuracy, cnn_forward, init_cnn, sgd_train_step


@pytest.fixture(scope="module")
def small_world():
    tr_i, tr_l, te_i, te_l = synth_mnist(n_train=1500, n_test=300, seed=0,
                                         noise=0.35)
    p = ChannelParams()
    veh = partition_vehicles(tr_i, tr_l, p, seed=0, scale=0.004)
    return veh, te_i, te_l, p


@pytest.mark.slow
def test_cnn_learns_standalone():
    tr_i, tr_l, te_i, te_l = synth_mnist(n_train=800, n_test=200, seed=1,
                                         noise=0.3)
    params = init_cnn(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    for _ in range(120):
        sel = rng.choice(len(tr_l), 128)
        params, loss = sgd_train_step(params, jnp.asarray(tr_i[sel]),
                                      jnp.asarray(tr_l[sel]), 0.05)
    acc = float(accuracy(cnn_forward(params, jnp.asarray(te_i)),
                         jnp.asarray(te_l)))
    assert acc > 0.55


@pytest.mark.parametrize("scheme", [
    "mafl", "afl",
    pytest.param("fedasync", marks=pytest.mark.slow),
    pytest.param("fedbuff", marks=pytest.mark.slow)])
def test_simulation_runs_all_schemes(small_world, scheme):
    veh, te_i, te_l, p = small_world
    r = run_simulation(veh, te_i, te_l, scheme=scheme, rounds=6, l_iters=2,
                       lr=0.05, eval_every=3, seed=0)
    assert len(r.rounds) == 6
    assert all(np.isfinite(a) for _, a in r.acc_history)
    # event ordering: upload times non-decreasing
    times = [rec.time for rec in r.rounds]
    assert times == sorted(times)


def test_mafl_round_records_have_paper_weights(small_world):
    veh, te_i, te_l, p = small_world
    r = run_simulation(veh, te_i, te_l, scheme="mafl", rounds=8, l_iters=1,
                       eval_every=8, seed=0)
    for rec in r.rounds:
        expect = (p.gamma ** (rec.upload_delay - 1.0) *
                  p.zeta ** (rec.train_delay - 1.0))
        assert rec.weight == pytest.approx(expect, rel=1e-6)
    # fast vehicles (small i) carry less data and must appear more often
    counts = np.bincount([rec.vehicle for rec in r.rounds], minlength=10)
    assert counts[0] >= counts[-1]


@pytest.mark.slow
def test_mafl_improves_over_init(small_world):
    veh, te_i, te_l, p = small_world
    r = run_simulation(veh, te_i, te_l, scheme="mafl", rounds=20,
                       l_iters=8, lr=0.05, eval_every=20, seed=0)
    assert r.final_accuracy() > 0.18          # well above 10% chance


def test_interpretation_literal_vs_mixing_differ(small_world):
    veh, te_i, te_l, p = small_world
    r1 = run_simulation(veh, te_i, te_l, scheme="mafl", rounds=4, l_iters=1,
                        eval_every=4, seed=0, interpretation="mixing")
    r2 = run_simulation(veh, te_i, te_l, scheme="mafl", rounds=4, l_iters=1,
                        eval_every=4, seed=0, interpretation="literal")
    a = jax.tree_util.tree_leaves(r1.final_params)
    b = jax.tree_util.tree_leaves(r2.final_params)
    assert any(not np.allclose(x, y) for x, y in zip(a, b))


def test_kernel_aggregation_path_in_simulation(small_world):
    """use_kernel=True must give the same global model (within fp tolerance).
    """
    veh, te_i, te_l, p = small_world
    r1 = run_simulation(veh, te_i, te_l, scheme="mafl", rounds=3, l_iters=1,
                        eval_every=3, seed=0, use_kernel=False)
    r2 = run_simulation(veh, te_i, te_l, scheme="mafl", rounds=3, l_iters=1,
                        eval_every=3, seed=0, use_kernel=True)
    for x, y in zip(jax.tree_util.tree_leaves(r1.final_params),
                    jax.tree_util.tree_leaves(r2.final_params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)


@pytest.mark.slow
def test_transformer_fl_driver_one_round():
    from repro.launch.train import main
    params = main(["--arch", "smollm-360m", "--reduced", "--rounds", "2",
                   "--l-iters", "1", "--batch", "2", "--seq-len", "16"])
    assert all(np.isfinite(l).all()
               for l in jax.tree_util.tree_leaves(params))


def test_serve_driver_decodes():
    from repro.launch.serve import main
    toks = main(["--arch", "smollm-360m", "--reduced", "--batch", "2",
                 "--prompt-len", "8", "--gen", "4"])
    assert toks.shape == (2, 4)

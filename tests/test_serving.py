"""Continuous-batching server: slot admission, per-slot positions, drain."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import BatchedServer


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-360m").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_server_drains_requests(served):
    cfg, params = served
    srv = BatchedServer(cfg, params, n_slots=2, max_seq=32)
    reqs = [srv.submit(np.arange(4) + i, max_new=5) for i in range(3)]
    ticks = srv.run_until_drained(max_ticks=100)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 5 for r in reqs)
    assert ticks < 100
    # 3 requests over 2 slots => the third admits after a slot frees
    assert srv.pending() == 0 and srv.active() == 0


@pytest.mark.slow
def test_server_matches_unbatched_decode(served):
    """Slot-pooled decode must equal a dedicated single-sequence decode."""
    cfg, params = served
    prompt = np.arange(6, dtype=np.int32)
    srv = BatchedServer(cfg, params, n_slots=2, max_seq=32)
    r = srv.submit(prompt, max_new=4)
    # occupy the other slot with a different request to prove isolation
    srv.submit(np.arange(3, dtype=np.int32) + 7, max_new=6)
    srv.run_until_drained()

    # reference: plain prefill + sequential greedy decode
    import jax.numpy as jnp
    logits, cache = T.prefill(cfg, params, jnp.asarray(prompt[None]))
    cache = T.grow_cache(cfg, cache, 1, 32)
    tok = int(jnp.argmax(logits[0, -1]))
    expect = [tok]
    pos = len(prompt)
    for _ in range(3):
        lg, cache = T.decode_step(cfg, params,
                                  jnp.asarray([[tok]], jnp.int32), cache,
                                  jnp.int32(pos))
        tok = int(jnp.argmax(lg[0, 0]))
        expect.append(tok)
        pos += 1
    assert r.out == expect


def test_server_eos_frees_slot(served):
    cfg, params = served
    srv = BatchedServer(cfg, params, n_slots=1, max_seq=32, eos_id=None)
    r1 = srv.submit(np.arange(4, dtype=np.int32), max_new=3)
    r2 = srv.submit(np.arange(4, dtype=np.int32) + 2, max_new=3)
    srv.run_until_drained()
    assert r1.done and r2.done

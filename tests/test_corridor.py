"""Corridor subsystem unit tests (DESIGN.md §10): the vectorized
CorridorMobility geometry, EMA/FedAvg cloud-tier reconciliation, the
engine's dispatch/validation surface, and the RSU-sharded mesh path
(subprocess with forced host devices)."""
import dataclasses
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel import ChannelParams, CorridorMobility
from repro.core.hierarchical import ema_toward, reconcile_models
from repro.core.scenarios import get_scenario, run_scenario


@pytest.fixture
def p():
    return dataclasses.replace(ChannelParams(), K=6)


# ---------------------------------------------------------------------------
# CorridorMobility — the promoted, vectorized geometry
# ---------------------------------------------------------------------------
def test_corridor_vectorized_over_vehicles_and_times(p):
    c = CorridorMobility(p, n_rsus=3)
    # whole-fleet broadcast forms agree with per-vehicle scalar calls
    t = 7.5
    xs = c.positions(t)
    cells = c.serving_cells(t)
    ds = c.distance(np.arange(p.K), t)
    assert xs.shape == cells.shape == ds.shape == (p.K,)
    for i in range(p.K):
        assert xs[i] == c.x(i, t)
        assert cells[i] == c.serving_rsu(i, t)
        assert ds[i] == c.distance(i, t)
    # time-vectorized: one vehicle across an array of times
    ts = np.linspace(0, 100, 17)
    assert c.x(0, ts).shape == ts.shape
    assert c.serving_rsu(0, ts).shape == ts.shape


def test_corridor_segment_geometry(p):
    c = CorridorMobility(p, n_rsus=4)
    assert c.span == 8 * p.coverage and len(c.centers) == 4
    # a vehicle at segment j's center is served by j at overhead distance
    for j in range(4):
        t = (c.centers[j] - c.x0[0]) / p.v
        assert c.serving_rsu(0, t) == j
        assert c.distance(0, t) == pytest.approx(
            np.sqrt(p.d_y ** 2 + p.H ** 2))
    # wrap-around re-entry keeps positions inside the corridor forever
    assert np.all(np.abs(c.x(np.arange(p.K), 1e6)) <= c.span / 2)


def test_corridor_boundary_crossing_is_the_handover_instant(p):
    c = CorridorMobility(p, n_rsus=3)
    t0 = 3.0
    tc = c.next_boundary_crossing(np.arange(p.K), t0)
    assert np.all(tc > t0)
    eps = 1e-6
    before = c.serving_rsu(np.arange(p.K), tc - eps)
    after = c.serving_rsu(np.arange(p.K), tc + eps)
    # crossing a segment edge changes the serving cell (modulo corridor
    # re-entry, which also lands in a different cell for n_rsus > 1)
    assert np.all(before != after)


def test_corridor_entry_profiles(p):
    uni = CorridorMobility(p, n_rsus=4)
    rush = CorridorMobility(p, n_rsus=4, entry="rush")
    # uniform: initial cells cover the corridor; rush: everyone starts in
    # the westmost segment
    assert len(set(uni.serving_cells(0.0).tolist())) > 1
    assert set(rush.serving_cells(0.0).tolist()) == {0}
    with pytest.raises(ValueError, match="entry profile"):
        CorridorMobility(p, n_rsus=4, entry="gridlock")


def test_corridor_alias_still_importable():
    # the ad-hoc helper's old home keeps working
    from repro.core.scenarios import _Corridor
    assert _Corridor is CorridorMobility


# ---------------------------------------------------------------------------
# cloud tier: EMA / FedAvg reconciliation
# ---------------------------------------------------------------------------
def test_reconcile_models_ema_mode():
    models = [{"w": jnp.full((256,), float(v))} for v in (1.0, 3.0)]
    mean = reconcile_models(models)
    np.testing.assert_allclose(np.asarray(mean["w"]), 2.0)
    stepped = [ema_toward(m, mean, 0.5) for m in models]
    np.testing.assert_allclose(np.asarray(stepped[0]["w"]), 1.5)
    np.testing.assert_allclose(np.asarray(stepped[1]["w"]), 2.5)
    # tau=1 EMA == FedAvg assignment
    np.testing.assert_allclose(
        np.asarray(ema_toward(models[0], mean, 1.0)["w"]), 2.0)
    # kernel-routed mix agrees with the jnp path
    k = ema_toward(models[0], mean, 0.5, use_kernel=True)
    np.testing.assert_allclose(np.asarray(k["w"]),
                               np.asarray(stepped[0]["w"]), atol=1e-6)


# ---------------------------------------------------------------------------
# engine dispatch and validation (the silently-substituting bug is gone)
# ---------------------------------------------------------------------------
def test_single_rsu_scenario_rejects_corridor_engine():
    with pytest.raises(ValueError, match="multi-RSU"):
        run_scenario("quick-k5", engine="corridor", rounds=2)


def test_corridor_scenario_rejects_single_rsu_engines():
    for eng in ("batched", "jit", "unbatched"):
        with pytest.raises(ValueError, match="cannot run multi-RSU"):
            run_scenario("corridor-quick-r2-k8", engine=eng, rounds=2)
    with pytest.raises(ValueError, match="unknown engine"):
        run_scenario("quick-k5", engine="warp", rounds=2)


def test_corridor_engine_rejects_fedbuff():
    with pytest.raises(ValueError, match="fedbuff"):
        run_scenario("corridor-quick-r2-k8", scheme="fedbuff", rounds=2)


def test_corridor_engine_rejects_unknown_reconcile_mode():
    with pytest.raises(ValueError, match="reconcile_mode"):
        run_scenario("corridor-quick-r2-k8", reconcile_mode="psum",
                     rounds=2)


def test_serial_reference_rejects_corridor_only_kwargs():
    with pytest.raises(ValueError, match="require engine='corridor'"):
        run_scenario("corridor-quick-r2-k8", engine="serial",
                     record_cohorts=True, rounds=2)


def test_rsu_mesh_must_tile_the_corridor():
    from types import SimpleNamespace

    from repro.corridor.engine import _rsu_shards
    assert _rsu_shards(None, 8) == 1
    assert _rsu_shards(SimpleNamespace(shape={"data": 4}), 8) == 1
    assert _rsu_shards(SimpleNamespace(shape={"rsu": 4}), 8) == 4
    with pytest.raises(ValueError, match="divisible"):
        _rsu_shards(SimpleNamespace(shape={"rsu": 3}), 8)


# ---------------------------------------------------------------------------
# corridor engine surface: records, extras, cohort snapshots
# ---------------------------------------------------------------------------
def test_corridor_engine_records_and_extras():
    r = run_scenario("corridor-quick-r2-k8", rounds=6, eval_every=3,
                     l_iters=1, record_cohorts=True)
    assert r.scheme == "mafl+corridor"
    assert len(r.rounds) == 6
    times = [rec.time for rec in r.rounds]
    assert times == sorted(times)
    sc = get_scenario("corridor-quick-r2-k8")
    # per-RSU round numbering: each RSU's records count its own arrivals
    counters = {}
    for rec in r.rounds:
        assert 0 <= rec.rsu < sc.n_rsus
        counters[rec.rsu] = counters.get(rec.rsu, 0) + 1
        assert rec.round == counters[rec.rsu]
    assert list(r.extras["up_rsu"]) == [rec.rsu for rec in r.rounds]
    assert r.extras["eval_rounds"] == [3, 6]
    # cohort snapshots: one [R, ...] stack per eval round
    snaps = r.extras["cohort_snapshots"]
    assert len(snaps) == 2
    leaf = jax.tree_util.tree_leaves(snaps[0])[0]
    assert leaf.shape[0] == sc.n_rsus
    # consensus of the final snapshot is the final params
    cons = jax.tree_util.tree_map(
        lambda x: jnp.mean(x.astype(jnp.float32), 0), snaps[-1])
    for a, b in zip(jax.tree_util.tree_leaves(cons),
                    jax.tree_util.tree_leaves(r.final_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_corridor_rush_hour_world_starts_in_cell_zero():
    sc = get_scenario("corridor-rush-hour-r8-k4000")
    assert sc.corridor_entry == "rush" and sc.n_rsus == 8
    p = sc.channel()
    assert p.platoon == 50 and p.K == 4000
    c = CorridorMobility(p, sc.n_rsus, entry=sc.corridor_entry)
    assert set(c.serving_cells(0.0).tolist()) == {0}


def test_corridor_engine_use_kernel_matches_plain():
    r0 = run_scenario("corridor-quick-r2-k8", rounds=5, eval_every=5,
                      l_iters=1)
    r1 = run_scenario("corridor-quick-r2-k8", rounds=5, eval_every=5,
                      l_iters=1, use_kernel=True)
    assert [(x.round, x.vehicle, x.rsu) for x in r0.rounds] == \
           [(x.round, x.vehicle, x.rsu) for x in r1.rounds]
    for a, b in zip(jax.tree_util.tree_leaves(r0.final_params),
                    jax.tree_util.tree_leaves(r1.final_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# RSU-sharded mesh path (forced host devices, isolated subprocess)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_corridor_rsu_sharded_matches_unsharded():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax
        import numpy as np
        from repro.core.scenarios import build_world, get_scenario
        from repro.corridor.engine import run_corridor_simulation
        import dataclasses

        sc = dataclasses.replace(get_scenario("corridor-quick-r2-k8"),
                                 rounds=6, l_iters=1)
        veh, te_i, te_l, p = build_world(sc, seed=0)
        kw = dict(seed=0, eval_every=3)
        r0 = run_corridor_simulation(sc, veh, te_i, te_l, p, **kw)
        mesh = jax.make_mesh((2,), ("rsu",))
        r1 = run_corridor_simulation(sc, veh, te_i, te_l, p, mesh=mesh,
                                     **kw)
        assert ([(x.round, x.vehicle, x.rsu) for x in r0.rounds]
                == [(x.round, x.vehicle, x.rsu) for x in r1.rounds])
        for a, b in zip(jax.tree_util.tree_leaves(r0.final_params),
                        jax.tree_util.tree_leaves(r1.final_params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
        print("CORRIDOR_MESH_OK")
    """)
    from test_hierarchical import SUBPROC_ENV
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600, env=SUBPROC_ENV)
    assert "CORRIDOR_MESH_OK" in res.stdout, res.stderr[-3000:]

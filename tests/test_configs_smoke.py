"""Deliverable (f): per-architecture smoke tests.

Every assigned arch instantiates its REDUCED variant (2 layers, d_model<=256,
<=4 experts) and runs one forward + one train step + one decode step on CPU,
asserting output shapes and finiteness.  The FULL configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, legal_shapes, list_archs
from repro.launch.steps import make_train_step
from repro.models import transformer as T

ARCHS = list_archs()

# the big MoE/hybrid archs pay tens of seconds of CPU compile per step —
# their full smoke runs ride the slow lane; the fast lane keeps a
# representative cross-section (dense, GQA, vision, SSM-free)
HEAVY_ARCHS = {"deepseek-v2-lite-16b", "jamba-v0.1-52b",
               "llama4-scout-17b-a16e", "rwkv6-1.6b", "musicgen-large",
               "internvl2-2b"}
ARCHS_MARKED = [pytest.param(a, marks=pytest.mark.slow)
                if a in HEAVY_ARCHS else a for a in ARCHS]


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10
    assert set(ARCHS) == {
        "mistral-nemo-12b", "deepseek-v2-lite-16b", "llama4-scout-17b-a16e",
        "llama3-405b", "jamba-v0.1-52b", "musicgen-large", "rwkv6-1.6b",
        "internvl2-2b", "qwen1.5-4b", "smollm-360m"}


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_limits(arch):
    r = get_config(arch).reduced()
    assert r.d_model <= 512
    assert r.n_layers - r.first_k_dense <= 2 * max(r.scan_period, 1)
    if r.n_routed_experts:
        assert r.n_routed_experts <= 4


@pytest.mark.parametrize("arch", ARCHS_MARKED)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    B, S = 2, 16
    P = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
    batch = {"tokens": jax.random.randint(key, (B, S + 1), 0,
                                          cfg.vocab_size)}
    if P:
        batch["patch_embeds"] = jax.random.normal(
            key, (B, P, cfg.d_model)) * 0.02
    logits, aux = T.forward(cfg, params, batch["tokens"][:, :-1],
                            batch.get("patch_embeds"))
    assert logits.shape == (B, S + P, cfg.vocab_size)
    assert jnp.isfinite(logits).all()

    step = make_train_step(cfg, lr=0.1)
    new_params, metrics = step(params, batch)
    assert jnp.isfinite(metrics["loss"])
    # parameters actually moved
    moved = any(
        not jnp.allclose(a, b) for a, b in
        zip(jax.tree_util.tree_leaves(params),
            jax.tree_util.tree_leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS_MARKED)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    B, MAX = 2, 32
    cache = T.init_cache(cfg, B, MAX)
    token = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, new_cache = T.decode_step(cfg, params, token, cache,
                                      jnp.int32(3))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(new_cache)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_microbatched_train_matches_single(arch):
    """Grad accumulation must be loss-equivalent to the unsplit step."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    B, S = 4, 8
    P = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
    batch = {"tokens": jax.random.randint(key, (B, S + 1), 0,
                                          cfg.vocab_size)}
    if P:
        batch["patch_embeds"] = jax.random.normal(
            key, (B, P, cfg.d_model)) * 0.02
    _, m1 = make_train_step(cfg)(params, batch)
    _, m2 = make_train_step(cfg.variant(microbatches=2))(params, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-3)


def test_long_context_legality():
    legal = {a: "long_500k" in legal_shapes(get_config(a)) for a in ARCHS}
    assert legal["rwkv6-1.6b"] and legal["jamba-v0.1-52b"] \
        and legal["llama4-scout-17b-a16e"]
    assert not legal["llama3-405b"] and not legal["qwen1.5-4b"] \
        and not legal["mistral-nemo-12b"]       # base config (SWA variant is)
    from repro.configs.mistral_nemo_12b import sliding_window_variant
    assert sliding_window_variant().supports_long_context

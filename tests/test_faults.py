"""Fault-injection subsystem (DESIGN.md §16): the faults-off bitwise
no-op + program-cache identity contract on all four engines, exact
f64-replay conformance of every injected decision, property tests over
the stochastic client-state sampler (hypothesis shim), seed determinism,
the FLT001 lint, and the engine scope gates.
"""
import dataclasses

import numpy as np
import pytest

from repro.channel.params import ChannelParams
from repro.checkpointing.checkpoint import tree_digest
from repro.core.scenarios import build_world, get_scenario, run_scenario
from repro.faults import (FaultSpec, check_faults_reconcile, named_profile,
                          replay_corridor_faults, replay_fleet_faults,
                          resolve_faults, scenario_faults)

from tests._hypothesis_compat import given, settings, st

# churn-heavy spec used wherever the tests need faults to actually fire
# on short runs (the named profiles are tuned for long mega-fleet runs)
HEAVY = FaultSpec(p_dropout=0.25, p_blackout=0.15, blackout_mean=20.0,
                  p_partial=0.5, straggler_frac=0.4, straggler_mult=3.0,
                  staleness_cap=6, recheck_every=2)


# ---------------------------------------------------------------------------
# spec resolution and scenario registry
# ---------------------------------------------------------------------------
def test_resolve_faults_collapses_falsy_and_noop():
    for falsy in (None, False, "off", "none", "", FaultSpec(),
                  FaultSpec(straggler_frac=0.5, straggler_mult=1.0)):
        assert resolve_faults(falsy) is None
    assert resolve_faults("flaky") == named_profile("flaky")
    with pytest.raises(KeyError):
        resolve_faults("no-such-profile")
    with pytest.raises(TypeError):
        resolve_faults(42)
    with pytest.raises(ValueError):
        resolve_faults(FaultSpec(p_dropout=1.5))


def test_fault_scenarios_registered():
    for name, profile in (("fleet-k1000-flaky", "flaky"),
                          ("corridor-rush-hour-deadzone-r8-k4000",
                           "deadzone"),
                          ("fleet-k1000-throttled", "throttled")):
        sc = get_scenario(name)
        assert sc.faults == profile
        assert scenario_faults(sc) == named_profile(profile)
    # a fault-free scenario resolves to no fault model
    assert scenario_faults(get_scenario("fleet-k1000")) is None


# ---------------------------------------------------------------------------
# faults-off: bitwise no-op + program-cache identity on all four engines
# ---------------------------------------------------------------------------
def test_faults_off_bitwise_noop_host_engines():
    """serial/batched: faults='off' produces bit-identical models to the
    legacy no-faults call and carries no fault report."""
    sc = get_scenario("quick-k5")
    veh, te_i, te_l, p = build_world(sc, seed=0)
    from repro.core.mafl import run_simulation
    kw = dict(scheme=sc.scheme, rounds=6, l_iters=1, lr=sc.lr, params=p,
              seed=0, eval_every=6)
    for engine in ("serial", "batched"):
        base = run_simulation(veh, te_i, te_l, engine=engine, **kw)
        off = run_simulation(veh, te_i, te_l, engine=engine,
                             faults="off", **kw)
        assert tree_digest(off.final_params) == \
            tree_digest(base.final_params)
        assert "faults" not in off.extras
        assert off.report.faults is None


def test_faults_off_cache_identity_jit():
    """jit: faults=None/'off' reuse the legacy executable object; a live
    profile stages a different program (the TEL001-dual contract)."""
    sc = get_scenario("quick-k5")
    veh, _, _, p = build_world(sc, seed=0)
    from repro.core.jit_engine import _stage_run
    kw = dict(scheme=sc.scheme, rounds=6, l_iters=1, lr=sc.lr, params=p,
              seed=0, eval_every=3, use_kernel=False, init_params=None,
              interpretation="mixing", batch_size=32, mesh=None,
              selection=None, flat=True, ring_dtype="f32")
    base, *_ = _stage_run(veh, faults=None, **kw)
    off, *_ = _stage_run(veh, faults="off", **kw)
    noop, *_ = _stage_run(veh, faults=FaultSpec(), **kw)
    live, *_ = _stage_run(veh, faults=HEAVY, **kw)
    assert off is base
    assert noop is base
    assert live is not base


def test_faults_off_cache_identity_corridor():
    sc = get_scenario("corridor-quick-r2-k8")
    veh, _, _, p = build_world(sc, seed=0)
    from repro.corridor.engine import _stage_run
    kw = dict(seed=0, eval_every=4, interpretation="mixing",
              use_kernel=False, batch_size=32, mesh=None,
              record_cohorts=False, init_params=None, selection=None,
              flat=True)
    base, *_ = _stage_run(sc, veh, p, faults=None, **kw)
    off, *_ = _stage_run(sc, veh, p, faults="off", **kw)
    live, *_ = _stage_run(sc, veh, p, faults=HEAVY, **kw)
    assert off is base
    assert live is not base


# ---------------------------------------------------------------------------
# exact f64-replay conformance (the oracle contract)
# ---------------------------------------------------------------------------
def test_fleet_k100_replay_conformance():
    """fleet-k100 under flaky churn: batched and jit reproduce every
    drop/blackout/partial/cap decision of the f64 replay exactly."""
    sc = dataclasses.replace(get_scenario("fleet-k100"), rounds=20,
                             l_iters=2, faults="flaky")
    oracle = replay_fleet_faults(sc.channel(), 0, sc.rounds, "flaky",
                                 l_iters=sc.l_iters)
    expected = oracle.summary(sc.l_iters)
    rb = run_scenario(sc, engine="batched", eval_every=sc.rounds)
    rj = run_scenario(sc, engine="jit", eval_every=sc.rounds)
    assert rb.extras["faults"] == expected
    assert rj.extras["faults"] == expected
    assert rb.report.faults["counts"] == expected["counts"]
    # the flaky profile actually fired on this world (not a vacuous pass)
    assert any(c != 0 for c in expected["cause"]) or \
        not all(expected["admit0"])


def test_corridor_quick_replay_conformance():
    """corridor-quick-r2-k8 under heavy churn: the device-resident engine
    and the serial reference both match the corridor replay exactly."""
    sc = dataclasses.replace(get_scenario("corridor-quick-r2-k8"))
    oracle = replay_corridor_faults(
        sc.channel(), sc.n_rsus, 0, sc.rounds, HEAVY, l_iters=sc.l_iters,
        entry=sc.corridor_entry, reconcile_every=sc.reconcile_every)
    expected = oracle.summary(sc.l_iters)
    rc = run_scenario(sc, engine="corridor", eval_every=sc.rounds,
                      faults_overrides=_as_overrides(HEAVY),
                      faults="flaky")
    rs = run_scenario(sc, engine="serial", eval_every=sc.rounds,
                      faults_overrides=_as_overrides(HEAVY),
                      faults="flaky")
    assert rc.extras["faults"] == expected
    assert rs.extras["faults"] == expected


def _as_overrides(spec: FaultSpec) -> tuple:
    return tuple(dataclasses.asdict(spec).items())


# ---------------------------------------------------------------------------
# seed determinism and cross-seed shape stability
# ---------------------------------------------------------------------------
def test_replay_seed_determinism():
    p = dataclasses.replace(ChannelParams(), K=20)
    a = replay_fleet_faults(p, 3, 30, HEAVY, l_iters=2)
    b = replay_fleet_faults(p, 3, 30, HEAVY, l_iters=2)
    assert a.signature() == b.signature()
    c = replay_fleet_faults(p, 4, 30, HEAVY, l_iters=2)
    assert c.signature() != a.signature()
    # FLT001 shape discipline: tables depend on (rounds, K), not the seed
    ta, tc = a.tables(30), c.tables(30)
    assert set(ta) == set(tc)
    for k in ta:
        assert ta[k].shape == tc[k].shape and ta[k].dtype == tc[k].dtype
    assert a.counts_table(2).shape == c.counts_table(2).shape == (30, 4)


# ---------------------------------------------------------------------------
# sampler properties (hypothesis shim)
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(st.floats(min_value=0.0, max_value=0.4))
def test_dropout_fraction_matches_spec_rate(p_drop):
    """Each pop draws its dropout independently at probability
    ``p_dropout``, so the recorded drop fraction concentrates around the
    spec rate (zero exactly at zero)."""
    spec = FaultSpec(p_dropout=p_drop, recheck_every=4)
    p = dataclasses.replace(ChannelParams(), K=50)
    plan = replay_fleet_faults(p, 0, 400, spec, l_iters=1)
    if p_drop == 0.0:
        assert plan is None          # no-op spec collapses to faults-off
        return
    frac = float(np.mean(np.asarray(plan.cause) == 1))
    assert abs(frac - p_drop) < 0.12


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=1, max_value=5))
def test_partial_epoch_counts_bounded_by_configured(l_iters):
    spec = FaultSpec(p_partial=0.6, recheck_every=4)
    p = dataclasses.replace(ChannelParams(), K=20)
    plan = replay_fleet_faults(p, 1, 120, spec, l_iters=l_iters)
    eps = np.asarray(plan.epochs)
    assert np.all((1 <= eps) & (eps <= l_iters))
    assert plan.counts(l_iters)["partial_rounds"] == \
        int(np.sum(eps < l_iters))
    # with partial disabled every cycle runs the full epoch count
    clean = replay_fleet_faults(
        p, 1, 120, FaultSpec(p_dropout=0.1, recheck_every=4),
        l_iters=l_iters)
    assert np.all(np.asarray(clean.epochs) == l_iters)


def test_dropped_vehicles_never_contribute_until_readmitted():
    """A suppressed re-schedule removes the vehicle from the event queue:
    it must not appear again in the pop sequence before a re-admission
    boundary brings it back."""
    from repro.telemetry.replay import replay_fleet_channels
    p = dataclasses.replace(ChannelParams(), K=30)
    rounds = 200
    plan = replay_fleet_faults(p, 2, rounds, HEAVY, l_iters=2)
    veh = replay_fleet_channels(p, 2, rounds, faults=HEAVY,
                                l_iters=2)["veh"]
    suppressed = [r for r in range(rounds) if not plan.sched[r]]
    assert suppressed, "HEAVY spec produced no suppressions on 200 rounds"
    readmits = plan.readmit_lists()
    for r in suppressed:
        v = int(veh[r])
        later = np.nonzero(veh[r + 1:] == v)[0]
        if later.size == 0:
            continue                 # never came back before the end
        r2 = r + 1 + int(later[0])
        assert any(r < b <= r2 and v in vs
                   for b, vs in readmits.items()), (
            f"vehicle {v} suppressed at pop {r} reappeared at {r2} "
            "without a re-admission boundary in between")


# ---------------------------------------------------------------------------
# telemetry fault counters (scan-carry accumulators vs f64 replay)
# ---------------------------------------------------------------------------
def test_fault_counters_conform_jit():
    sc = dataclasses.replace(get_scenario("quick-k5"), rounds=12)
    plan = replay_fleet_faults(sc.channel(), 0, sc.rounds, HEAVY,
                               l_iters=sc.l_iters)
    r = run_scenario(sc, engine="jit", eval_every=sc.rounds,
                     metrics="on", faults="flaky",
                     faults_overrides=_as_overrides(HEAVY))
    got = r.report.channels["fault_counts"]
    np.testing.assert_array_equal(
        got, plan.counts_table(sc.l_iters).sum(axis=0))
    # faults off -> no fault counter channel rides the carry
    clean = run_scenario(sc, engine="jit", eval_every=sc.rounds,
                         metrics="on")
    assert "fault_counts" not in clean.report.channels


def test_fault_counters_conform_corridor():
    sc = get_scenario("corridor-quick-r2-k8")
    plan = replay_corridor_faults(
        sc.channel(), sc.n_rsus, 0, sc.rounds, HEAVY, l_iters=sc.l_iters,
        entry=sc.corridor_entry, reconcile_every=sc.reconcile_every)
    r = run_scenario(sc, engine="corridor", eval_every=sc.rounds,
                     metrics="on", faults="flaky",
                     faults_overrides=_as_overrides(HEAVY))
    np.testing.assert_array_equal(
        r.report.channels["fault_counts"],
        plan.counts_table(sc.l_iters).sum(axis=0))


# ---------------------------------------------------------------------------
# scope gates
# ---------------------------------------------------------------------------
def test_ema_reconcile_rejects_timeline_faults():
    """Recovery re-admission needs an RSU-independent download model, so
    timeline-active faults are fedavg-only on corridor worlds."""
    with pytest.raises(ValueError, match="ema"):
        check_faults_reconcile(named_profile("flaky"), "ema")
    # compute-only faults never touch the timeline: ema stays legal
    check_faults_reconcile(named_profile("throttled"), "ema")
    check_faults_reconcile(named_profile("flaky"), "fedavg")
    sc = dataclasses.replace(get_scenario("corridor-quick-r2-k8"),
                             reconcile_mode="ema", faults="flaky")
    for engine in ("corridor", "serial"):
        with pytest.raises(ValueError, match="ema"):
            run_scenario(sc, engine=engine, eval_every=sc.rounds)


def test_vmap_engine_rejects_fault_worlds():
    with pytest.raises(ValueError, match="vmap.*fault"):
        run_scenario("fleet-k1000-flaky", engine="vmap", K=5, rounds=6,
                     l_iters=1, n_train=400, n_test=80)


# ---------------------------------------------------------------------------
# FLT001 lint (the faults dual of PLN001/PLN002)
# ---------------------------------------------------------------------------
def test_flt001_flags_engine_imports_and_f32_in_fault_modules():
    from repro.check.boundary import check_source
    bad = ("import jax\n"
           "from repro.core.jit_engine import plan_fleet\n"
           "import numpy as np\n"
           "x = np.zeros(3, np.float32)\n")
    findings = check_source("src/repro/faults/runtime.py", bad)
    rules = [f.rule for f in findings]
    assert rules.count("FLT001") == 3      # jax, engine import, f32 drop
    # the real fault modules are clean under their own rule
    from pathlib import Path
    from repro.check.boundary import check_file
    for name in ("spec.py", "runtime.py", "replay.py", "__init__.py"):
        path = Path("src/repro/faults") / name
        assert not [f for f in check_file(path) if not f.waived], name


def test_faults_off_probe_is_green():
    from repro.check.faults_off import _resolve_findings
    assert _resolve_findings() == []

"""Figs. 3 & 4: accuracy and loss of the global model, MAFL vs conventional
AFL, over rounds (3-seed average, per the paper's protocol)."""
from __future__ import annotations

import time

from benchmarks.common import averaged_curves, save_result


def run(quick=False):
    t0 = time.time()
    rounds = 16 if quick else None
    kw = {} if rounds is None else {"rounds": rounds}
    out = {}
    for scheme in ("mafl", "afl"):
        r_axis, acc, loss = averaged_curves(scheme, **kw)
        out[scheme] = {"rounds": r_axis, "accuracy": acc, "loss": loss}
        print(f"{scheme:5s} acc: " + " ".join(f"{a:.3f}" for a in acc))
        print(f"{scheme:5s} loss: " + " ".join(f"{l:.3f}" for l in loss))
    gap = out["mafl"]["accuracy"][-1] - out["afl"]["accuracy"][-1]
    out["final_gap_mafl_minus_afl"] = gap
    out["claim_mafl_geq_afl"] = bool(gap >= -0.02)
    out["claim_accuracy_increases"] = bool(
        out["mafl"]["accuracy"][-1] > out["mafl"]["accuracy"][0])
    out["claim_loss_decreases"] = bool(
        out["mafl"]["loss"][-1] < out["mafl"]["loss"][0])
    out["seconds"] = round(time.time() - t0, 1)
    save_result("fig3_fig4", out)
    print(f"final gap (mafl-afl): {gap:+.4f}  [{out['seconds']}s]")
    return out


if __name__ == "__main__":
    run()

"""Mega-fleet engine benchmark (ISSUE 2 acceptance artifact).

Measures per-round wall-clock of the device-resident ``engine="jit"`` on a
mega-fleet scenario and compares it against the host wave-batched engine
two ways, writing everything to ``benchmarks/results/BENCH_fleet.json``:

- **extrapolated**: the batched engine measured at its PR-1 operating
  point (``fleet-k100``: 128-image minibatches, 5 local iterations — the
  world the 37 s / 30-round headline came from) and extrapolated to
  K=1000 with the *conservative flat model* (per-round cost treated as
  K-independent; any K-linear term in scheduling/stacking only raises it).
- **direct**: the batched engine run outright on the identical K=1000
  world — same shards, same single local step — so the number is an
  honest same-work comparison, not only an extrapolation.

``python -m benchmarks.run fleet [scenario] [rounds]``; QUICK=1 swaps in
``quick-k5`` and runs all three engines directly (the CI smoke artifact).
"""
from __future__ import annotations

import time

from benchmarks.common import save_result
from repro.core.mafl import run_simulation
from repro.core.scenarios import build_world, get_scenario


def _timed(veh, te_i, te_l, p, sc, engine, rounds, seed=0):
    t0 = time.perf_counter()
    r = run_simulation(veh, te_i, te_l, scheme=sc.scheme, rounds=rounds,
                       l_iters=sc.l_iters, lr=sc.lr, params=p, seed=seed,
                       eval_every=rounds, engine=engine)
    return time.perf_counter() - t0, r


def _bench_engine(world, sc, engine, rounds):
    veh, te_i, te_l, p = world
    cold, r = _timed(veh, te_i, te_l, p, sc, engine, rounds)
    warm, r = _timed(veh, te_i, te_l, p, sc, engine, rounds)
    return {
        "cold_s": round(cold, 3),
        "warm_s": round(warm, 3),
        "cold_ms_per_round": round(cold * 1e3 / rounds, 2),
        "warm_ms_per_round": round(warm * 1e3 / rounds, 2),
        "final_accuracy": float(r.final_accuracy()),
    }, r


def run(scenario: str = "fleet-k1000", rounds: int | None = None,
        quick: bool = False) -> dict:
    if quick:
        scenario, rounds = "quick-k5", rounds or 8
    sc = get_scenario(scenario)
    rounds = rounds or sc.rounds
    print(f"building {scenario} (K={sc.K}) ...")
    world = build_world(sc, seed=0)

    payload = {"scenario": scenario, "K": sc.K, "rounds": rounds,
               "l_iters": sc.l_iters, "engines": {}}

    engines = ("serial", "batched", "jit") if quick else ("batched", "jit")
    for engine in engines:
        stats, _ = _bench_engine(world, sc, engine, rounds)
        payload["engines"][engine] = stats
        print(f"  {engine:8s}: cold {stats['cold_s']:7.1f}s  warm "
              f"{stats['warm_s']:7.1f}s  ({stats['warm_ms_per_round']:.1f} "
              f"ms/round warm)")

    # accuracy/loss trajectory from a separate (untimed) jit run so the
    # timed runs above stay eval-free except for the final round
    veh, te_i, te_l, p = world
    traj = run_simulation(veh, te_i, te_l, scheme=sc.scheme, rounds=rounds,
                          l_iters=sc.l_iters, lr=sc.lr, params=p, seed=0,
                          eval_every=max(1, rounds // 10), engine="jit")
    payload["trajectory"] = {
        "rounds": [rd for rd, _ in traj.acc_history],
        "accuracy": [float(a) for _, a in traj.acc_history],
        "loss": [float(l) for _, l in traj.loss_history],
    }

    jit_ms = payload["engines"]["jit"]["warm_ms_per_round"]
    direct_ms = payload["engines"]["batched"]["warm_ms_per_round"]
    payload["ratio_direct_same_world"] = round(direct_ms / jit_ms, 2)

    if not quick:
        # extrapolation basis: the batched engine at its fleet-k100
        # operating point (PR-1 headline world), flat-in-K model
        basis = get_scenario("fleet-k100")
        b_rounds = min(rounds, 30)
        print(f"measuring extrapolation basis fleet-k100 ({b_rounds} "
              "rounds) ...")
        bworld = build_world(basis, seed=0)
        bstats, _ = _bench_engine(bworld, basis, "batched", b_rounds)
        extrap = bstats["warm_ms_per_round"]
        payload["batched_extrapolated_at_K"] = {
            "basis_scenario": "fleet-k100",
            "basis_rounds": b_rounds,
            "basis_warm_ms_per_round": extrap,
            "model": "flat-in-K (conservative: ignores K-linear "
                     "scheduling/stacking terms)",
            "extrapolated_ms_per_round_at_target_K": extrap,
        }
        payload["ratio_vs_extrapolated"] = round(extrap / jit_ms, 2)
        print(f"  jit {jit_ms:.1f} ms/round vs batched extrapolated "
              f"{extrap:.1f} ms/round -> {payload['ratio_vs_extrapolated']}x"
              f" (direct same-world: {payload['ratio_direct_same_world']}x)")

    # quick (CI smoke) runs get their own file so they never clobber the
    # committed mega-fleet acceptance artifact
    path = save_result("BENCH_fleet_quick" if quick else "BENCH_fleet",
                       payload)
    print(f"wrote {path}")
    return payload

"""Corridor engine benchmark (ISSUE 3 acceptance artifact).

Measures per-round wall-clock of the device-resident ``engine="corridor"``
against the retired serial handover reference, writing everything to
``benchmarks/results/BENCH_corridor.json``:

- **r4-k400 direct**: both engines run outright on the identical
  ``corridor-r4-k400`` world — the honest same-work comparison.
- **r8-k4000**: the corridor engine runs the mega-corridor directly; the
  serial path is *extrapolated* from its r4-k400 per-round cost with the
  conservative flat model (per-round cost treated as K- and R-independent;
  the serial loop's per-arrival scheduling and per-RSU bookkeeping are
  K-linear, so any such term only raises the real number).

``python -m benchmarks.run corridor [rounds]``; QUICK=1 swaps in
``corridor-quick-r2-k8`` through both engines (the CI smoke artifact).
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import save_result
from repro.core.scenarios import build_world, get_scenario


def _timed(sc, world, engine, rounds, seed=0):
    from repro.corridor.engine import run_corridor_simulation
    from repro.corridor.reference import run_handover_simulation
    veh, te_i, te_l, p = world
    run = (run_handover_simulation if engine == "serial"
           else run_corridor_simulation)
    scr = dataclasses.replace(sc, rounds=rounds)
    t0 = time.perf_counter()
    r = run(scr, veh, te_i, te_l, p, seed=seed, eval_every=rounds)
    return time.perf_counter() - t0, r


def _bench_engine(sc, world, engine, rounds):
    cold, r = _timed(sc, world, engine, rounds)
    warm, r = _timed(sc, world, engine, rounds)
    return {
        "rounds": rounds,
        "cold_s": round(cold, 3),
        "warm_s": round(warm, 3),
        "cold_ms_per_round": round(cold * 1e3 / rounds, 2),
        "warm_ms_per_round": round(warm * 1e3 / rounds, 2),
        "warm_rounds_per_s": round(rounds / warm, 2),
        "final_accuracy": float(r.final_accuracy()),
    }


def run(rounds: int | None = None, quick: bool = False) -> dict:
    direct_name = "corridor-quick-r2-k8" if quick else "corridor-r4-k400"
    sc = get_scenario(direct_name)
    rounds = rounds or sc.rounds
    serial_rounds = min(rounds, 8 if quick else 24)

    print(f"building {direct_name} (K={sc.K}, R={sc.n_rsus}) ...")
    world = build_world(sc, seed=0)
    payload = {"direct_scenario": direct_name, "K": sc.K,
               "n_rsus": sc.n_rsus, "engines": {}}

    for engine, n in (("serial", serial_rounds), ("corridor", rounds)):
        stats = _bench_engine(sc, world, engine, n)
        payload["engines"][engine] = stats
        print(f"  {engine:8s}: cold {stats['cold_s']:7.1f}s  warm "
              f"{stats['warm_s']:7.1f}s  ({stats['warm_ms_per_round']:.1f} "
              f"ms/round, {stats['warm_rounds_per_s']:.1f} rounds/s warm)")
    serial_ms = payload["engines"]["serial"]["warm_ms_per_round"]
    direct_ms = payload["engines"]["corridor"]["warm_ms_per_round"]
    payload["ratio_direct_same_world"] = round(serial_ms / direct_ms, 2)

    if not quick:
        # the mega-corridor: corridor engine direct, serial extrapolated
        mega = get_scenario("corridor-r8-k4000")
        mrounds = min(rounds, mega.rounds)
        print(f"building corridor-r8-k4000 (K={mega.K}, R={mega.n_rsus}) "
              "...")
        mworld = build_world(mega, seed=0)
        mstats = _bench_engine(mega, mworld, "corridor", mrounds)
        payload["mega"] = {
            "scenario": "corridor-r8-k4000", "K": mega.K,
            "n_rsus": mega.n_rsus, "corridor": mstats,
            "serial_extrapolated_ms_per_round": serial_ms,
            "extrapolation_model":
                "flat-in-K/R from corridor-r4-k400 (conservative: the "
                "serial loop's per-arrival scheduling and per-RSU "
                "bookkeeping scale with K and R, which only raises it)",
        }
        payload["ratio_vs_extrapolated"] = round(
            serial_ms / mstats["warm_ms_per_round"], 2)
        print(f"  r8-k4000 corridor {mstats['warm_ms_per_round']:.1f} "
              f"ms/round vs serial extrapolated {serial_ms:.1f} ms/round "
              f"-> {payload['ratio_vs_extrapolated']}x (direct same-world "
              f"at r4-k400: {payload['ratio_direct_same_world']}x)")

    path = save_result("BENCH_corridor_quick" if quick
                       else "BENCH_corridor", payload)
    print(f"wrote {path}")
    return payload

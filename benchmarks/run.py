"""Benchmark harness — one entry per paper artifact + system extras.

  fig3_fig4  — accuracy & loss vs rounds, MAFL vs AFL (Figs. 3-4)
  fig5       — beta sweep at 10 rounds (Fig. 5)
  kernels    — Pallas kernel micro + v5e roofline projections (CSV rows)
  roofline   — render the dry-run roofline tables (deliverable g)

``python -m benchmarks.run``            runs everything (QUICK=1 shrinks the
simulation rounds for CI-speed smoke runs).
``python -m benchmarks.run fig5`` etc.  runs one.
"""
from __future__ import annotations

import os
import sys
import time


def main() -> None:
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    quick = bool(int(os.environ.get("QUICK", "0")))
    t0 = time.time()

    if which in ("all", "kernels"):
        print("== kernel microbenchmarks ==")
        from benchmarks import kernel_micro
        kernel_micro.run()

    if which in ("all", "roofline"):
        print("\n== roofline (from dry-run artifacts) ==")
        from benchmarks import roofline_report
        roofline_report.run()

    if which in ("all", "fig3", "fig4", "fig3_fig4"):
        print("\n== Figs. 3-4: MAFL vs AFL accuracy/loss ==")
        from benchmarks import fig3_fig4_accuracy_loss
        fig3_fig4_accuracy_loss.run(quick=quick)

    if which in ("all", "fig5"):
        print("\n== Fig. 5: beta sweep ==")
        from benchmarks import fig5_beta_sweep
        fig5_beta_sweep.run(quick=quick)

    if which in ("all", "ablation"):
        print("\n== Beyond-paper: scheme ablation ==")
        from benchmarks import ablation_schemes
        ablation_schemes.run(quick=quick)

    print(f"\ntotal {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()

"""Benchmark harness — one entry per paper artifact + system extras.

  fig3_fig4  — accuracy & loss vs rounds, MAFL vs AFL (Figs. 3-4)
  fig5       — beta sweep at 10 rounds (Fig. 5)
  kernels    — Pallas kernel micro + v5e roofline projections (CSV rows)
  roofline   — render the dry-run roofline tables (deliverable g)
  scenario   — run a named scenario from the registry (DESIGN.md §8):
               ``python -m benchmarks.run scenario fleet-k100 [rounds]``
  fleet      — mega-fleet engine comparison -> BENCH_fleet.json
               (DESIGN.md §9): ``python -m benchmarks.run fleet
               [scenario] [rounds]``; QUICK=1 smokes quick-k5 through
               serial/batched/jit
  corridor   — multi-RSU corridor engine comparison ->
               BENCH_corridor.json (DESIGN.md §10): serial reference vs
               engine='corridor' at r4-k400 direct + r8-k4000;
               QUICK=1 smokes corridor-quick-r2-k8
  selection  — admission-policy comparison -> BENCH_selection.json
               (DESIGN.md §11): admit-all vs weighted-topk vs budget
               ms/round on fleet-k1000 at equal rounds; QUICK=1 smokes
               quick-k5 with topk through serial/batched/jit
  perf       — flat-parameter fast-path comparison -> BENCH_perf.json at
               the REPO ROOT (DESIGN.md §12): batched/jit-pytree/jit-flat
               (+bf16) ms/round on fleet-k1000 + corridor-r4-k400 +
               fleet-k10000, consolidating the other BENCH headline
               numbers; QUICK=1 runs the smoke lanes only.
               ``perf check`` compares fresh QUICK lanes against the
               committed baseline (2x threshold, CI perf-regression job);
               ``perf k10000-smoke`` compile-smokes fleet-k10000;
               ``perf telemetry`` measures the metrics=on/off overhead
               (DESIGN.md §14) and merges it into BENCH_perf.json.
  faults     — fault-injection comparison -> BENCH_faults.json
               (DESIGN.md §16): clean-vs-flaky ms/round overhead on
               fleet-k1000 (exit 1 past the +10% bar) + accuracy under
               churn per admission policy; QUICK=1 smokes quick-k5
  sweep      — multi-world vmap sweep vs serial jit loop ->
               BENCH_sweep.json (DESIGN.md §15): the Fig. 5 grid
               (5 betas x 3 seeds) as ONE dispatch, wall-clock compared
               against the solo-jit rerun loop with a bitwise
               cross-check; QUICK=1 smokes a W=4 quick-k5 grid

All committed (non-quick) BENCH_*.json artifacts are also copied to the
repo root, where the perf-trajectory tracker reads them.

``python -m benchmarks.run``            runs everything (QUICK=1 shrinks the
simulation rounds for CI-speed smoke runs).
``python -m benchmarks.run fig5`` etc.  runs one.
"""
from __future__ import annotations

import os
import sys
import time

# before any jax import: the legacy CPU runtime runs the paper CNN's train
# step ~15% faster than the thunk runtime on this host (benchmarks only —
# the library itself never forces backend flags)
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_use_thunk_runtime=false")


def run_scenario_cmd(argv) -> None:
    from repro.core.scenarios import list_scenarios, run_scenario
    if not argv:
        print("available scenarios:", ", ".join(list_scenarios()))
        return
    name = argv[0]
    kw = {"rounds": int(argv[1])} if len(argv) > 1 else {}
    t0 = time.time()
    r = run_scenario(name, progress=lambda rd, a: print(
        f"  round {rd}: acc={a:.3f}"), **kw)
    dt = time.time() - t0
    print(f"{name}: {len(r.rounds)} rounds in {dt:.1f}s "
          f"({len(r.rounds) / max(dt, 1e-9):.2f} rounds/s), "
          f"final acc {r.final_accuracy():.3f}")


def main() -> None:
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    quick = bool(int(os.environ.get("QUICK", "0")))
    t0 = time.time()

    if which == "scenario":
        run_scenario_cmd(sys.argv[2:])
        return

    if which == "fleet":
        from benchmarks import fleet_bench
        argv = sys.argv[2:]
        kw = {}
        if argv:
            kw["scenario"] = argv[0]
        if len(argv) > 1:
            kw["rounds"] = int(argv[1])
        fleet_bench.run(quick=quick, **kw)
        return

    if which == "corridor":
        from benchmarks import corridor_bench
        argv = sys.argv[2:]
        kw = {"rounds": int(argv[0])} if argv else {}
        corridor_bench.run(quick=quick, **kw)
        return

    if which == "selection":
        from benchmarks import selection_bench
        argv = sys.argv[2:]
        kw = {"rounds": int(argv[0])} if argv else {}
        selection_bench.run(quick=quick, **kw)
        return

    if which == "faults":
        from benchmarks import faults_bench
        argv = sys.argv[2:]
        kw = {"rounds": int(argv[0])} if argv else {}
        sys.exit(faults_bench.main(quick=quick, **kw))

    if which == "sweep":
        from benchmarks import sweep_bench
        sweep_bench.run(quick=quick)
        return

    if which == "perf":
        from benchmarks import perf_bench
        sys.exit(perf_bench.main(sys.argv[2:]))

    if which in ("all", "kernels"):
        print("== kernel microbenchmarks ==")
        from benchmarks import kernel_micro
        kernel_micro.run()

    if which in ("all", "roofline"):
        print("\n== roofline (from dry-run artifacts) ==")
        from benchmarks import roofline_report
        roofline_report.run()

    if which in ("all", "fig3", "fig4", "fig3_fig4"):
        print("\n== Figs. 3-4: MAFL vs AFL accuracy/loss ==")
        from benchmarks import fig3_fig4_accuracy_loss
        fig3_fig4_accuracy_loss.run(quick=quick)

    if which in ("all", "fig5"):
        print("\n== Fig. 5: beta sweep ==")
        from benchmarks import fig5_beta_sweep
        fig5_beta_sweep.run(quick=quick)

    if which in ("all", "ablation"):
        print("\n== Beyond-paper: scheme ablation ==")
        from benchmarks import ablation_schemes
        ablation_schemes.run(quick=quick)

    if which == "all":
        print("\n== Mega-fleet engine comparison ==")
        from benchmarks import fleet_bench
        fleet_bench.run(quick=quick)

    if which == "all":
        print("\n== Corridor engine comparison ==")
        from benchmarks import corridor_bench
        corridor_bench.run(quick=quick)

    if which == "all":
        print("\n== Selection policy comparison ==")
        from benchmarks import selection_bench
        selection_bench.run(quick=quick)

    if which == "all":
        print("\n== Fault-injection comparison ==")
        from benchmarks import faults_bench
        faults_bench.run(quick=quick)

    if which == "all":
        print("\n== Multi-world sweep engine comparison ==")
        from benchmarks import sweep_bench
        sweep_bench.run(quick=quick)

    if which == "all":
        print("\n== Flat fast-path comparison ==")
        from benchmarks import perf_bench
        perf_bench.run(quick=quick)

    print(f"\ntotal {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()

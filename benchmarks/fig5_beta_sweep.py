"""Fig. 5: MAFL accuracy at round 10 under different aggregation proportions
beta — the paper reports a flat region for beta <= 0.5 and a sharp drop at
beta = 0.9."""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import averaged_curves, save_result
from repro.channel.params import ChannelParams

BETAS = (0.1, 0.3, 0.5, 0.7, 0.9)


def run(quick=False):
    t0 = time.time()
    base = ChannelParams()
    rounds = 10                      # the paper evaluates at 10 rounds
    accs = {}
    for beta in BETAS:
        p = dataclasses.replace(base, beta=beta)
        # l=30 local iterations: at 10 rounds the paper's well-trained
        # local models are what makes small beta favourable (EXPERIMENTS.md)
        _, acc, _ = averaged_curves("mafl", rounds=rounds, eval_every=rounds,
                                    params=p, seeds=(0,), l_iters=30)
        accs[beta] = acc[-1]
        print(f"beta={beta:.1f} acc@{rounds} = {acc[-1]:.3f}")
    out = {"betas": list(BETAS), "accuracy": [accs[b] for b in BETAS]}
    out["claim_drop_at_0.9"] = bool(accs[0.9] < max(accs.values()) - 0.02)
    out["claim_small_beta_ok"] = bool(
        min(accs[0.1], accs[0.3], accs[0.5]) >
        accs[0.9] - 0.02)
    out["seconds"] = round(time.time() - t0, 1)
    save_result("fig5_beta", out)
    return out


if __name__ == "__main__":
    run()

"""Fig. 5: MAFL accuracy at round 10 under different aggregation proportions
beta — the paper reports a flat region for beta <= 0.5 and a sharp drop at
beta = 0.9.

The paper averages 3 experiments, and the sweep tier makes that free:
the full 5-beta x 3-seed grid runs as ONE ``engine="vmap"`` dispatch
(DESIGN.md §15) instead of 15 serial reruns, so this benchmark reports the
paper's seed-averaged point *with* its per-seed spread rather than the
single-seed curve the serial budget used to force.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import SEEDS, save_result
from repro.core.scenarios import SweepSpec, run_sweep

BETAS = (0.1, 0.3, 0.5, 0.7, 0.9)


def run(quick=False):
    t0 = time.time()
    rounds = 10                      # the paper evaluates at 10 rounds
    betas = (0.1, 0.9) if quick else BETAS
    seeds = SEEDS[:2] if quick else SEEDS
    # l=30 local iterations: at 10 rounds the paper's well-trained local
    # models are what makes small beta favourable (EXPERIMENTS.md)
    l_iters = 4 if quick else 30
    spec = SweepSpec(
        scenario="paper-k10", seeds=seeds,
        variants=tuple((("channel_overrides", (("beta", b),)),)
                       for b in betas),
        overrides=(("rounds", rounds), ("l_iters", l_iters)),
        eval_every=rounds)
    results = run_sweep(spec)        # one dispatch: |betas| x |seeds| worlds
    S = len(seeds)
    accs, spread = {}, {}
    for i, beta in enumerate(betas):
        per_seed = [results[i * S + j].acc_history[-1][1] for j in range(S)]
        accs[beta] = float(np.mean(per_seed))
        spread[beta] = float(np.std(per_seed))
        print(f"beta={beta:.1f} acc@{rounds} = {accs[beta]:.3f} "
              f"+/- {spread[beta]:.3f} (n={S})")
    out = {"betas": list(betas), "accuracy": [accs[b] for b in betas],
           "accuracy_std": [spread[b] for b in betas],
           "seeds": list(seeds), "engine": "vmap",
           "n_worlds": len(results), "l_iters": l_iters}
    out["claim_drop_at_0.9"] = bool(
        accs[betas[-1]] < max(accs.values()) - 0.02)
    if not quick:
        out["claim_small_beta_ok"] = bool(
            min(accs[0.1], accs[0.3], accs[0.5]) > accs[0.9] - 0.02)
    out["seconds"] = round(time.time() - t0, 1)
    save_result("fig5_beta_quick" if quick else "fig5_beta", out)
    return out


if __name__ == "__main__":
    run()

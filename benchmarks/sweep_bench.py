"""Multi-world sweep-engine benchmark (DESIGN.md §15 acceptance artifact).

Runs the Fig. 5 grid — 5 betas x 3 seeds — twice: as ONE ``engine="vmap"``
dispatch of the multi-world sweep program, and as the serial solo
``engine="jit"`` loop it replaces, writing ``BENCH_sweep.json`` with the
wall-clock comparison and a per-world bitwise cross-check (the measured
serial worlds' final parameters must digest-match their vmap slices —
the same pin ``tests/test_vmap_sweep.py`` enforces).

The serial side of the full grid is measured on 3 of the 15 worlds and
extrapolated linearly (flagged ``serial_extrapolated`` in the artifact —
never silently); each serial world compiles its own program where the
sweep compiles once per batch, so both cold and warm timings are reported.

``python -m benchmarks.run sweep``; QUICK=1 swaps in a W=4 quick-k5 grid
(2 betas x 2 seeds) with every serial world measured — the CI smoke
artifact.

This lane runs under XLA:CPU's **default thunk runtime**, not the legacy
runtime the other benchmark lanes select for its ~15% faster train step:
the legacy runtime contracts FMAs differently across the sweep and solo
program structures, so the bitwise cross-check (and the conformance
contract it mirrors — the tier-1 suite also runs under the default
runtime) only holds on the thunk runtime.  The flag is stripped below
before jax initializes; when another lane already initialized jax in
this process (``benchmarks.run all``), ``run()`` re-execs this module in
a clean subprocess instead.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_LEGACY = "--xla_cpu_use_thunk_runtime=false"
_FOREIGN_RUNTIME = (_LEGACY in os.environ.get("XLA_FLAGS", "")
                    and "jax" in sys.modules)
if not _FOREIGN_RUNTIME and _LEGACY in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = " ".join(
        t for t in os.environ["XLA_FLAGS"].split() if t != _LEGACY)

from benchmarks.common import RESULTS_DIR, SEEDS, save_result
from repro.checkpointing.checkpoint import tree_digest
from repro.core.scenarios import SweepSpec, run_scenario, run_sweep

BETAS = (0.1, 0.3, 0.5, 0.7, 0.9)


def _grid_spec(quick: bool) -> SweepSpec:
    if quick:
        return SweepSpec(
            scenario="quick-k5", seeds=(0, 1),
            variants=tuple((("channel_overrides", (("beta", b),)),)
                           for b in (0.1, 0.5)),
            overrides=(("rounds", 8),), eval_every=8)
    return SweepSpec(
        scenario="paper-k10", seeds=SEEDS,
        variants=tuple((("channel_overrides", (("beta", b),)),)
                       for b in BETAS),
        overrides=(("rounds", 10), ("l_iters", 30)), eval_every=10)


def run(quick: bool = False) -> dict:
    if _FOREIGN_RUNTIME:
        # jax already came up on the legacy runtime in this process: the
        # bitwise cross-check needs the thunk runtime, so measure in a
        # clean subprocess and read back the artifact it wrote
        env = dict(os.environ, QUICK="1" if quick else "0")
        env["XLA_FLAGS"] = " ".join(
            t for t in env.get("XLA_FLAGS", "").split() if t != _LEGACY)
        subprocess.run([sys.executable, "-m", "benchmarks.sweep_bench"],
                       check=True, env=env)
        name = "BENCH_sweep_quick" if quick else "BENCH_sweep"
        with open(os.path.join(RESULTS_DIR, f"{name}.json")) as f:
            return json.load(f)
    spec = _grid_spec(quick)
    worlds = spec.worlds()
    W = len(worlds)
    betas = sorted({dict(sc.channel_overrides).get("beta", 0.5)
                    for sc, _ in worlds})
    print(f"sweep grid: W={W} worlds ({len(betas)} betas x "
          f"{len(spec.seeds)} seeds) on {worlds[0][0].name}")

    t0 = time.perf_counter()
    vm = run_sweep(spec)
    cold_vmap = time.perf_counter() - t0
    t0 = time.perf_counter()
    vm = run_sweep(spec)
    warm_vmap = time.perf_counter() - t0
    print(f"  vmap one-dispatch: cold {cold_vmap:6.1f}s  "
          f"warm {warm_vmap:6.1f}s")

    # serial baseline: the solo jit loop the sweep replaces.  The full
    # grid measures a 3-world subset (one per beta of the first three
    # variants, first seed) and extrapolates — flagged, never silent.
    n_serial = W if quick else min(3, W)
    serial_idx = (list(range(W)) if quick
                  else [i * len(spec.seeds) for i in range(n_serial)])
    cold_s = warm_s = 0.0
    digests_match = True
    for i in serial_idx:
        sc, seed = worlds[i]
        t0 = time.perf_counter()
        r = run_scenario(sc, seed=seed, engine="jit",
                         eval_every=spec.eval_every)
        cold_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        r = run_scenario(sc, seed=seed, engine="jit",
                         eval_every=spec.eval_every)
        dt_w = time.perf_counter() - t0
        warm_s += dt_w
        same = (tree_digest(r.final_params)
                == tree_digest(vm[i].final_params))
        digests_match = digests_match and same
        print(f"  serial world {i}: warm {dt_w:5.1f}s, "
              f"bitwise={'yes' if same else 'NO'}")
    scale = W / n_serial
    payload = {
        "scenario": worlds[0][0].name, "n_worlds": W,
        "betas": [float(b) for b in betas],
        "seeds": list(spec.seeds),
        "rounds": worlds[0][0].rounds, "l_iters": worlds[0][0].l_iters,
        "vmap_cold_s": round(cold_vmap, 2),
        "vmap_warm_s": round(warm_vmap, 2),
        "serial_measured_worlds": n_serial,
        "serial_extrapolated": n_serial < W,
        "serial_cold_s": round(cold_s * scale, 2),
        "serial_warm_s": round(warm_s * scale, 2),
        "speedup_cold": round(cold_s * scale / cold_vmap, 2),
        "speedup_warm": round(warm_s * scale / warm_vmap, 2),
        "bitwise_vs_serial": bool(digests_match),
        "mean_final_accuracy": round(
            float(sum(r.final_accuracy() for r in vm)) / W, 4),
    }
    print(f"  serial loop ({'extrapolated ' if n_serial < W else ''}"
          f"W={W}): cold {payload['serial_cold_s']:6.1f}s  "
          f"warm {payload['serial_warm_s']:6.1f}s -> speedup "
          f"{payload['speedup_cold']}x cold / "
          f"{payload['speedup_warm']}x warm, bitwise="
          f"{payload['bitwise_vs_serial']}")
    if not digests_match:
        raise RuntimeError(
            "sweep bench: a serial world's final parameters diverged "
            "bitwise from its vmap slice — the DESIGN.md §15 conformance "
            "pin is broken; do not publish this artifact")
    path = save_result("BENCH_sweep_quick" if quick else "BENCH_sweep",
                       payload)
    print(f"wrote {path}")
    return payload


if __name__ == "__main__":
    run(quick=bool(int(os.environ.get("QUICK", "0"))))

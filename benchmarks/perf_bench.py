"""Flat-parameter fast-path benchmark + consolidated perf artifact
(ISSUE 5 acceptance): ``python -m benchmarks.run perf``.

Measures warm end-to-end ms/round in one process ("measured in the same
run") for three layouts of each lane:

- **batched** — the host wave-batched engine: the library's default
  single-RSU path and the *pytree path* of the ISSUE motivation (one
  ``mix_update_donated`` pytree pass per upload, one kernel launch per
  leaf, Python dispatch per arrival);
- **jit-pytree** — the device engine with the legacy pytree layout
  (``flat=False``): the event loop is compiled but the model is still a
  pytree and the snapshot ring stores M+1 full models;
- **jit-flat** — the packed flat fast path (DESIGN.md §12), plus its
  bf16-ring variant.

Writes ``BENCH_perf.json`` (repo root + ``benchmarks/results/``)
consolidating ms/round per engine/scenario — including the headline
ms/round from the other committed ``BENCH_*.json`` artifacts — plus the
ring/locals buffer accounting that the bf16 mode halves.

``python -m benchmarks.run perf check`` re-runs the QUICK lanes and
compares against the committed baseline with a generous 2x threshold
(the CI perf-regression smoke); ``perf k10000-smoke`` compile-smokes the
``fleet-k10000`` scenario at 3 rounds; ``perf telemetry`` measures the
metrics=on vs metrics=off overhead at fleet-k1000 and fleet-k10000 and
merges a ``telemetry`` section into the committed artifact (DESIGN.md
§14 — the fleet-k1000 overhead must stay under +10%).

Every lane also records per-engine ``compile_s`` (cold-minus-warm
end-to-end) and peak-RSS columns from the engines' RunReport phase
timers.
"""
from __future__ import annotations

import json
import os
import resource
import sys
import time

from benchmarks.common import REPO_ROOT, save_result
from repro.core.mafl import run_simulation
from repro.core.scenarios import build_world, get_scenario

# generous threshold: QUICK lanes are seconds-long on shared CI runners.
# The check compares each engine's ms/round RELATIVE to its lane's
# pytree reference engine, so absolute machine speed (dev container vs
# GitHub runner) cancels; only a layout-specific slowdown >2x fails.
CHECK_THRESHOLD = 2.0
# reference engine per quick lane for the relative comparison
CHECK_REFERENCE = {"quick-k5": "batched-pytree",
                   "corridor-quick-r2-k8": "corridor-pytree"}


def _warm_ms(veh, te_i, te_l, p, sc, rounds, *, engine, reps=3, **kw):
    kwargs = dict(scheme=sc.scheme, rounds=rounds, l_iters=sc.l_iters,
                  lr=sc.lr, params=p, seed=0, eval_every=rounds,
                  engine=engine, **kw)
    t0 = time.perf_counter()
    run_simulation(veh, te_i, te_l, **kwargs)          # compile + warm
    cold = time.perf_counter() - t0
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        r = run_simulation(veh, te_i, te_l, **kwargs)
        best = min(best, time.perf_counter() - t0)
    # compile_s: cold-minus-warm end-to-end — XLA compilation plus the
    # one-time trace, with plan/stage/eval cancelling between the runs
    stats = {"compile_s": round(max(cold - best, 0.0), 2)}
    rep = getattr(r, "report", None)
    if rep is not None:
        stats["phases_s"] = {k: round(v, 3) for k, v in rep.phases.items()}
        if "peak_rss_bytes" in rep.memory:
            stats["peak_rss_gb"] = round(rep.memory["peak_rss_bytes"] / 1e9,
                                         2)
        if "device_peak_bytes_in_use" in rep.memory:
            stats["device_peak_gb"] = round(
                rep.memory["device_peak_bytes_in_use"] / 1e9, 2)
    return round(best * 1e3 / rounds, 2), float(r.final_accuracy()), stats


def _buffer_bytes(rounds: int, ring_dtype: str, flat: bool,
                  p=None) -> dict:
    """Analytic model-state buffer accounting (the memory the flat/bf16
    modes attack): snapshot ring + upload (locals) buffers.  The flat
    ring materializes exactly the static checkpoint rows of the lane
    (later-wave payload rounds + the final eval row + row 0), counted
    from the same plan the engine compiles from."""
    from repro.core.flat import ParamLayout
    from repro.models.cnn import init_cnn
    import jax
    layout = ParamLayout.from_tree(init_cnn(jax.random.PRNGKey(0)))
    itemsize = 2 if ring_dtype == "bf16" else 4
    if flat:
        from repro.core.jit_engine import plan_fleet
        plan = plan_fleet(p, 0, rounds, None)
        needed = {0, rounds}
        for T, _s, _e in plan.waves:
            needed |= {int(plan.dl_round[t]) + 1 for t in T}
        ring_rows = len(needed)
    else:
        ring_rows = rounds + 1
    return {
        "P": layout.P,
        "ring_rows": ring_rows,
        "ring_bytes": ring_rows * layout.P * itemsize,
        "locals_bytes": rounds * layout.P * itemsize,
    }


def _fleet_lane(scenario: str, rounds: int, batch: int,
                with_bf16: bool) -> dict:
    sc = get_scenario(scenario)
    print(f"building {scenario} (K={sc.K}) ...")
    veh, te_i, te_l, p = build_world(sc, seed=0)
    lane = {"K": sc.K, "rounds": rounds, "batch_size": batch,
            "l_iters": sc.l_iters, "ms_per_round": {}, "compile_s": {},
            "peak_rss_gb": {}}

    def _one(label, **kw):
        ms, acc, st = _warm_ms(veh, te_i, te_l, p, sc, rounds,
                               batch_size=batch, **kw)
        lane["ms_per_round"][label] = ms
        lane["compile_s"][label] = st["compile_s"]
        if "peak_rss_gb" in st:
            lane["peak_rss_gb"][label] = st["peak_rss_gb"]
        print(f"  {label:15s}: {ms:8.1f} ms/round "
              f"(compile {st['compile_s']:.1f}s)")
        return acc

    _one("batched-pytree", engine="batched")
    _one("jit-pytree", engine="jit", flat=False)
    acc = _one("jit-flat", engine="jit", flat=True)
    lane["final_accuracy_flat"] = acc
    if with_bf16:
        _one("jit-flat-bf16", engine="jit", flat=True, ring_dtype="bf16")
    mspr = lane["ms_per_round"]
    lane["ratio_flat_vs_pytree"] = round(
        mspr["batched-pytree"] / mspr["jit-flat"], 2)
    lane["ratio_flat_vs_jit_pytree"] = round(
        mspr["jit-pytree"] / mspr["jit-flat"], 2)
    lane["buffers"] = {
        "pytree_f32": _buffer_bytes(rounds, "f32", False),
        "flat_f32": _buffer_bytes(rounds, "f32", True, p),
        "flat_bf16": _buffer_bytes(rounds, "bf16", True, p),
    }
    return lane


def _corridor_lane(scenario: str, rounds: int) -> dict:
    from repro.core.scenarios import run_scenario
    sc = get_scenario(scenario)
    print(f"building {scenario} (R={sc.n_rsus}, K={sc.K}) ...")
    lane = {"K": sc.K, "n_rsus": sc.n_rsus, "rounds": rounds,
            "ms_per_round": {}}
    for label, kw in (("corridor-pytree", {"flat": False}),
                      ("corridor-flat", {"flat": True})):
        run_scenario(scenario, rounds=rounds, eval_every=rounds, **kw)
        best = float("inf")
        for _ in range(2):
            t0 = time.perf_counter()
            run_scenario(scenario, rounds=rounds, eval_every=rounds, **kw)
            best = min(best, time.perf_counter() - t0)
        lane["ms_per_round"][label] = round(best * 1e3 / rounds, 2)
        print(f"  {label:15s}: {lane['ms_per_round'][label]:8.1f} ms/round")
    lane["ratio_flat_vs_pytree"] = round(
        lane["ms_per_round"]["corridor-pytree"] /
        lane["ms_per_round"]["corridor-flat"], 2)
    return lane


def _k10000_lane(rounds: int = 60, batch: int = 8) -> dict:
    """The bf16-unlock lane: fleet-k10000 completes under the bf16 flat
    ring; the f32 pytree path (the host batched engine — the library's
    pytree default, holding full-precision pytrees per upload) is
    measured at a reduced round count and compared per-round."""
    sc = get_scenario("fleet-k10000")
    print(f"building fleet-k10000 (K={sc.K}) ...")
    veh, te_i, te_l, p = build_world(sc, seed=0)
    lane = {"K": sc.K, "rounds": rounds, "batch_size": batch,
            "ms_per_round": {}, "compile_s": {}}
    t0 = time.perf_counter()
    ms, acc, st = _warm_ms(veh, te_i, te_l, p, sc, rounds, engine="jit",
                           batch_size=batch, flat=True, ring_dtype="bf16",
                           reps=2)
    lane["ms_per_round"]["jit-flat-bf16"] = ms
    lane["compile_s"]["jit-flat-bf16"] = st["compile_s"]
    lane["final_accuracy_bf16"] = acc
    lane["completes_bf16"] = True
    print(f"  jit-flat-bf16  : {ms:8.1f} ms/round "
          f"(full {rounds}-round lane, {time.perf_counter() - t0:.0f}s)")
    ms, _, st = _warm_ms(veh, te_i, te_l, p, sc, rounds, engine="jit",
                         batch_size=batch, flat=False, reps=2)
    lane["ms_per_round"]["jit-pytree-f32"] = ms
    lane["compile_s"]["jit-pytree-f32"] = st["compile_s"]
    print(f"  jit-pytree-f32 : {ms:8.1f} ms/round")
    # the host pytree engine pays Python dispatch per arrival on a
    # 10000-vehicle queue — measured at a short round count (per-round
    # cost is flat-to-falling in rounds, so this UNDERestimates it)
    b_rounds = 10
    ms, _, _ = _warm_ms(veh, te_i, te_l, p, sc, b_rounds, engine="batched",
                        batch_size=batch, reps=1)
    lane["ms_per_round"]["batched-pytree"] = ms
    lane["batched_rounds_measured"] = b_rounds
    print(f"  batched-pytree : {ms:8.1f} ms/round ({b_rounds} rounds)")
    lane["ratio_bf16_vs_pytree"] = round(
        lane["ms_per_round"]["batched-pytree"] /
        lane["ms_per_round"]["jit-flat-bf16"], 2)
    lane["ratio_bf16_vs_jit_pytree"] = round(
        lane["ms_per_round"]["jit-pytree-f32"] /
        lane["ms_per_round"]["jit-flat-bf16"], 2)
    lane["buffers"] = {
        "pytree_f32": _buffer_bytes(rounds, "f32", False),
        "flat_bf16": _buffer_bytes(rounds, "bf16", True, p),
    }
    lane["max_rss_gb"] = round(
        resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1e6, 2)
    return lane


# the telemetry hard bar (DESIGN.md §14): metrics=on may cost at most
# this much warm ms/round over metrics=off at fleet-k1000
TELEMETRY_OVERHEAD_LIMIT_PCT = 10.0


def _telemetry_lane(scenario: str, rounds: int, batch: int,
                    reps: int = 3) -> dict:
    """metrics=on vs metrics=off on the same world, same engine, same
    process — the published overhead of the device-resident channels
    (DESIGN.md §14).  The engine is the scenario's fastest device lane
    (jit-flat, bf16 ring where the scenario opts in)."""
    sc = get_scenario(scenario)
    print(f"building {scenario} (K={sc.K}) for telemetry overhead ...")
    veh, te_i, te_l, p = build_world(sc, seed=0)
    lane = {"K": sc.K, "rounds": rounds, "batch_size": batch,
            "engine": "jit-flat" + ("-bf16" if sc.ring_dtype == "bf16"
                                    else ""),
            "ms_per_round": {}, "compile_s": {}, "phases_s": {},
            "peak_rss_gb": {}}
    for label, met in (("metrics-off", "off"), ("metrics-on", "on")):
        ms, _, st = _warm_ms(veh, te_i, te_l, p, sc, rounds, engine="jit",
                             batch_size=batch, flat=True,
                             ring_dtype=sc.ring_dtype, metrics=met,
                             reps=reps)
        lane["ms_per_round"][label] = ms
        lane["compile_s"][label] = st["compile_s"]
        lane["phases_s"][label] = st.get("phases_s", {})
        if "peak_rss_gb" in st:
            lane["peak_rss_gb"][label] = st["peak_rss_gb"]
        print(f"  {label:12s}: {ms:8.2f} ms/round "
              f"(compile {st['compile_s']:.1f}s)")
    off = lane["ms_per_round"]["metrics-off"]
    on = lane["ms_per_round"]["metrics-on"]
    lane["overhead_pct"] = round((on / off - 1.0) * 100.0, 2)
    print(f"  overhead    : {lane['overhead_pct']:+.2f}% "
          f"(limit +{TELEMETRY_OVERHEAD_LIMIT_PCT:.0f}%)")
    return lane


def telemetry_lanes() -> int:
    """``perf telemetry``: measure the metrics on/off overhead at
    fleet-k1000 and fleet-k10000 and merge a ``telemetry`` section into
    the committed BENCH_perf.json (EXPERIMENTS.md §Telemetry quotes it).
    Exit 1 if the fleet-k1000 overhead exceeds the published limit."""
    lanes = {
        "fleet-k1000": _telemetry_lane("fleet-k1000", 30, 128),
        "fleet-k10000": _telemetry_lane("fleet-k10000", 60, 8, reps=2),
    }
    base_path = os.path.join(REPO_ROOT, "BENCH_perf.json")
    payload = {"lanes": {}, "quick": False}
    if os.path.exists(base_path):
        with open(base_path) as f:
            payload = json.load(f)
    payload["telemetry"] = {
        "overhead_limit_pct": TELEMETRY_OVERHEAD_LIMIT_PCT,
        "lanes": lanes,
    }
    path = save_result("BENCH_perf", payload)
    print(f"wrote {path}")
    pct = lanes["fleet-k1000"]["overhead_pct"]
    if pct > TELEMETRY_OVERHEAD_LIMIT_PCT:
        print(f"telemetry overhead check FAILED: {pct:+.2f}% > "
              f"+{TELEMETRY_OVERHEAD_LIMIT_PCT:.0f}% at fleet-k1000")
        return 1
    print("telemetry overhead check passed")
    return 0


def _headline_summary() -> dict:
    """ms/round per engine/scenario consolidated from the other committed
    BENCH artifacts (the trajectory tracker reads one file)."""
    out = {}
    for name, key in (("BENCH_fleet", "engines"),
                      ("BENCH_corridor", "engines"),
                      ("BENCH_selection", "policies")):
        path = os.path.join(REPO_ROOT, f"{name}.json")
        if not os.path.exists(path):
            path = os.path.join(os.path.dirname(__file__), "results",
                                f"{name}.json")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            data = json.load(f)
        entries = data.get(key, {})
        out[name] = {
            "scenario": data.get("scenario") or data.get("direct_scenario"),
            "warm_ms_per_round": {
                eng: st.get("warm_ms_per_round")
                for eng, st in entries.items()
                if isinstance(st, dict) and "warm_ms_per_round" in st},
        }
    return out


def run(quick: bool = False, k10000: bool = True) -> dict:
    payload = {"lanes": {}, "quick": quick}
    if quick:
        payload["lanes"]["quick-k5"] = _fleet_lane("quick-k5", 8, 32,
                                                   with_bf16=True)
        payload["lanes"]["corridor-quick-r2-k8"] = _corridor_lane(
            "corridor-quick-r2-k8", 8)
    else:
        # the scenario's own operating point (PR-2's direct-same-world
        # lane): rounds=30, fleet minibatch cap 128 -> min-shard 24
        payload["lanes"]["fleet-k1000"] = _fleet_lane("fleet-k1000", 30, 128,
                                                      with_bf16=True)
        payload["lanes"]["corridor-r4-k400"] = _corridor_lane(
            "corridor-r4-k400", 40)
        if k10000:
            payload["lanes"]["fleet-k10000"] = _k10000_lane()
        payload["summary"] = _headline_summary()
        # embed the QUICK-lane baseline the CI perf-regression smoke
        # compares against (same machine as the committed artifact)
        print("measuring QUICK baseline lanes ...")
        payload["quick_baseline"] = {
            "quick-k5": _fleet_lane("quick-k5", 8, 32,
                                    with_bf16=True)["ms_per_round"],
            "corridor-quick-r2-k8": _corridor_lane(
                "corridor-quick-r2-k8", 8)["ms_per_round"],
        }
    name = "BENCH_perf_quick" if quick else "BENCH_perf"
    path = save_result(name, payload)
    print(f"wrote {path}")
    return payload


def check(quick: bool = True) -> int:
    """Perf-regression smoke: re-run the QUICK lanes and compare each
    engine's ms/round against the committed BENCH_perf.json baseline with
    a {CHECK_THRESHOLD}x threshold.  Returns a process exit code."""
    base_path = os.path.join(REPO_ROOT, "BENCH_perf.json")
    if not os.path.exists(base_path):
        print("no committed BENCH_perf.json baseline — run "
              "`python -m benchmarks.run perf` first")
        return 1
    with open(base_path) as f:
        base = json.load(f)
    fresh = run(quick=quick)
    baseline_lanes = base.get("quick_baseline", {})
    if not baseline_lanes:
        print("baseline has no quick_baseline section — regenerate with "
              "`python -m benchmarks.run perf` (it embeds one)")
        return 1
    failures = []
    for lane, engines in baseline_lanes.items():
        got = fresh["lanes"].get(lane, {}).get("ms_per_round", {})
        ref = CHECK_REFERENCE.get(lane)
        if ref not in engines or ref not in got:
            failures.append(f"{lane}: reference engine {ref!r} missing")
            continue
        for engine, base_ms in engines.items():
            now = got.get(engine)
            if now is None:
                failures.append(f"{lane}/{engine}: missing from fresh run")
                continue
            base_rel = base_ms / engines[ref]
            now_rel = now / got[ref]
            limit = base_rel * CHECK_THRESHOLD
            status = "OK" if now_rel <= limit else "REGRESSION"
            print(f"  {lane}/{engine}: {now:.1f} ms/round, {now_rel:.2f}x "
                  f"of {ref} (baseline {base_rel:.2f}x, limit "
                  f"{limit:.2f}x) {status}")
            if now_rel > limit:
                failures.append(
                    f"{lane}/{engine}: {now_rel:.2f}x > {limit:.2f}x "
                    f"relative to {ref}")
    if failures:
        print("perf check FAILED:\n  " + "\n  ".join(failures))
        return 1
    print("perf check passed")
    return 0


def k10000_smoke() -> int:
    """CI bench-smoke: compile + run fleet-k10000 for 3 rounds under the
    bf16 ring (proves the K=10000 world builds, plans, compiles, and the
    quantized ring stays finite)."""
    from repro.core.scenarios import run_scenario
    t0 = time.perf_counter()
    r = run_scenario("fleet-k10000", rounds=3, eval_every=3)
    dt = time.perf_counter() - t0
    print(f"fleet-k10000 compile smoke: 3 rounds in {dt:.1f}s, "
          f"acc {r.final_accuracy():.3f}")
    return 0


def main(argv) -> int:
    if argv and argv[0] == "check":
        return check()
    if argv and argv[0] == "k10000-smoke":
        return k10000_smoke()
    if argv and argv[0] == "telemetry":
        return telemetry_lanes()
    quick = bool(int(os.environ.get("QUICK", "0")))
    run(quick=quick)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

"""Shared benchmark scaffolding: the simulation world matching Section V-A
(scaled for CPU; relative D_i/delta_i heterogeneity preserved exactly)."""
from __future__ import annotations

import json
import os
import time

import numpy as np

# measured on the 2-core CPU host: the legacy runtime executes this CNN's
# train step ~15% faster than the thunk runtime (EXPERIMENTS.md §Engine)
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_use_thunk_runtime=false")
try:                                 # compile-dominated 2-core host: reuse
    import jax                       # XLA programs across benchmark runs
    jax.config.update(
        "jax_compilation_cache_dir",
        os.path.join(os.path.dirname(__file__), "..", ".jax_cache"))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
except Exception:                    # pragma: no cover
    pass

from repro.channel.params import ChannelParams
from repro.core import run_simulation
from repro.data import partition_vehicles, synth_mnist

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# CPU-budget scaling knobs (documented in EXPERIMENTS.md §Repro):
N_TRAIN, N_TEST = 6000, 800
SCALE = 0.02              # shrinks every D_i proportionally
NOISE = 0.5
ROUNDS = 40
L_ITERS = 10
LR = 0.03
SEEDS = (0, 1, 2)         # the paper averages 3 experiments


def world(seed=0):
    tr_i, tr_l, te_i, te_l = synth_mnist(n_train=N_TRAIN, n_test=N_TEST,
                                         seed=0, noise=NOISE)
    p = ChannelParams()
    veh = partition_vehicles(tr_i, tr_l, p, seed=seed, scale=SCALE)
    return veh, te_i, te_l, p


def averaged_curves(scheme: str, rounds=ROUNDS, eval_every=4, params=None,
                    seeds=SEEDS, interpretation="mixing", l_iters=L_ITERS,
                    engine="batched"):
    """Mean accuracy/loss curves over seeds (paper: 3 experiments).

    Runs on the vehicle-batched wave engine by default (DESIGN.md §3) —
    identical event semantics to the serial engine, a fraction of the
    dispatches."""
    accs, losses, axes = [], [], []
    for seed in seeds:
        veh, te_i, te_l, p = world(seed)
        r = run_simulation(veh, te_i, te_l, scheme=scheme, rounds=rounds,
                           l_iters=l_iters, lr=LR, eval_every=eval_every,
                           seed=seed, params=params or p,
                           interpretation=interpretation, engine=engine)
        accs.append([a for _, a in r.acc_history])
        losses.append([l for _, l in r.loss_history])
        axes.append([rd for rd, _ in r.acc_history])
    # every seed must evaluate at the same rounds: np.mean would silently
    # average ragged rows element-by-position otherwise (or crash on a
    # ragged array), pairing round-8 accuracy with round-12 accuracy
    if any(ax != axes[0] for ax in axes[1:]):
        raise ValueError(
            "averaged_curves: per-seed eval rounds diverge — "
            + "; ".join(f"seed {s}: {ax}" for s, ax in zip(seeds, axes))
            + " — mean curves would mis-pair rounds; fix eval_every/rounds")
    return (axes[0], np.mean(accs, axis=0).tolist(),
            np.mean(losses, axis=0).tolist())


REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def save_result(name: str, payload: dict):
    """Write the artifact under ``benchmarks/results/`` and, for the
    committed (non-quick) artifacts, copy it to the repo root where the
    perf-trajectory tracker reads ``BENCH_*.json`` — results/ alone is
    invisible to it (ISSUE 5 satellite)."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    payload["timestamp"] = time.strftime("%Y-%m-%d %H:%M:%S")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    if name.startswith("BENCH_") and not name.endswith("_quick"):
        with open(os.path.join(REPO_ROOT, f"{name}.json"), "w") as f:
            json.dump(payload, f, indent=1)
    return path

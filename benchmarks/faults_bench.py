"""Fault-injection benchmark (ISSUE 10 acceptance artifact).

Two lanes over the device-resident ``engine="jit"`` mega-fleet
(DESIGN.md §16):

**Overhead** — the identical ``fleet-k1000`` world staged clean
(``faults=None``, the legacy program by cache identity) and under the
``flaky`` profile, warm ms/round compared.  The fault tables are baked
into the staged program as constants, so the bar is hard: the faulty
program may cost at most **+10% ms/round** over clean — ``main`` exits
nonzero past the bar, wiring the regression gate into CI.

**Accuracy under churn** — every admission policy (admit-all,
weighted-topk, budget, eps-bandit) on the ``fleet-k1000-flaky`` world at
equal rounds, against the clean admit-all reference.  This is where the
selection policies earn (or fail to earn) their keep: a policy that
scores data x compute x residence should degrade more gracefully than
admit-all when 8% of uploads drop and vehicles black out — EXPERIMENTS.md
§Faults reads the artifact honestly either way.  The throttled profile
rides along as an admit-all lane (partial epochs + 4x stragglers).

``python -m benchmarks.run faults [rounds]``; QUICK=1 swaps in
``quick-k5`` under the same flaky profile (the CI smoke artifact).
Writes ``benchmarks/results/BENCH_faults[_quick].json``.
"""
from __future__ import annotations

import time

from benchmarks.common import save_result
from repro.core.mafl import run_simulation
from repro.core.scenarios import build_world, get_scenario
from repro.selection import SelectionSpec

OVERHEAD_BAR_PCT = 10.0

# admission policies judged under churn (DESIGN.md §11 x §16); k/budget
# sized for fleet-k1000, shrunk for the QUICK world below
POLICIES = {
    "admit-all": None,
    "weighted-topk": SelectionSpec(policy="weighted-topk", k=250),
    "budget": SelectionSpec(policy="budget", budget=0.5),
    "eps-bandit": SelectionSpec(policy="eps-bandit", k=250, eps=0.1,
                                resel_every=8),
}
QUICK_POLICIES = {
    "admit-all": None,
    "weighted-topk": SelectionSpec(policy="weighted-topk", k=3),
}


def _timed(world, sc, rounds, *, selection=None, faults=None, seed=0):
    veh, te_i, te_l, p = world
    t0 = time.perf_counter()
    r = run_simulation(veh, te_i, te_l, scheme=sc.scheme, rounds=rounds,
                       l_iters=sc.l_iters, lr=sc.lr, params=p, seed=seed,
                       eval_every=rounds, engine="jit",
                       selection=selection, faults=faults)
    return time.perf_counter() - t0, r


def _overhead(world, sc, rounds) -> dict:
    stats = {}
    for name, faults in (("clean", None), ("flaky", "flaky")):
        cold, _ = _timed(world, sc, rounds, faults=faults)
        # min over two warm repeats: the 10% bar should gate the program,
        # not one noisy wall-clock sample on a loaded CI host
        warm = min(_timed(world, sc, rounds, faults=faults)[0]
                   for _ in range(2))
        stats[name] = {"cold_s": round(cold, 3), "warm_s": round(warm, 3),
                       "warm_ms_per_round": round(warm * 1e3 / rounds, 2)}
    pct = 100.0 * (stats["flaky"]["warm_s"] / stats["clean"]["warm_s"] - 1.0)
    stats["overhead_pct"] = round(pct, 1)
    stats["overhead_bar_pct"] = OVERHEAD_BAR_PCT
    stats["within_bar"] = pct <= OVERHEAD_BAR_PCT
    return stats


def _churn_entry(r, base_acc) -> dict:
    out = {"final_accuracy": float(r.final_accuracy()),
           "accuracy_delta_vs_clean": round(
               float(r.final_accuracy()) - base_acc, 4)}
    if "faults" in r.extras:
        out["fault_counts"] = r.extras["faults"]["counts"]
    return out


def run(rounds: int | None = None, quick: bool = False) -> dict:
    scenario = "quick-k5" if quick else "fleet-k1000"
    sc = get_scenario(scenario)
    rounds = rounds or (8 if quick else sc.rounds)
    policies = QUICK_POLICIES if quick else POLICIES
    print(f"building {scenario} (K={sc.K}) ...")
    world = build_world(sc, seed=0)

    payload = {"scenario": scenario, "K": sc.K, "rounds": rounds,
               "l_iters": sc.l_iters, "profile": "flaky"}

    print("overhead lane (clean vs flaky, jit) ...")
    payload["overhead"] = _overhead(world, sc, rounds)
    o = payload["overhead"]
    print(f"  clean {o['clean']['warm_ms_per_round']:.1f} ms/round, flaky "
          f"{o['flaky']['warm_ms_per_round']:.1f} ms/round -> "
          f"{o['overhead_pct']:+.1f}% (bar +{OVERHEAD_BAR_PCT:.0f}%)")

    print("accuracy-under-churn lane ...")
    _, clean = _timed(world, sc, rounds)
    base_acc = float(clean.final_accuracy())
    payload["clean_admit_all_accuracy"] = base_acc
    payload["policies"] = {}
    for name, spec in policies.items():
        _, r = _timed(world, sc, rounds, selection=spec, faults="flaky")
        entry = _churn_entry(r, base_acc)
        payload["policies"][name] = entry
        print(f"  {name:13s}: acc {entry['final_accuracy']:.3f} "
              f"({entry['accuracy_delta_vs_clean']:+.3f} vs clean), "
              f"counts {entry.get('fault_counts')}")

    # the compute-throttled profile as an admit-all rider: partial local
    # epochs + 4x stragglers + aggressive staleness cap
    _, rt = _timed(world, sc, rounds, faults="throttled")
    payload["throttled_admit_all"] = _churn_entry(rt, base_acc)
    print(f"  throttled/all: acc "
          f"{payload['throttled_admit_all']['final_accuracy']:.3f} "
          f"({payload['throttled_admit_all']['accuracy_delta_vs_clean']:+.3f}"
          f" vs clean)")

    path = save_result("BENCH_faults_quick" if quick else "BENCH_faults",
                       payload)
    print(f"wrote {path}")
    return payload


def main(rounds: int | None = None, quick: bool = False) -> int:
    payload = run(rounds=rounds, quick=quick)
    if not payload["overhead"]["within_bar"]:
        print(f"FAIL: fault-table overhead "
              f"{payload['overhead']['overhead_pct']:+.1f}% exceeds the "
              f"+{OVERHEAD_BAR_PCT:.0f}% ms/round bar")
        return 1
    return 0

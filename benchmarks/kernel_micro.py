"""Kernel microbenchmarks (beyond paper): wall time of the interpret-mode
Pallas kernels vs their jnp oracles on CPU, plus DERIVED TPU-v5e roofline
projections (the meaningful number — interpret mode is a correctness
vehicle, not a performance one).

Prints ``name,us_per_call,derived`` CSV rows per the harness contract.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cross_entropy import ops as ce_ops, ref as ce_ref
from repro.kernels.weighted_agg import ops as agg_ops, ref as agg_ref
from repro.roofline.analysis import V5E


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    # --- weighted_agg: the RSU update is HBM-bound; derived = projected
    #     v5e time for a 12B-param aggregation at 819 GB/s (3 streams)
    n = 1 << 20
    g = jax.random.normal(jax.random.PRNGKey(0), (n,))
    l = jax.random.normal(jax.random.PRNGKey(1), (n,))
    us_ref = _time(jax.jit(lambda a, b: agg_ref.weighted_agg(a, b, 0.5,
                                                             0.9)), g, l)
    v5e_12b_ms = 3 * 12e9 * 2 / V5E.hbm_bw * 1e3
    rows.append(("weighted_agg_ref_1M", us_ref,
                 f"v5e-12B-agg-projection={v5e_12b_ms:.1f}ms"))
    us_k = _time(lambda a, b: agg_ops.weighted_agg_leaf(a, b, 0.5, 0.9,
                                                        interpret=True),
                 g, l)
    rows.append(("weighted_agg_pallas_interp_1M", us_k,
                 "correctness-path (interpret)"))

    # --- cross_entropy at mistral-nemo vocab
    R, V = 256, 131072
    logits = jax.random.normal(jax.random.PRNGKey(0), (R, V))
    labels = jax.random.randint(jax.random.PRNGKey(1), (R,), 0, V)
    us_ref = _time(jax.jit(ce_ref.cross_entropy), logits, labels)
    hbm_us = R * V * 4 / V5E.hbm_bw * 1e6
    rows.append(("cross_entropy_ref_256x131k", us_ref,
                 f"v5e-stream-bound={hbm_us:.0f}us"))

    # --- end-to-end aggregation step over a real CNN pytree
    from repro.models.cnn import init_cnn
    from repro.core.aggregation import mafl_update
    p1 = init_cnn(jax.random.PRNGKey(0))
    p2 = init_cnn(jax.random.PRNGKey(1))
    us_tree = _time(lambda a, b: jax.block_until_ready(
        mafl_update(a, b, 0.5, 0.95)), p1, p2)
    rows.append(("mafl_update_cnn_tree", us_tree, "Eq.10+11 full pytree"))

    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()

"""Deliverable (g): render the dry-run JSON records into the roofline table
for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "mafl_agg"]


def load_records(mesh="pod16x16"):
    recs = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR,
                                           f"dryrun_*_{mesh}.json"))):
        recs.append(json.load(open(f)))
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 99))
    return recs


def fmt_seconds(s):
    if s >= 1:
        return f"{s:7.2f}s "
    if s >= 1e-3:
        return f"{s * 1e3:7.2f}ms"
    return f"{s * 1e6:7.2f}us"


def render(mesh="pod16x16"):
    recs = load_records(mesh)
    lines = []
    hdr = (f"| arch | shape | compute | memory | collective | bottleneck | "
           f"useful-FLOPs | fits 16G |")
    lines.append(hdr)
    lines.append("|---|---|---|---|---|---|---|---|")
    for r in recs:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_seconds(r['compute_s'])} "
            f"| {fmt_seconds(r['memory_s'])} "
            f"| {fmt_seconds(r['collective_s'])} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio'] * 100:5.1f}% "
            f"| {'yes' if r['fits_hbm'] else 'NO'} |")
    return "\n".join(lines)


def run():
    for mesh in ("pod16x16", "pod2x16x16"):
        recs = load_records(mesh)
        if not recs:
            continue
        print(f"\n### Roofline — {mesh} ({len(recs)} records)\n")
        print(render(mesh))
    return True


if __name__ == "__main__":
    run()

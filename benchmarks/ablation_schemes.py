"""Beyond-paper ablation: MAFL vs the wider aggregation-scheme zoo
(AFL / FedAsync / FedBuff) and the Eq. 10 interpretation (mixing vs literal),
single seed for CPU budget."""
from __future__ import annotations

import time

from benchmarks.common import averaged_curves, save_result


def run(quick=False):
    t0 = time.time()
    rounds = 16 if quick else 30
    out = {}
    for scheme in ("mafl", "afl", "fedasync", "fedbuff"):
        _, acc, loss = averaged_curves(scheme, rounds=rounds,
                                       eval_every=rounds // 2, seeds=(0,))
        out[scheme] = {"accuracy": acc, "loss": loss}
        print(f"{scheme:9s} acc@{rounds} = {acc[-1]:.3f}")
    _, acc_lit, _ = averaged_curves("mafl", rounds=rounds,
                                    eval_every=rounds // 2, seeds=(0,),
                                    interpretation="literal")
    out["mafl_literal_eq10"] = {"accuracy": acc_lit}
    print(f"{'mafl-lit':9s} acc@{rounds} = {acc_lit[-1]:.3f} "
          f"(literal Eq. 10: weight scales the parameter vector)")
    out["seconds"] = round(time.time() - t0, 1)
    save_result("ablation_schemes", out)
    return out


if __name__ == "__main__":
    run()

"""Vehicle-selection benchmark (ISSUE 4 acceptance artifact).

Measures the device-resident ``engine="jit"`` mega-fleet under each
admission policy at *equal rounds* on the identical ``fleet-k1000`` world,
writing ``benchmarks/results/BENCH_selection.json`` with ms/round, final
accuracy, and simulated completion time per policy.

Honest note on what moves and what doesn't (recorded in DESIGN.md §11):
the engines already train **only consumed uploads** (the PR-1 dry-run
consumed-set), so at rounds << K selection cannot shrink the training work
below one local update per round — wall-clock ms/round stays roughly flat
(compile time does drop with the admitted fleet).  Selection's measured
wins are the sequel papers' claims instead: higher accuracy at equal
rounds (the admitted fleet carries more data/compute) and much lower
*simulated* time-to-round (admitted vehicles have shorter delays).

``python -m benchmarks.run selection [rounds]``; QUICK=1 swaps in
``quick-k5`` through serial/batched/jit with weighted-topk (the CI smoke
artifact, which also proves the cross-engine selection path end-to-end).
"""
from __future__ import annotations

import time

from benchmarks.common import save_result
from repro.core.mafl import run_simulation
from repro.core.scenarios import build_world, get_scenario
from repro.selection import SelectionSpec

POLICIES = {
    "admit-all": None,
    "weighted-topk": SelectionSpec(policy="weighted-topk", k=250),
    "budget": SelectionSpec(policy="budget", budget=0.5),
}


def _timed(world, sc, engine, rounds, selection, seed=0):
    veh, te_i, te_l, p = world
    t0 = time.perf_counter()
    r = run_simulation(veh, te_i, te_l, scheme=sc.scheme, rounds=rounds,
                       l_iters=sc.l_iters, lr=sc.lr, params=p, seed=seed,
                       eval_every=rounds, engine=engine, selection=selection)
    return time.perf_counter() - t0, r


def _bench(world, sc, engine, rounds, selection):
    cold, r = _timed(world, sc, engine, rounds, selection)
    warm, r = _timed(world, sc, engine, rounds, selection)
    admitted = (r.report.selection["n_admitted_final"]
                if selection is not None else sc.K)
    return {
        "cold_s": round(cold, 3),
        "warm_s": round(warm, 3),
        "cold_ms_per_round": round(cold * 1e3 / rounds, 2),
        "warm_ms_per_round": round(warm * 1e3 / rounds, 2),
        "final_accuracy": float(r.final_accuracy()),
        "n_admitted": int(admitted),
        # simulated seconds until the last consumed arrival — selection
        # admits low-delay vehicles, so equal rounds complete far sooner
        # on the simulated clock
        "simulated_final_time_s": round(float(r.rounds[-1].time), 3),
    }


def run(rounds: int | None = None, quick: bool = False) -> dict:
    scenario = "quick-k5" if quick else "fleet-k1000"
    sc = get_scenario(scenario)
    rounds = rounds or (8 if quick else sc.rounds)
    print(f"building {scenario} (K={sc.K}) ...")
    world = build_world(sc, seed=0)

    payload = {"scenario": scenario, "K": sc.K, "rounds": rounds,
               "l_iters": sc.l_iters, "policies": {}}

    if quick:
        # CI smoke: the same small world with topk through all three
        # single-RSU engines — proves the cross-engine selection path
        spec = SelectionSpec(policy="weighted-topk", k=3)
        for engine in ("serial", "batched", "jit"):
            stats = _bench(world, sc, engine, rounds, spec)
            payload["policies"][f"weighted-topk/{engine}"] = stats
            print(f"  topk/{engine:8s}: warm {stats['warm_s']:6.2f}s "
                  f"({stats['warm_ms_per_round']:.1f} ms/round, "
                  f"{stats['n_admitted']} admitted)")
        stats = _bench(world, sc, "jit", rounds, None)
        payload["policies"]["admit-all/jit"] = stats
        print(f"  all /jit     : warm {stats['warm_s']:6.2f}s "
              f"({stats['warm_ms_per_round']:.1f} ms/round)")
    else:
        for name, spec in POLICIES.items():
            stats = _bench(world, sc, "jit", rounds, spec)
            payload["policies"][name] = stats
            print(f"  {name:13s}: cold {stats['cold_s']:7.1f}s  warm "
                  f"{stats['warm_s']:7.1f}s  "
                  f"({stats['warm_ms_per_round']:.1f} ms/round, "
                  f"{stats['n_admitted']}/{sc.K} admitted, final acc "
                  f"{stats['final_accuracy']:.3f}, simulated "
                  f"{stats['simulated_final_time_s']:.1f}s)")
        base = payload["policies"]["admit-all"]
        for name in ("weighted-topk", "budget"):
            st = payload["policies"][name]
            key = name.replace("-", "_")
            payload[f"speedup_{key}"] = round(
                base["warm_ms_per_round"] / st["warm_ms_per_round"], 2)
            payload[f"simulated_speedup_{key}"] = round(
                base["simulated_final_time_s"]
                / st["simulated_final_time_s"], 2)
            payload[f"accuracy_delta_{key}"] = round(
                st["final_accuracy"] - base["final_accuracy"], 4)
        print(f"  vs admit-all: topk {payload['speedup_weighted_topk']}x "
              f"wall / {payload['simulated_speedup_weighted_topk']}x "
              f"simulated / {payload['accuracy_delta_weighted_topk']:+.3f} "
              f"acc; budget {payload['speedup_budget']}x wall / "
              f"{payload['simulated_speedup_budget']}x simulated / "
              f"{payload['accuracy_delta_budget']:+.3f} acc")

    path = save_result("BENCH_selection_quick" if quick
                       else "BENCH_selection", payload)
    print(f"wrote {path}")
    return payload

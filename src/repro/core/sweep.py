"""Multi-world vmap sweep engine: ``engine="vmap"`` (DESIGN.md §15).

Every sweep the paper demands — the Fig. 5 beta ablation, the 3-seed
averaging behind every reported curve, selection-policy comparisons — is a
set of *independent* worlds that differ only in scalars (beta, seed,
channel constants) or static plan data (admission tables).  Running them
serially pays one compiled program and one Python round-trip per world.
This engine batches W worlds through ONE compiled flat-path program:

- **World axis on the flat buffer.**  The packed ``ParamLayout`` already
  broadcasts leading batch axes, so the W models are a single ``[W, P]``
  buffer, the slot queues are ``[W, K]`` columns, and the event-loop scan
  body is ``jax.vmap`` of the solo per-world step.

- **Padded plan tables.**  The host f64 planners emit fixed-shape tables
  (``FleetPlan.tables()``, ``SelectionPlan.tables()`` — shapes depend only
  on ``(M, K)``, PLN003-probed) that stack along a leading world axis;
  ragged residue (gain-table heights) zero-pads to the batch maximum.

- **Bitwise per-world conformance.**  World ``w`` of a batch reproduces
  its solo ``engine="jit"`` run bit-for-bit — final parameters, accuracy
  history, event structure (pinned by ``tests/test_vmap_sweep.py``; the
  *reported* per-event delay floats are f32-ulp instead: the union
  segmentation changes the scan body's fusion context, and XLA:CPU's
  context-dependent FMA contraction can move reporting-only expressions
  by an ulp — holds under the default thunk runtime, the tier-1
  environment; the legacy CPU runtime loses bit equality outright, see
  EXPERIMENTS.md §Sweep).  Three rules make that possible: (1) the
  program splits its scans at the *union* of all worlds' wave/readmit/
  checkpoint boundaries — scan splitting is carry-transparent, so extra
  split points are bitwise no-ops for the other worlds; (2) a channel
  scalar equal across the batch stays a trace-time constant (the exact
  solo codepath — and a W=1 batch degenerates to the solo program), while
  a differing one becomes a traced ``[W]`` input (linear/pow-base/log uses
  only — bitwise-stable under vmap on this backend); (3) worlds sharing a
  timeline (same seed/plan/data) train as one nested ``vmap`` block,
  worlds that don't get their own solo-shaped ``_wave_train`` call.

- **Constant path-loss exponent.**  ``ChannelParams.alpha`` is a pow
  *exponent*, and XLA special-cases constant exponents (``x**2 -> x*x``,
  ``x**-0.5 -> rsqrt``) — tracing it would change every world's codegen.
  The engine therefore requires ``alpha`` uniform across the batch.

Always the flat layout and the in-scan mix (the CPU-default form that
reproduces the golden digests); no ``use_kernel``/``mesh``/``metrics`` —
those stay solo-tier features and are rejected loudly, never silently
dropped.  The entry points are :class:`repro.core.scenarios.SweepSpec` /
``run_sweep`` (grids over a base scenario) and ``run_scenario(...,
engine="vmap")`` (a W=1 batch).
"""
from __future__ import annotations

import time
from collections import OrderedDict
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel import Mobility, slot_gain_table
from repro.core import client as client_mod
from repro.core.client import Vehicle
from repro.core.jit_engine import _SUPPORTED_SCHEMES, _wave_train, plan_fleet
from repro.core.server import DEFAULT_FEDASYNC_MIX, RoundRecord


def stack_plan_tables(tables: Sequence[dict]) -> dict:
    """Stack per-world plan tables along a leading world axis.

    Every world must emit the same keys with identical ``(shape, dtype)``
    — the PLN003 invariant; a mismatch raises with the offending field
    instead of silently broadcasting."""
    if not tables:
        raise ValueError("stack_plan_tables: empty world batch")
    keys = list(tables[0])
    for i, t in enumerate(tables[1:], 1):
        if list(t) != keys:
            raise ValueError(
                f"plan tables not stackable: world 0 has fields {keys}, "
                f"world {i} has {list(t)} — planner emissions must be "
                "field-stable across worlds (rule PLN003)")
    out = {}
    for k in keys:
        arrs = [np.asarray(t[k]) for t in tables]
        base = (arrs[0].shape, arrs[0].dtype)
        for i, a in enumerate(arrs[1:], 1):
            if (a.shape, a.dtype) != base:
                raise ValueError(
                    f"plan table {k!r} not stackable: world 0 is {base}, "
                    f"world {i} is {(a.shape, a.dtype)} — planner shapes "
                    "must depend only on (M, K) (rule PLN003)")
        out[k] = np.stack(arrs)
    return out


def stack_gain_tables(ps, seeds, n_slots_list) -> np.ndarray:
    """``f32[W, S_max, K]`` slot-gain tables, zero-padded to the batch's
    tallest table — padded rows are unreachable (the Eq. 3 slot clip is
    bounded by each world's own ``n_slots``)."""
    S = max(int(n) for n in n_slots_list)
    K = ps[0].K
    out = np.zeros((len(ps), S, K), np.float32)
    for w, (p, seed, ns) in enumerate(zip(ps, seeds, n_slots_list)):
        out[w, :int(ns)] = np.asarray(slot_gain_table(p, seed, int(ns)),
                                      np.float32)
    return out


# per-world ChannelParams scalars that enter the compiled program's f32
# arithmetic.  Uniform across the batch -> trace-time constant (exact solo
# codepath); varying -> traced [W] input.  All appear linearly, as pow
# *base*, or inside log2 — lowerings that are operand-stable whether the
# scalar is a constant or a traced input (pinned by test_vmap_sweep).
def _world_scalars(p, plan) -> dict:
    return {
        "beta": float(p.beta), "gamma": float(p.gamma),
        "zeta": float(p.zeta), "v": float(p.v),
        "coverage": float(p.coverage),
        "dy2H2": float(p.d_y ** 2 + p.H ** 2),
        "p_m": float(p.p_m), "sigma2": float(p.sigma2),
        "B": float(p.B), "model_bits": float(p.model_bits),
        "n_slots": int(plan.n_slots),
    }


# ---------------------------------------------------------------------------
# the compiled multi-world program
# ---------------------------------------------------------------------------
_SWEEP_CACHE: OrderedDict = OrderedDict()
_SWEEP_CACHE_SIZE = 8


def _build_sweep_program(plans, ps, groups, *, scheme, interpretation,
                         layout, ring_dtype, eval_rounds, fedasync_mix):
    """One compiled program for the whole W-world batch.  Structure (wave
    partitions, boundary union, groups) is trace-time constant; per-world
    values (queues, gains, minibatches, varied scalars) are inputs."""
    W = len(plans)
    M = len(plans[0].veh)
    K = ps[0].K
    d_list = [np.asarray(plan.dl_round) for plan in plans]

    bf16 = ring_dtype == "bf16"
    store_dtype = jnp.bfloat16 if bf16 else jnp.float32
    store = ((lambda x: x.astype(jnp.bfloat16)) if bf16 else (lambda x: x))

    # scalar split: uniform -> closure constant, varying -> traced [W]
    scal = [_world_scalars(p, plan) for p, plan in zip(ps, plans)]
    varied_names = tuple(sorted(
        n for n in scal[0] if len({s[n] for s in scal}) > 1))
    consts = {n: (int(v) if n == "n_slots" else jnp.float32(v))
              for n, v in scal[0].items() if n not in varied_names}
    f_mix = jnp.float32(fedasync_mix)
    alpha_pl = jnp.float32(ps[0].alpha)        # uniform (validated): pow exp

    # selection (DESIGN.md §11): stacked [W, M, K] admission tables — a
    # policy-free world is the all-True row, and where(True, x, inf) == x
    # bitwise, so mixing selection and no-selection worlds is exact
    any_sel = any(plan.sel is not None and not plan.sel.is_noop
                  for plan in plans)
    any_state = any(plan.sel is not None and not plan.sel.is_noop
                    and plan.sel.spec.policy == "eps-bandit"
                    for plan in plans)
    readmit_at = []
    sel_tabs = []
    for plan in plans:
        if plan.sel is not None and not plan.sel.is_noop:
            readmit_at.append({b: np.asarray(n, np.int32)
                               for b, n, _ in plan.sel.boundaries if len(n)})
            sel_tabs.append(plan.sel.tables(M)["mask"])
        else:
            readmit_at.append({})
            sel_tabs.append(np.ones((M, K), bool))
    if any_sel:
        adm_tab = jnp.asarray(np.stack(sel_tabs))

    # rounds whose post-round [W, P] snapshot must materialize: the union
    # of every world's later-wave payload rounds plus the eval rows
    needed = set(int(x) for x in eval_rounds)
    for plan, d in zip(plans, d_list):
        for T, _s, _e in plan.waves:
            needed |= {int(d[t]) + 1 for t in T if d[t] >= 0}

    # scan-split union: every world's wave boundaries, re-admission points
    # and checkpoints.  Splitting a scan is carry-transparent, so a point
    # another world needs is a bitwise no-op for this one.
    pts = {0, M} | needed
    for plan, ra in zip(plans, readmit_at):
        for _T, s, e in plan.waves:
            pts |= {s, e}
        pts |= set(ra)
    pts = sorted(b for b in pts if 0 <= b <= M)

    # per-(group, wave-start) static training-block data, precomputed here
    # so the traced program body does no host math on plan tables (the
    # boundary lint's taint rules, DESIGN.md §13); members share the group
    # plan's partition by the grouping key
    group_train = {}
    for gi, G in enumerate(groups):
        d_g = d_list[G[0]]
        for T, s, _e in plans[G[0]].waves:
            if not len(T):
                continue
            T_np = np.asarray(T, np.int32)
            pay = tuple(int(x) for x in (d_g[T_np] + 1))
            group_train[(gi, s)] = (T_np, pay, len(set(pay)) == 1)

    # per-world trace-time constants for the boundary re-admission helper
    # (solo codepath: readmits run at trace level with baked scalars)
    wconsts = [{n: (int(v) if n == "n_slots" else jnp.float32(v))
                for n, v in s.items()} for s in scal]

    def eq36_upload_delay(gains_w, x0_w, idx, t_up, S):
        """Eq. 3-6 re-schedule pipeline — expression-for-expression the
        solo engine's (``jit_engine.eq36_upload_delay``); ``S`` resolves
        each channel scalar to the world's constant or traced value."""
        slot = jnp.clip(t_up.astype(jnp.int32), 0, S["n_slots"] - 1)
        gain = gains_w[slot, idx]
        dx = x0_w[idx] + S["v"] * t_up                        # Eq. 3
        dx = jnp.mod(dx + S["coverage"],
                     2.0 * S["coverage"]) - S["coverage"]     # re-entry wrap
        dist = jnp.sqrt(dx * dx + S["dy2H2"])                 # Eq. 4
        snr = S["p_m"] * gain * dist ** (-alpha_pl) / S["sigma2"]
        rate = S["B"] * jnp.log2(1.0 + snr)                   # Eq. 5
        return S["model_bits"] / jnp.maximum(rate, 1e-12)     # Eq. 6

    def aggregate(g_w, loc, t, cu, cl, dl_t, S):
        """One arrival's Eq. 10+11 mix on the packed [P] buffer — the solo
        in-scan form verbatim (the one the golden digests pin)."""
        if scheme == "mafl":
            weight = S["gamma"] ** (cu - 1.0) * S["zeta"] ** (cl - 1.0)
        else:
            weight = jnp.float32(1.0)
        if scheme == "mafl" and interpretation == "literal":
            new = jax.tree_util.tree_map(
                lambda a, b: (S["beta"] * a.astype(jnp.float32) +
                              (1.0 - S["beta"]) * weight *
                              b.astype(jnp.float32)).astype(a.dtype),
                g_w, loc)
            return new, weight
        if scheme == "mafl":
            alpha = jnp.clip((1.0 - S["beta"]) * weight, 0.0, 1.0)
        elif scheme == "afl":
            alpha = 1.0 - S["beta"]
        else:                                                 # fedasync
            stale = jnp.maximum(t - dl_t, 0.0)
            alpha = f_mix * (stale + 1.0) ** (-0.5)
        new = jax.tree_util.tree_map(
            lambda a, b: ((1.0 - alpha) * a.astype(jnp.float32) +
                          alpha * b.astype(jnp.float32)).astype(a.dtype),
            g_w, loc)
        return new, weight

    def program(w0s, gains, x0s, qt, qdl, qcu, qcl, g_imgs, g_labs, lrs,
                var):
        local_scan = client_mod._local_scan
        g = layout.pack(w0s)                        # f32[W, P] masters
        locals_buf = jnp.zeros((W, M, layout.P), store_dtype)
        snaps = {0: store(g)}
        rs = rc = None
        if any_state:
            rs = jnp.zeros((W, K), jnp.float32)
            rc = jnp.zeros((W, K), jnp.float32)
        traces = []

        def make_body(locals_buf):
            # fresh body per segment — locals_buf rebinds per wave (the
            # lax.scan traced-body cache pitfall, DESIGN.md §9)
            stat = {"qcl": qcl, "x0": x0s, "gains": gains,
                    "lb": locals_buf, "var": var}
            if any_sel:
                stat["adm"] = adm_tab

            def body(carry, r):
                def step_w(cw, sw):
                    # the solo flat in-scan body over one world's slices
                    S = dict(consts)
                    S.update(sw["var"])
                    g_w, qt_w, qdl_w, qcu_w = (cw["g"], cw["qt"],
                                               cw["qdl"], cw["qcu"])
                    i = jnp.argmin(qt_w)                      # pop
                    t, cu, cl, dl_t = (qt_w[i], qcu_w[i], sw["qcl"][i],
                                       qdl_w[i])
                    g_w, weight = aggregate(g_w, sw["lb"][r], t, cu, cl,
                                            dl_t, S)
                    out = {"g": g_w}
                    if any_state:
                        rew = (S["gamma"] ** (cu - 1.0)
                               * S["zeta"] ** (cl - 1.0))
                        out["rs"] = cw["rs"].at[i].add(rew)
                        out["rc"] = cw["rc"].at[i].add(1.0)
                    t_up = t + cl
                    cu_new = eq36_upload_delay(sw["gains"], sw["x0"], i,
                                               t_up, S)
                    t_new = t_up + cu_new
                    if any_sel:
                        t_new = jnp.where(sw["adm"][r, i], t_new, jnp.inf)
                    out["qt"] = qt_w.at[i].set(t_new)
                    out["qdl"] = qdl_w.at[i].set(t)
                    out["qcu"] = qcu_w.at[i].set(cu_new)
                    return out, (i, t, cu, cl, dl_t, weight)
                return jax.vmap(step_w)(carry, stat)
            return body

        def readmit_world(qt, qdl, qcu, w, A, t_b):
            # boundary re-admission for ONE world — trace-level, with that
            # world's baked scalar constants (the solo readmit verbatim)
            A = jnp.asarray(A)
            t_up = t_b + qcl[w, A]
            cu_new = eq36_upload_delay(gains[w], x0s[w], A, t_up,
                                       wconsts[w])
            return (qt.at[w, A].set(t_up + cu_new),
                    qdl.at[w, A].set(t_b), qcu.at[w, A].set(cu_new))

        a = 0
        for b in pts:
            if b > a:
                carry = {"g": g, "qt": qt, "qdl": qdl, "qcu": qcu}
                if any_state:
                    carry["rs"], carry["rc"] = rs, rc
                with jax.named_scope(f"sweep_scan_{a}_{b}"):
                    carry, ys = jax.lax.scan(make_body(locals_buf), carry,
                                             jnp.arange(a, b))
                g, qt, qdl, qcu = (carry["g"], carry["qt"], carry["qdl"],
                                   carry["qcu"])
                if any_state:
                    rs, rc = carry["rs"], carry["rc"]
                traces.append(ys)
            if b > 0 and b in needed:
                snaps[b] = store(g)
            for w, ra in enumerate(readmit_at):
                if b in ra:
                    # t_b = world w's boundary pop timestamp (last of the
                    # sub-segment that just ran)
                    qt, qdl, qcu = readmit_world(qt, qdl, qcu, w, ra[b],
                                                 traces[-1][1][-1, w])
            for gi, G in enumerate(groups):
                tg = group_train.get((gi, b))
                if tg is None:
                    continue
                T_np, pay_rounds, shared = tg
                imgs_g, labs_g = g_imgs[gi], g_labs[gi]
                lr_g = lrs[G[0]]        # equal across the group (group key)
                T_dev = jnp.asarray(T_np)
                if len(G) == 1:
                    # singleton group: the exact solo wave-training block
                    w = G[0]
                    if shared:
                        pay = layout.unpack(snaps[pay_rounds[0]][w])
                    else:
                        pay = layout.unpack(jnp.stack(
                            [snaps[pr][w] for pr in pay_rounds]))
                    train = _wave_train(local_scan, None, len(T_np), shared)
                    with jax.named_scope(f"sweep_wave_{b}_w{w}"):
                        loc, _ = train(pay, imgs_g[T_np], labs_g[T_np],
                                       lr_g)
                    locals_buf = locals_buf.at[w, T_dev].set(
                        layout.pack(loc, dtype=store_dtype))
                    continue
                # shared-timeline group: nested vmap — worlds stack on the
                # payload axis, members broadcast (shared payload) or stack
                G_np = np.asarray(G, np.int32)
                G_dev = jnp.asarray(G_np)
                if shared:
                    pay = layout.unpack(snaps[pay_rounds[0]][G_np])
                    vf = jax.vmap(jax.vmap(local_scan,
                                           in_axes=(None, 0, 0, None)),
                                  in_axes=(0, None, None, None))
                else:
                    rows = jnp.stack([snaps[pr][G_np]
                                      for pr in pay_rounds], axis=1)
                    pay = layout.unpack(rows)       # leaves [nG, |T|, ...]
                    vf = jax.vmap(jax.vmap(local_scan,
                                           in_axes=(0, 0, 0, None)),
                                  in_axes=(0, None, None, None))
                with jax.named_scope(f"sweep_wave_{b}_g{gi}"):
                    loc, losses = vf(pay, imgs_g[T_np], labs_g[T_np], lr_g)
                    loc, _ = jax.lax.optimization_barrier((loc, losses))
                locals_buf = locals_buf.at[
                    G_dev[:, None], T_dev[None, :]].set(
                    layout.pack(loc, dtype=store_dtype))
            a = b

        trace = tuple(jnp.concatenate([tr[k] for tr in traces])
                      for k in range(6))             # each [M, W]
        evals = jnp.stack([snaps[rr] for rr in eval_rounds])
        ret = (layout.unpack(g), evals, trace)
        if any_state:
            ret = ret + ((rs, rc),)
        return ret

    return jax.jit(program)


def _get_sweep_program(plans, ps, lrs, groups, *, scheme, interpretation,
                       layout, ring_dtype, eval_rounds, group_shapes):
    key = (tuple((plan.waves, tuple(plan.dl_round.tolist()),
                  tuple(plan.veh.tolist()), plan.n_slots, p, lr,
                  None if plan.sel is None else plan.sel.signature())
                 for plan, p, lr in zip(plans, ps, lrs)),
           tuple(tuple(G) for G in groups), group_shapes, scheme,
           interpretation, layout.signature(), ring_dtype, eval_rounds,
           client_mod._local_scan)
    prog = _SWEEP_CACHE.get(key)
    if prog is None:
        prog = _build_sweep_program(
            plans, ps, groups, scheme=scheme, interpretation=interpretation,
            layout=layout, ring_dtype=ring_dtype, eval_rounds=eval_rounds,
            fedasync_mix=DEFAULT_FEDASYNC_MIX)
        _SWEEP_CACHE[key] = prog
        while len(_SWEEP_CACHE) > _SWEEP_CACHE_SIZE:
            _SWEEP_CACHE.popitem(last=False)
    else:
        _SWEEP_CACHE.move_to_end(key)
    return prog


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------
def run_simulation_vmap(worlds, *, eval_every: int = 10, batch_size: int = 128,
                        progress=None, metrics=None):
    """Run ``W = len(worlds)`` independent single-RSU worlds as one vmap
    batch; ``worlds`` is a sequence of ``(Scenario, seed)`` pairs (built
    by :func:`repro.core.scenarios.run_sweep`).  Returns one ``SimResult``
    per world, in order, each carrying an ``engine="vmap"`` RunReport.

    Uniform across the batch (validated, clear errors): ``K``, ``rounds``,
    ``scheme``, ``ring_dtype``, topology (single-RSU), and the path-loss
    exponent ``alpha``.  Free to vary per world: seed, any linear channel
    scalar (beta/gamma/zeta/v/coverage/geometry/power/noise/bandwidth/
    model bits), ``lr``, ``l_iters``, data fields, and the selection spec.

    ``progress`` fires post-hoc as ``progress(world_index, round, acc)``.
    """
    from repro.core.flat import ParamLayout
    from repro.core.mafl import SimResult, evaluate
    from repro.core.scenarios import build_world
    from repro.models.cnn import init_cnn
    from repro.telemetry import RunReport, memory_stats
    from repro.telemetry.report import wave_stats
    from repro.telemetry.spec import metrics_requested
    from repro.telemetry.timers import PhaseTimers

    if metrics_requested(metrics):
        raise ValueError(
            "engine='vmap' does not collect device telemetry yet: the "
            "metrics accumulators are per-world scan state the sweep tier "
            "does not carry (DESIGN.md §15) — run the world solo with "
            "engine='jit', metrics='on'")
    worlds = list(worlds)
    if not worlds:
        raise ValueError("run_simulation_vmap: empty world batch")
    W = len(worlds)
    scs = [sc for sc, _seed in worlds]
    seeds = [int(seed) for _sc, seed in worlds]
    sc0 = scs[0]
    for field, label in (("n_rsus", "topology"), ("K", "fleet size"),
                         ("rounds", "rounds"), ("scheme", "scheme"),
                         ("ring_dtype", "ring_dtype")):
        vals = {getattr(sc, field) for sc in scs}
        if len(vals) > 1:
            raise ValueError(
                f"engine='vmap' needs a uniform {label} across the world "
                f"batch (got {field}={sorted(map(str, vals))}): these set "
                "the compiled program's shapes/structure — split the sweep")
    if sc0.n_rsus > 1:
        raise ValueError(
            "engine='vmap' is single-RSU only: corridor worlds carry "
            "per-RSU cohort rows the [W, P] world axis does not model "
            "(DESIGN.md §15) — use engine='corridor' per world")
    if sc0.scheme not in _SUPPORTED_SCHEMES:
        raise ValueError(
            f"engine='vmap' supports schemes {_SUPPORTED_SCHEMES}, not "
            f"{sc0.scheme!r} (fedbuff keeps host-side buffer state)")
    from repro.faults import scenario_faults
    if any(scenario_faults(sc) is not None for sc in scs):
        raise ValueError(
            "engine='vmap' does not support fault injection yet: the "
            "fault folds (admission, staleness-cap, partial epochs) are "
            "per-world program structure the [W, P] world axis does not "
            "model (DESIGN.md §15/§16) — run the world solo with "
            "engine='jit', faults=...")
    if sc0.ring_dtype not in ("f32", "bf16"):
        raise ValueError(f"unknown ring_dtype {sc0.ring_dtype!r}")
    ps = [sc.channel() for sc in scs]
    if len({float(p.alpha) for p in ps}) > 1:
        raise ValueError(
            "engine='vmap' needs a uniform path-loss exponent alpha: it "
            "is a pow exponent XLA special-cases when constant, so a "
            "traced per-world alpha would change the solo worlds' codegen "
            "(DESIGN.md §15) — sweep it serially")
    M = sc0.rounds
    K = sc0.K

    timers = PhaseTimers()
    _t0 = time.perf_counter()
    # -- host staging: per-world worlds, plans, padded tables --------------
    built = [build_world(sc, seed=seed) for sc, seed in worlds]
    with timers.phase("plan"):
        plans = [plan_fleet(p, seed, M, sc.selection_spec())
                 for sc, seed, p in zip(scs, seeds, ps)]
    tabs = stack_plan_tables([plan.tables() for plan in plans])

    # -- timeline groups: worlds whose training blocks can share one
    #    nested-vmap call.  The key pins everything the minibatch stacks
    #    and wave payload indices depend on: the data world, the seed, the
    #    pop/wave structure, and lr (one traced scalar per group).
    fleet_batches = [min(batch_size, min(d.size for d in veh))
                     for (veh, _i, _l, _p) in built]
    group_of = {}
    groups: list[list[int]] = []
    for w, (sc, seed) in enumerate(worlds):
        key = (seed, sc.n_train, sc.n_test, sc.noise, sc.scale,
               sc.dirichlet_alpha, sc.max_per_vehicle, ps[w].K,
               ps[w].platoon, sc.l_iters, sc.lr, fleet_batches[w],
               plans[w].waves, tuple(plans[w].veh.tolist()),
               tuple(plans[w].dl_round.tolist()))
        if key in group_of:
            groups[group_of[key]].append(w)
        else:
            group_of[key] = len(groups)
            groups.append([w])

    # -- one minibatch stack per GROUP (members share data + pop order;
    #    same per-vehicle RNG streams as every other engine, DESIGN.md §3)
    _t1 = time.perf_counter()
    g_imgs, g_labs = [], []
    for G in groups:
        w = G[0]
        veh_data = built[w][0]
        clients = [Vehicle(d, lr=scs[w].lr, batch_size=fleet_batches[w],
                           seed=seeds[w]) for d in veh_data]
        im_list, lab_list = [], []
        for r in range(M):
            im, lab = clients[plans[w].veh[r]].sample_batches(scs[w].l_iters)
            im_list.append(im)
            lab_list.append(lab)
        g_imgs.append(jnp.asarray(np.stack(im_list)))
        g_labs.append(jnp.asarray(np.stack(lab_list)))
    group_shapes = tuple(x.shape for x in g_imgs)

    # -- stacked device inputs ---------------------------------------------
    w0_list = [init_cnn(jax.random.PRNGKey(seed)) for seed in seeds]
    w0s = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *w0_list)
    layout = ParamLayout.from_tree(w0_list[0])
    gains = jnp.asarray(stack_gain_tables(ps, seeds,
                                          [plan.n_slots for plan in plans]))
    x0s = jnp.asarray(np.stack([Mobility(p).x0 for p in ps]), jnp.float32)
    qt = jnp.asarray(tabs["q0_time"], jnp.float32)
    qdl = jnp.asarray(tabs["q0_download_time"], jnp.float32)
    qcu = jnp.asarray(tabs["q0_upload_delay"], jnp.float32)
    qcl = jnp.asarray(tabs["q0_train_delay"], jnp.float32)
    lrs = jnp.asarray(np.asarray([sc.lr for sc in scs], np.float32))

    scal = [_world_scalars(p, plan) for p, plan in zip(ps, plans)]
    varied_names = tuple(sorted(
        n for n in scal[0] if len({s[n] for s in scal}) > 1))
    var = {n: jnp.asarray(np.asarray(
        [s[n] for s in scal],
        np.int32 if n == "n_slots" else np.float32)) for n in varied_names}

    eval_rounds = tuple(rr for rr in range(1, M + 1)
                        if rr % eval_every == 0 or rr == M)
    prog = _get_sweep_program(
        plans, ps, [sc.lr for sc in scs], groups, scheme=sc0.scheme,
        interpretation="mixing", layout=layout, ring_dtype=sc0.ring_dtype,
        eval_rounds=eval_rounds, group_shapes=group_shapes)
    args = (w0s, gains, x0s, qt, qdl, qcu, qcl, tuple(g_imgs),
            tuple(g_labs), lrs, var)
    timers.add("stage", time.perf_counter() - _t1)

    with timers.phase("run"):
        out = jax.block_until_ready(prog(*args))
    if any(plan.sel is not None and not plan.sel.is_noop
           and plan.sel.spec.policy == "eps-bandit" for plan in plans):
        g_tree, evals, trace, (dev_rs, dev_rc) = out
    else:
        g_tree, evals, trace = out
        dev_rs = dev_rc = None
    t_veh, t_time, t_cu, t_cl, t_dlt, t_w = (np.asarray(x) for x in trace)

    # -- per-world divergence guards + result split ------------------------
    results = []
    with timers.phase("eval"):
        for w, (sc, seed) in enumerate(worlds):
            plan_w = plans[w]
            if not np.array_equal(t_veh[:, w], tabs["veh"][w]):
                bad = int(np.argmax(t_veh[:, w] != tabs["veh"][w]))
                raise RuntimeError(
                    f"vmap engine: world {w} device pop order diverged "
                    f"from the host dry run at round {bad} (device vehicle "
                    f"{int(t_veh[bad, w])}, host {int(tabs['veh'][w][bad])})")
            if not np.allclose(t_time[:, w], tabs["times"][w],
                               rtol=1e-4, atol=1e-3):
                bad = int(np.argmax(~np.isclose(
                    t_time[:, w], tabs["times"][w], rtol=1e-4, atol=1e-3)))
                raise RuntimeError(
                    f"vmap engine: world {w} device event times diverged "
                    f"from the host dry run at round {bad}: "
                    f"{t_time[bad, w]} vs {tabs['times'][w][bad]}")
            if (plan_w.sel is not None and not plan_w.sel.is_noop
                    and plan_w.sel.spec.policy == "eps-bandit"):
                exp_rs, exp_rc = plan_w.sel_bandit
                if not np.array_equal(np.asarray(dev_rc)[w], exp_rc):
                    raise RuntimeError(
                        f"vmap engine: world {w} bandit arrival counts "
                        "diverged from the host selection replay")
                if not np.allclose(np.asarray(dev_rs)[w], exp_rs,
                                   rtol=1e-4, atol=1e-3):
                    raise RuntimeError(
                        f"vmap engine: world {w} bandit reward "
                        "accumulators diverged from the host replay")
            final_w = jax.tree_util.tree_map(lambda x: x[w], g_tree)
            if sc0.ring_dtype == "bf16" and not all(
                    bool(jnp.isfinite(x).all())
                    for x in jax.tree_util.tree_leaves(final_w)):
                raise RuntimeError(
                    f"vmap engine: world {w} non-finite master weights "
                    "under ring_dtype='bf16' — rerun with 'f32' to bisect")
            result = SimResult(scheme=sc.scheme, rounds=[], acc_history=[],
                               loss_history=[], final_params=final_w)
            eval_idx = {rr: k for k, rr in enumerate(eval_rounds)}
            te_i, te_l = built[w][1], built[w][2]
            for r in range(M):
                rec = RoundRecord(round=r + 1, time=float(t_time[r, w]),
                                  vehicle=int(t_veh[r, w]),
                                  upload_delay=float(t_cu[r, w]),
                                  train_delay=float(t_cl[r, w]),
                                  weight=float(t_w[r, w]))
                rr = r + 1
                if rr % eval_every == 0 or rr == M:
                    params_r = layout.unpack(evals[eval_idx[rr], w])
                    acc, loss = evaluate(params_r, te_i, te_l)
                    rec.accuracy, rec.loss = acc, loss
                    result.acc_history.append((rr, acc))
                    result.loss_history.append((rr, loss))
                    if progress:
                        progress(w, rr, acc)
                result.rounds.append(rec)
            results.append(result)
    timers.add("total", time.perf_counter() - _t0)
    # shared phase timers: one plan/stage/run/eval cost for the whole batch
    # — every world's report carries the same snapshot plus its world index
    for w, ((sc, seed), result) in enumerate(zip(worlds, results)):
        plan_w = plans[w]
        result.report = RunReport(
            engine="vmap", scheme=sc.scheme, rounds=M, seed=seed,
            metrics_on=False, spec=None, phases=timers.snapshot(),
            memory=memory_stats(),
            selection=(None if plan_w.sel is None
                       else plan_w.sel.summary()),
            waves=wave_stats(plan_w.waves, K),
            channels={"world_index": w, "n_worlds": W,
                      "group": next(gi for gi, G in enumerate(groups)
                                    if w in G)})
    return results

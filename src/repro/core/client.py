"""Vehicle-side local training (Algorithm 1, "Vehicle Update").

A client owns a private data shard and runs ``l`` SGD iterations (Eq. 2) from
the downloaded global model.  The trainable model is pluggable: the paper's
CNN for the faithful reproduction, or any assigned transformer arch via
``lm_local_step`` (the aggregation layer never inspects structure).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cnn import cnn_forward, cross_entropy_loss


@dataclass
class VehicleData:
    """Private shard of vehicle i (1-based index per the paper)."""
    index: int
    images: np.ndarray      # [D_i, 28, 28, 1]
    labels: np.ndarray      # [D_i]

    @property
    def size(self) -> int:
        return len(self.labels)


@jax.jit
def _cnn_sgd_iter(params, images, labels, lr):
    def loss_fn(p):
        return cross_entropy_loss(cnn_forward(p, images), labels)
    loss, grads = jax.value_and_grad(loss_fn)(params)
    params = jax.tree_util.tree_map(lambda w, g: w - lr * g, params, grads)
    return params, loss


class Vehicle:
    """One FL client.  ``local_update`` = l iterations of Eq. (1)+(2)."""

    def __init__(self, data: VehicleData, lr: float = 0.01,
                 batch_size: int = 128, seed: int = 0):
        self.data = data
        self.lr = lr
        # The paper's Eq. (1) sums the loss over all D_i data each iteration;
        # we use minibatch SGD (batch_size<=D_i) for CPU tractability — a
        # documented deviation (DESIGN.md §6) that preserves Eq. (2).
        self.batch_size = min(batch_size, data.size)
        self.rng = np.random.default_rng(seed + data.index)

    def local_update(self, global_params, l_iters: int):
        params = global_params
        last_loss = np.inf
        for _ in range(l_iters):
            sel = self.rng.choice(self.data.size, self.batch_size,
                                  replace=False)
            params, loss = _cnn_sgd_iter(
                params, jnp.asarray(self.data.images[sel]),
                jnp.asarray(self.data.labels[sel]), self.lr)
            last_loss = float(loss)
        return params, last_loss


def make_lm_local_step(cfg, forward_fn) -> Callable:
    """Local SGD step factory for transformer clients (examples/)."""

    @jax.jit
    def step(params, tokens, lr):
        def loss_fn(p):
            logits, aux = forward_fn(cfg, p, tokens[:, :-1])
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(logp, tokens[:, 1:, None], -1)
            return jnp.mean(nll) + aux
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params = jax.tree_util.tree_map(lambda w, g: w - lr * g, params,
                                        grads)
        return params, loss

    return step

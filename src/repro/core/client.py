"""Vehicle-side local training (Algorithm 1, "Vehicle Update").

A client owns a private data shard and runs ``l`` SGD iterations (Eq. 2) from
the downloaded global model.  The trainable model is pluggable: the paper's
CNN for the faithful reproduction, or any assigned transformer arch via
``lm_local_step`` (the aggregation layer never inspects structure).

The ``l`` iterations are a single ``jax.lax.scan`` program: one dispatch per
local update instead of ``l``, with the loss materialized on the host only
once at the end (DESIGN.md §3).  ``local_update_many`` additionally vmaps the
same scan over a stack of vehicles so a whole wave of pending uploads trains
in one program.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cnn import cnn_forward, cross_entropy_loss


@dataclass
class VehicleData:
    """Private shard of vehicle i (1-based index per the paper)."""
    index: int
    images: np.ndarray      # [D_i, 28, 28, 1]
    labels: np.ndarray      # [D_i]

    @property
    def size(self) -> int:
        return len(self.labels)


@jax.jit
def _cnn_sgd_iter(params, images, labels, lr):
    def loss_fn(p):
        return cross_entropy_loss(cnn_forward(p, images), labels)
    loss, grads = jax.value_and_grad(loss_fn)(params)
    params = jax.tree_util.tree_map(lambda w, g: w - lr * g, params, grads)
    return params, loss


def _local_scan(params, images, labels, lr):
    """l SGD iterations (Eq. 2) as one scan.  images [l, b, 28, 28, 1].

    Fully unrolled: XLA:CPU runs conv/dot ops inside a rolled while-loop
    body ~20x slower than the same ops at top level (no parallel thunk
    path), so the rolled form turned a 0.75 s local update into 15 s.
    Unrolling keeps the single-dispatch property and restores per-op
    performance; compile time grows with l but is paid once per shape."""
    def body(p, batch):
        img, lab = batch

        def loss_fn(q):
            return cross_entropy_loss(cnn_forward(q, img), lab)

        loss, grads = jax.value_and_grad(loss_fn)(p)
        p = jax.tree_util.tree_map(lambda w, g: w - lr * g, p, grads)
        return p, loss

    params, losses = jax.lax.scan(body, params, (images, labels),
                                  unroll=True)
    return params, losses[-1]


_local_scan_jit = jax.jit(_local_scan)
# vehicle-batched path: vmap the identical scan over stacked (params, data)
_local_scan_vmap = jax.jit(jax.vmap(_local_scan, in_axes=(0, 0, 0, None)))


def _local_scan_partial(params, images, labels, lr, n_ep):
    """Partial-computation variant (faults, DESIGN.md §16): the same l-step
    unrolled scan, but only the first ``n_ep`` updates apply — deadline
    semantics, so the dispatch shape and the per-vehicle minibatch draws
    are identical to the full scan and only steps >= n_ep become no-ops.
    Kept separate from ``_local_scan`` so faults-off runs retain the legacy
    scan's object identity (program-cache keys, rule FLT001)."""
    def body(carry, batch):
        p, step, last = carry
        img, lab = batch

        def loss_fn(q):
            return cross_entropy_loss(cnn_forward(q, img), lab)

        loss, grads = jax.value_and_grad(loss_fn)(p)
        live = step < n_ep
        p = jax.tree_util.tree_map(
            lambda w, g: jnp.where(live, w - lr * g, w), p, grads)
        last = jnp.where(live, loss, last)
        return (p, step + 1, last), loss

    init = (params, jnp.int32(0), jnp.float32(0.0))
    (params, _, last), _ = jax.lax.scan(body, init, (images, labels),
                                        unroll=True)
    return params, last


_local_scan_partial_jit = jax.jit(_local_scan_partial)
_local_scan_partial_vmap = jax.jit(
    jax.vmap(_local_scan_partial, in_axes=(0, 0, 0, None, 0)))


class Vehicle:
    """One FL client.  ``local_update`` = l iterations of Eq. (1)+(2)."""

    def __init__(self, data: VehicleData, lr: float = 0.01,
                 batch_size: int = 128, seed: int = 0):
        self.data = data
        self.lr = lr
        # The paper's Eq. (1) sums the loss over all D_i data each iteration;
        # we use minibatch SGD (batch_size<=D_i) for CPU tractability — a
        # documented deviation (DESIGN.md §6) that preserves Eq. (2).
        self.batch_size = min(batch_size, data.size)
        self.rng = np.random.default_rng(seed + data.index)

    def sample_batches(self, l_iters: int):
        """Draw the l minibatches for one local update (host RNG).

        Drawn in the same per-iteration order as the legacy python loop, so
        a vehicle's RNG stream advances identically regardless of which
        engine (serial or vehicle-batched) consumes the batches."""
        sel = np.stack([self.rng.choice(self.data.size, self.batch_size,
                                        replace=False)
                        for _ in range(l_iters)])
        return self.data.images[sel], self.data.labels[sel]

    def local_update(self, global_params, l_iters: int, n_ep=None):
        """``n_ep`` truncates the update to the first n_ep of the l_iters
        steps (partial computation, faults); the minibatches for all
        l_iters steps are drawn regardless so the RNG stream stays aligned
        with the fault-free run."""
        imgs, labs = self.sample_batches(l_iters)
        if n_ep is None:
            params, loss = _local_scan_jit(global_params, jnp.asarray(imgs),
                                           jnp.asarray(labs), self.lr)
        else:
            params, loss = _local_scan_partial_jit(
                global_params, jnp.asarray(imgs), jnp.asarray(labs),
                self.lr, jnp.int32(n_ep))
        return params, float(loss)


def local_update_many(payloads: Sequence, batches: Sequence, lr: float,
                      chunk: int = 16, n_eps: Sequence | None = None):
    """Train a wave of vehicles with a bounded number of compiled programs.

    ``payloads``: per-vehicle global-model snapshots (pytrees of identical
    structure); ``batches``: matching [l, b, ...] minibatch arrays, all the
    same shape (the engine gives the fleet one minibatch size, so a world
    compiles exactly one training shape).  Full ``chunk``-sized
    slices of the wave stack their pytrees and run under the vmapped scan —
    one dispatch per chunk, one compiled program per (chunk, batch shape)
    for the whole simulation; the remainder reuses the serial-engine scan
    program per event (on a compute-bound host, looping a short remainder
    is cheaper than padding it to ``chunk``).  Returns the list of updated
    pytrees and the final losses.

    ``n_eps`` (faults, partial computation): matching per-vehicle epoch
    counts; when given, every update runs the masked partial scan (a
    count equal to l_iters is bitwise the full update)."""
    outs, losses = [], []
    n = len(payloads)
    full = (n // chunk) * chunk if chunk > 1 else 0
    for s in range(0, full, chunk):
        pay = payloads[s:s + chunk]
        stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *pay)
        imgs = jnp.stack([jnp.asarray(b[0])
                          for b in batches[s:s + chunk]])
        labs = jnp.stack([jnp.asarray(b[1])
                          for b in batches[s:s + chunk]])
        if n_eps is None:
            out, ls = _local_scan_vmap(stacked, imgs, labs, lr)
        else:
            eps = jnp.asarray(n_eps[s:s + chunk], dtype=jnp.int32)
            out, ls = _local_scan_partial_vmap(stacked, imgs, labs, lr, eps)
        ls = np.asarray(ls)
        outs.extend(jax.tree_util.tree_map(lambda x, i=i: x[i], out)
                    for i in range(chunk))
        losses.extend(float(l) for l in ls)
    for i in range(full, n):
        if n_eps is None:
            params, loss = _local_scan_jit(payloads[i],
                                           jnp.asarray(batches[i][0]),
                                           jnp.asarray(batches[i][1]), lr)
        else:
            params, loss = _local_scan_partial_jit(
                payloads[i], jnp.asarray(batches[i][0]),
                jnp.asarray(batches[i][1]), lr, jnp.int32(n_eps[i]))
        outs.append(params)
        losses.append(float(loss))
    return outs, losses


def make_lm_local_step(cfg, forward_fn) -> Callable:
    """Local SGD step factory for transformer clients (examples/)."""

    @jax.jit
    def step(params, tokens, lr):
        def loss_fn(p):
            logits, aux = forward_fn(cfg, p, tokens[:, :-1])
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(logp, tokens[:, 1:, None], -1)
            return jnp.mean(nll) + aux
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params = jax.tree_util.tree_map(lambda w, g: w - lr * g, params,
                                        grads)
        return params, loss

    return step

"""RSU-side state: the global model, round log, and aggregation dispatch.

The non-kernel update paths run through the jitted donated variants in
``aggregation`` — the received upload buffer is consumed exactly once per
round, so its memory is donated to the new global model (DESIGN.md §3).
"""
from __future__ import annotations

import numpy as np

from dataclasses import dataclass, field
from typing import Any, Optional

from repro.channel.params import ChannelParams
from repro.core import aggregation
from repro.core.weights import combined_weight


@dataclass
class RoundRecord:
    round: int
    time: float
    vehicle: int               # 0-based
    upload_delay: float
    train_delay: float
    weight: float              # beta_u * beta_l (1.0 for plain AFL)
    loss: Optional[float] = None
    accuracy: Optional[float] = None
    # serving RSU the upload landed on (multi-RSU corridor engines only)
    rsu: Optional[int] = None


# fedasync's mixing coefficient (alpha = mix * (staleness+1)^-0.5); the
# device engines (core/jit_engine.py, corridor/engine.py) must mirror the
# host path, so all of them read this one constant
DEFAULT_FEDASYNC_MIX = 0.5


class RSUServer:
    """Holds w_g and applies one aggregation per received upload
    (Algorithm 1 lines 6-7)."""

    def __init__(self, init_params, params: ChannelParams,
                 scheme: str = "mafl", use_kernel: bool = False,
                 fedbuff_size: int = 3,
                 fedasync_mix: float = DEFAULT_FEDASYNC_MIX,
                 interpretation: str = "mixing"):
        self.global_params = init_params
        self.p = params
        self.scheme = scheme
        self.use_kernel = use_kernel
        self.interpretation = interpretation
        self.rounds: list[RoundRecord] = []
        self._round = 0
        self._fedbuff = aggregation.FedBuffAggregator(fedbuff_size)
        self._fedasync_mix = fedasync_mix
        self._last_update_time = 0.0

    def receive(self, local_params, *, time: float, vehicle: int,
                upload_delay: float, train_delay: float,
                download_time: float, discard: bool = False) -> RoundRecord:
        """One upload -> one round r (Eq. 11 et al.).

        ``discard=True`` is the staleness-cap degradation path (faults,
        DESIGN.md §16): the arrival still consumes round r and is logged,
        but the global model is left untouched."""
        self._round += 1
        weight = 1.0
        if self.scheme == "mafl":
            weight = combined_weight(self.p, upload_delay, train_delay)
        if discard:
            pass
        elif self.scheme == "mafl":
            if self.use_kernel:
                self.global_params = aggregation.mafl_update(
                    self.global_params, local_params, self.p.beta, weight,
                    use_kernel=True, interpretation=self.interpretation)
            elif self.interpretation == "literal":
                self.global_params = aggregation.literal_update_donated(
                    self.global_params, local_params, self.p.beta, weight)
            else:
                alpha = float(np.clip((1.0 - self.p.beta) * weight, 0.0, 1.0))
                self.global_params = aggregation.mix_update_donated(
                    self.global_params, local_params, alpha)
        elif self.scheme == "afl":
            self.global_params = aggregation.mix_update_donated(
                self.global_params, local_params, 1.0 - self.p.beta)
        elif self.scheme == "fedasync":
            staleness = max(time - download_time, 0.0)
            alpha = self._fedasync_mix * (staleness + 1.0) ** (-0.5)
            self.global_params = aggregation.mix_update_donated(
                self.global_params, local_params, alpha)
        elif self.scheme == "fedbuff":
            self.global_params, _ = self._fedbuff.add(
                self.global_params, local_params)
        else:
            raise ValueError(f"unknown scheme {self.scheme!r}")
        rec = RoundRecord(self._round, time, vehicle, upload_delay,
                          train_delay, weight)
        self.rounds.append(rec)
        self._last_update_time = time
        return rec

    @property
    def round(self) -> int:
        return self._round

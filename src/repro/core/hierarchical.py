"""Hierarchical multi-pod MAFL (beyond paper, DESIGN.md §7).

Maps the vehicular hierarchy onto the production mesh: each **pod is one RSU
cohort** running the paper's asynchronous aggregation locally; a cross-pod
EMA periodically reconciles the cohort models (the "cloud" tier the paper
alludes to but does not model).  Built on ``shard_map`` over the ``pod``
axis so each cohort's Eq. 10+11 update stays pod-local (zero inter-pod
traffic) and only the reconciliation step touches ICI.

Used by ``tests/test_hierarchical.py`` and the multi-pod dry-run notes in
EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pod_local_mafl(global_params, local_params, beta, weight):
    """Eq. 10+11 per pod — identical math to ``aggregation.mafl_update`` but
    expressed per-shard so it composes under ``shard_map``."""
    alpha = jnp.clip((1.0 - beta) * weight, 0.0, 1.0)
    return jax.tree_util.tree_map(
        lambda g, l: ((1 - alpha) * g.astype(jnp.float32) +
                      alpha * l.astype(jnp.float32)).astype(g.dtype),
        global_params, local_params)


def ema_toward(params, target, tau: float, use_kernel: bool = False):
    """One EMA step of every leaf toward ``target``:
    ``(1 - tau) * params + tau * target``.  ``tau = 1`` is plain
    assignment (FedAvg-style consensus); ``tau < 1`` keeps each cohort's
    identity between reconciliations (the cloud tier's EMA mode).
    ``use_kernel`` routes the mix through the fused Pallas
    ``weighted_agg`` kernel (beta = 1 - tau, weight = 1)."""
    if use_kernel:
        from repro.kernels.weighted_agg import ops as agg_ops
        return agg_ops.weighted_agg_tree(params, target, 1.0 - tau, 1.0)
    return jax.tree_util.tree_map(
        lambda g, c: ((1.0 - tau) * g.astype(jnp.float32) +
                      tau * c.astype(jnp.float32)).astype(g.dtype),
        params, target)


def cross_pod_reconcile(params, mesh, pod_axis: str = "pod",
                        shard_spec: P | None = None, tau: float = 1.0,
                        use_kernel: bool = False):
    """Reconcile the per-pod cohort models over the pod axis — the only
    inter-pod traffic in the hierarchy.  One pmean per leaf produces the
    cross-pod mean; ``tau`` selects the mode:

    - ``tau = 1`` (default, FedAvg): every pod adopts the mean outright —
      the original consensus behavior.
    - ``tau < 1`` (EMA): each pod moves a ``tau`` fraction toward the mean,
      keeping some cohort identity between reconciliations (what the
      corridor subsystem calls "ema" mode, DESIGN.md §10).

    ``shard_spec`` describes how each leaf's leading dim is laid out
    (default: sharded over (pod, data) — the FSDP layout the launcher
    uses); the pmean averages corresponding shards across pods."""
    spec = shard_spec if shard_spec is not None else P((pod_axis, "data"))

    def step(t):
        mean = jax.tree_util.tree_map(
            lambda x: jax.lax.pmean(x, pod_axis), t)
        if tau == 1.0:
            return mean
        return ema_toward(t, mean, tau, use_kernel=use_kernel)

    fn = shard_map(step, mesh=mesh, in_specs=(spec,), out_specs=spec,
                   check_rep=False)
    return fn(params)


def reconcile_models(models):
    """Host-level analogue of :func:`cross_pod_reconcile` for the serial
    multi-RSU reference engine (``corridor.reference``): plain mean of N
    cohort models held as separate pytrees (no mesh required).  EMA-mode
    callers apply :func:`ema_toward` per cohort on top of this mean."""
    n = len(models)
    return jax.tree_util.tree_map(
        lambda *xs: (sum(x.astype(jnp.float32) for x in xs) / n).astype(
            xs[0].dtype), *models)


def make_hierarchical_round(mesh, beta: float, pod_axis: str = "pod",
                            reconcile_every: int = 4):
    """Returns ``round_fn(step, cohort_models, upload, weight)`` that applies
    the pod-local MAFL update every call and the cross-pod pmean every
    ``reconcile_every`` rounds (jit-able; ``step`` is a traced scalar)."""

    def round_fn(step, cohort_models, upload, weight):
        updated = pod_local_mafl(cohort_models, upload, beta, weight)

        def do_reconcile(t):
            return cross_pod_reconcile(t, mesh, pod_axis)

        return jax.lax.cond(
            (step % reconcile_every) == reconcile_every - 1,
            do_reconcile, lambda t: t, updated)

    return round_fn

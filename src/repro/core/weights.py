"""The paper's delay weights (Eqs. 7, 9) and the weighted local model (Eq. 10).

beta_u = gamma ** (C_u - 1)     -- uploading-delay weight (mobility/channel)
beta_l = zeta  ** (C_l - 1)     -- training-delay weight (data/compute)
w_up   = w_local * beta_u * beta_l
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.channel.params import ChannelParams


def upload_weight(p: ChannelParams, upload_delay: float) -> float:
    """Eq. (7)."""
    return float(p.gamma ** (upload_delay - 1.0))


def training_weight(p: ChannelParams, train_delay: float) -> float:
    """Eq. (9)."""
    return float(p.zeta ** (train_delay - 1.0))


def combined_weight(p: ChannelParams, upload_delay: float,
                    train_delay: float) -> float:
    return upload_weight(p, upload_delay) * training_weight(p, train_delay)


def weighted_local_model(local_params, weight: float):
    """Eq. (10): scale the whole local pytree by the scalar weight."""
    w = jnp.float32(weight)
    return jax.tree_util.tree_map(
        lambda a: (a.astype(jnp.float32) * w).astype(a.dtype), local_params)

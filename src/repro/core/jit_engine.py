"""Device-resident mega-fleet engine: the whole round loop as one compiled
program (``engine="jit"``, DESIGN.md §9).

The serial and batched engines (DESIGN.md §2-§3) pay one Python dispatch per
arrival — heap pop, aggregation call, re-schedule — so wall-clock grows with
fleet size even though the training itself is batched.  This engine moves
the *event loop itself* into XLA:

- **Fixed-capacity slot queue.**  Every vehicle has exactly one in-flight
  upload at all times (it re-downloads the instant its upload is consumed,
  Fig. 2), so the event queue is exactly ``K`` structured slots: ``f32[K]``
  times/delays and ``i32[K]`` cycles, indexed by vehicle.  A pop is an
  ``argmin`` over the time column; a re-schedule is a one-slot scatter.

- **Precomputed slot gains.**  The host-side incremental ``SlotGainCache``
  is replaced by :func:`repro.channel.slot_gain_table` — the AR(1) linear
  recurrence evaluated for all slots at once by a vectorized prefix scan —
  loaded as an ``f32[S, K]`` table the in-program re-scheduler indexes.

- **Snapshot ring.**  Stale download-time payloads (DESIGN.md §2 invariant
  1) live in a ring of the last ``M+1`` global models indexed by *round*:
  the payload of an event downloaded after round ``d`` is ``ring[d+1]``
  (``ring[0]`` = the initial model).  Capacity ``M+1`` is exact — an event
  consumed within ``M`` rounds can only have downloaded at one of rounds
  ``0..M-1`` — and for mega-fleets it is far smaller than a per-vehicle
  payload buffer (``M+1`` vs ``K`` models when ``K >> M``).

- **Wave-hoisted training.**  Local training is grouped into the same
  waves the batched engine discovers (every pending upload whose payload
  round has completed trains together) and runs as top-level ``jax.vmap``
  blocks *between* the event-loop scan segments, optionally sharded over
  the ``"data"`` axis of a `launch/mesh.py` mesh via ``shard_map``.  Waves
  whose members all share one payload (every initial-download wave — the
  overwhelmingly common case when ``K >> M``) broadcast the parameters
  instead of stacking them, so the convolutions keep unbatched filters —
  on CPU a stacked-parameter vmap lowers to grouped convolutions that run
  *slower* than serial dispatch, and on TPU the broadcast form feeds the
  MXU one large batch.  The event-loop scan between waves touches only
  argmin/scalar/elementwise-aggregation ops, which lose nothing inside a
  compiled loop body.

- **Packed flat fast path (default, DESIGN.md §12).**  ``flat=True``
  replaces the pytree model states with one lane-aligned ``f32[P]``
  buffer per state (``core/flat.py``): the model leaves the scan carry,
  the ring materializes only checkpoint rows, aggregation is one vector
  op per pop (or a fused ``ring_agg`` chain under ``use_kernel`` /
  accelerator backends), and ``ring_dtype="bf16"`` halves the ring +
  upload buffers around f32 master weights.  ``flat=False`` keeps the
  legacy pytree program below as the benchmark baseline.

Times inside the program are ``f32`` (the event semantics are unchanged;
conformance vs the f64 host engines is to tolerance — pinned exactly on the
(round, vehicle) sequence by ``tests/test_engine_conformance.py``).  The
timeline never depends on training (DESIGN.md §3), so a cheap f64 host dry
run plans the program (pop order, wave partition, gain-table size, one
minibatch stack per round) and afterwards cross-checks the device trace —
any divergence raises instead of silently mis-pairing batches to rounds.

Not handled here (falls back to the host engines): multi-RSU handover
corridors (``run_handover_simulation``) and the buffered ``fedbuff``
scheme, both of which carry host-side state between arrivals.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel import (ChannelParams, Mobility, slot_gain_table,
                           training_delay)
from repro.core import client as client_mod
from repro.core.client import Vehicle, VehicleData
from repro.core.server import DEFAULT_FEDASYNC_MIX, RoundRecord
from repro.models.cnn import init_cnn
from repro.selection import make_selection_state

_SUPPORTED_SCHEMES = ("mafl", "afl", "fedasync")


@dataclass
class FleetPlan:
    """Host dry-run of the timeline: everything the compiled program needs
    that training cannot change (DESIGN.md §3: times depend only on the
    channel/mobility/data-size processes)."""
    veh: np.ndarray             # i32[M] vehicle popped at round r
    cycle: np.ndarray           # i32[M] that vehicle's upload cycle
    dl_round: np.ndarray        # i32[M] round after which it downloaded (-1 = initial)
    times: np.ndarray           # f64[M] host-reference pop times
    train_delay: np.ndarray     # f64[M]
    upload_delay: np.ndarray    # f64[M]
    download_time: np.ndarray   # f64[M]
    waves: tuple                # ((train_rounds, seg_start, seg_end), ...)
    n_slots: int                # gain-table height
    q0: dict                    # initial per-vehicle slot arrays
    sel: object = None          # SelectionPlan (DESIGN.md §11) or None
    sel_bandit: object = None   # (rew_sum f64[K], rew_cnt f64[K]) or None
    flt: object = None          # FaultPlan (DESIGN.md §16) or None

    def tables(self) -> dict:
        """Fixed-shape padded plan tables for the multi-world sweep tier
        (DESIGN.md §15): every array's shape depends only on ``(M, K)`` —
        never on the seed — so per-world tables stack along a leading
        world axis (``repro.core.sweep.stack_plan_tables``; PLN003 probes
        the stability).  The ragged ``waves`` tuple is re-encoded as two
        per-round columns: ``train_round[r]`` = the wave start at which
        consumed upload ``r`` trains, ``seg_end[r]`` = the end of the
        scan segment containing pop ``r``.  ``n_slots`` pads as a value,
        not a shape — the sweep engine zero-pads the gain tables to the
        batch maximum."""
        M = len(self.veh)
        train_round = np.full(M, -1, np.int32)
        seg_end = np.zeros(M, np.int32)
        for T, s, e in self.waves:
            for t in T:
                train_round[t] = s
            seg_end[s:e] = e
        return {
            "veh": np.asarray(self.veh, np.int32),
            "cycle": np.asarray(self.cycle, np.int32),
            "dl_round": np.asarray(self.dl_round, np.int32),
            "times": np.asarray(self.times, np.float64),
            "train_delay": np.asarray(self.train_delay, np.float64),
            "upload_delay": np.asarray(self.upload_delay, np.float64),
            "download_time": np.asarray(self.download_time, np.float64),
            "train_round": train_round,
            "seg_end": seg_end,
            "n_slots": np.asarray(self.n_slots, np.int32),
            "q0_time": np.asarray(self.q0["time"], np.float64),
            "q0_download_time": np.asarray(self.q0["download_time"],
                                           np.float64),
            "q0_upload_delay": np.asarray(self.q0["upload_delay"],
                                          np.float64),
            "q0_train_delay": np.asarray(self.q0["train_delay"],
                                         np.float64),
        }


def plan_fleet(p: ChannelParams, seed: int, rounds: int,
               selection=None, faults=None, l_iters: int = 5) -> FleetPlan:
    """Dry-run ``rounds`` arrivals (no payloads, no training) and derive the
    pop order, the wave partition, and the initial queue slots.  With a
    selection policy the replay drives a :class:`SelectionState`, so the
    admission masks, re-admission schedule, and (bandit) expected reward
    accumulators come out as static plan data; a fault model drives a
    :class:`FaultState` the same way (DESIGN.md §16), so dropped/blackout
    suppressions, recovery sweeps, staleness-cap verdicts, per-cycle epoch
    counts, and straggler delay inflation are all plan data too."""
    from repro.core.mafl import _Timeline
    from repro.faults import arrival_step, initial_vehicles, make_fault_state

    sel = make_selection_state(selection, p, Mobility(p), seed, rounds)
    flt = make_fault_state(faults, p, seed, rounds, l_iters)
    tl = _Timeline(p, seed, cl_scale=None if flt is None else flt.cl_scale)
    for k in initial_vehicles(sel, flt, p.K):
        tl.schedule(k, 0.0)

    ev0 = tl.queue.as_struct_arrays()
    if sel is None and flt is None:
        assert len(np.unique(ev0["vehicle"])) == p.K, \
            "slot queue invariant: one in-flight upload per vehicle"
    # full-K slot arrays; parked vehicles hold +inf (never popped) until a
    # re-admission boundary writes them a live slot.  train_delay comes from
    # Eq. 8 directly — bit-identical to the event values, and defined for
    # parked vehicles too (the in-program re-admission needs it); the
    # straggler multipliers (faults) scale it exactly as the timeline does.
    q0 = {
        "time": np.full(p.K, np.inf),
        "download_time": np.zeros(p.K),
        "upload_delay": np.zeros(p.K),
        "train_delay": np.array(
            [training_delay(p, i) for i in range(1, p.K + 1)]),
    }
    if flt is not None:
        q0["train_delay"] = q0["train_delay"] * flt.cl_scale
    q0["time"][ev0["vehicle"]] = ev0["time"]
    q0["download_time"][ev0["vehicle"]] = ev0["download_time"]
    q0["upload_delay"][ev0["vehicle"]] = ev0["upload_delay"]

    M = rounds
    veh = np.empty(M, np.int32)
    cyc = np.empty(M, np.int32)
    dlr = np.empty(M, np.int32)
    times = np.empty(M)
    c_l = np.empty(M)
    c_u = np.empty(M)
    dlt = np.empty(M)
    last_pop = np.full(p.K, -1, np.int32)
    for r in range(M):
        ev = tl.queue.pop()
        veh[r], cyc[r] = ev.vehicle, ev.cycle
        dlr[r] = last_pop[ev.vehicle]
        times[r], c_l[r], c_u[r] = ev.time, ev.train_delay, ev.upload_delay
        dlt[r] = ev.download_time
        last_pop[ev.vehicle] = r
        if sel is None and flt is None:
            tl.schedule(ev.vehicle, ev.time)
        else:
            if flt is not None:
                flt.on_pop(ev.vehicle, r)

            def _readmit(v, t=ev.time, r=r):
                # a re-admitted vehicle downloads the post-round-r model,
                # so its next pop's payload is ring[r+1] — same indexing
                # rule as an ordinary re-download
                tl.schedule(v, t)
                last_pop[v] = r

            arrival_step(
                sel, flt, r=r, vehicle=ev.vehicle, time=ev.time,
                upload_delay=ev.upload_delay, train_delay=ev.train_delay,
                pending=len(tl.queue),
                schedule=lambda v, t=ev.time: tl.schedule(v, t),
                readmit=_readmit)
        tl.prune()

    # Wave partition — identical to the batched engine's rule: a wave trains
    # every not-yet-trained consumed upload whose payload round has already
    # completed, then the scan segment consumes pops up to the first event
    # scheduled *during* that segment.
    waves = []
    trained = np.zeros(M, bool)
    s = 0
    while s < M:
        T = np.where(~trained & (dlr < s))[0]
        trained[T] = True
        untrained = np.where(~trained)[0]
        e = int(untrained[0]) if len(untrained) else M
        waves.append((tuple(int(x) for x in T), s, e))
        s = e

    return FleetPlan(veh=veh, cycle=cyc, dl_round=dlr, times=times,
                     train_delay=c_l, upload_delay=c_u, download_time=dlt,
                     waves=tuple(waves), n_slots=tl.gains.last_slot + 3,
                     q0=q0, sel=None if sel is None else sel.plan(),
                     sel_bandit=None if sel is None
                     else sel.bandit_expectation(),
                     flt=None if flt is None else flt.plan())


# ---------------------------------------------------------------------------
# the compiled program
# ---------------------------------------------------------------------------
# LRU-bounded: one compiled program per world *structure*; long-lived
# processes sweeping many worlds (hypothesis conformance, seed sweeps) must
# not retain every executable forever (the gain-cache lesson from PR 1)
from collections import OrderedDict

_PROGRAM_CACHE: OrderedDict = OrderedDict()
_PROGRAM_CACHE_SIZE = 32


def _mesh_key(mesh) -> tuple:
    if mesh is None:
        return ()
    return (tuple(mesh.shape.items()),)


def _wave_train(local_scan, mesh, n_events, shared: bool,
                partial: bool = False):
    """The wave-training block: vmap over events, optionally sharded over
    the mesh ``"data"`` axis via shard_map (DESIGN.md §5, §9).

    ``partial=True`` (faults, DESIGN.md §16) selects the masked partial
    scan — the trainer takes a per-event epoch-count vector as a trailing
    argument, mapped over the event axis like the minibatches.

    The trained weights pass through an ``optimization_barrier``: without
    it XLA:CPU re-fuses the SGD epilogue (``w - lr*g``) into whatever
    consumes the wave — and FMA-contracts it differently per consumer, so
    the *same* training would yield different low bits under the pytree
    and flat layouts (DESIGN.md §12).  The host engines materialize
    training outputs at their jit-call boundaries by construction; the
    barrier gives the device programs the same property, making the flat
    fast path bitwise against the pytree path."""
    axes = (None if shared else 0, 0, 0, None) + ((0,) if partial else ())
    vf = jax.vmap(local_scan, in_axes=axes)

    def f(pay, imgs, labs, lr, *eps):
        loc, losses = vf(pay, imgs, labs, lr, *eps)
        return jax.lax.optimization_barrier((loc, losses))
    if mesh is None or "data" not in mesh.shape:
        return f
    n_data = mesh.shape["data"]
    if n_events % n_data != 0:
        return f                      # ragged wave: replicate instead
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    pay_spec = P() if shared else P("data")
    in_specs = ((pay_spec, P("data"), P("data"), P())
                + ((P("data"),) if partial else ()))
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=(P("data"), P("data")), check_rep=False)


def _ring_interpret(use_kernel: bool):
    """``ring_agg`` dispatch mode: ``None`` auto-selects (the race
    analyzer's verdict picks compiled Pallas where legal, the jnp chain
    elsewhere); ``use_kernel=True`` forces the Pallas kernel — compiled
    where the verdict allows, the interpreter everywhere else (the
    kernel's cross-chunk accumulation needs a sequential grid)."""
    if not use_kernel:
        return None
    from repro.kernels.dispatch import resolve_interpret
    return resolve_interpret("weighted_agg.ring_agg_2d")


def _chain_segment(g, locals_buf, coeffs, snaps, s: int, e: int,
                   needed, store, ring_interpret):
    """Advance the f32 master ``g`` across scan segment ``[s, e)`` as fused
    ``ring_agg`` chains, materializing a snapshot row only at the rounds in
    ``needed`` (later-wave payloads / evals) — the global model streams
    once per checkpoint interval instead of once per upload, and the
    arithmetic stays the bitwise sequential chain (DESIGN.md §12).

    ``coeffs`` are the segment's per-upload (c, d) pairs (f32[e-s, 2]);
    ``snaps`` is the trace-level dict of stored ring rows."""
    from repro.kernels.weighted_agg import ops as agg_ops
    a = s
    for b in sorted({x for x in needed if s < x <= e} | {e}):
        if b > a:
            g = agg_ops.ring_agg(g, locals_buf[a:b], coeffs[a - s:b - s],
                                 interpret=ring_interpret)
        if b in needed:
            snaps[b] = store(g)
        a = b
    return g


def _build_program(plan: FleetPlan, p: ChannelParams, *, scheme: str,
                   interpretation: str, use_kernel: bool, mesh,
                   fedasync_mix: float, flat_layout=None,
                   ring_dtype: str = "f32", eval_rounds: tuple = (),
                   metrics=None, l_iters: int = 1):
    """Trace-time constants live in the closure; the returned function is
    cached on the plan/world structure so repeated runs of the same world
    (determinism tests, warm benchmarks) compile exactly once.

    ``flat_layout`` selects the packed flat-parameter fast path (DESIGN.md
    §12): model states become lane-aligned ``[P]`` buffers, the model
    leaves the event-loop scan entirely (the scan carries only queue
    columns), and each segment's aggregation runs as a fused ``ring_agg``
    chain.  ``ring_dtype="bf16"`` stores snapshot rows and upload buffers
    in bf16 (f32 master weights, f32 accumulation)."""
    M = len(plan.veh)
    K = p.K
    d = np.asarray(plan.dl_round)
    beta = jnp.float32(p.beta)
    gamma = jnp.float32(p.gamma)
    zeta = jnp.float32(p.zeta)
    f_mix = jnp.float32(fedasync_mix)
    v_c = jnp.float32(p.v)
    cov = jnp.float32(p.coverage)
    dy2H2 = jnp.float32(p.d_y ** 2 + p.H ** 2)
    pm = jnp.float32(p.p_m)
    alpha_pl = jnp.float32(p.alpha)
    sigma2 = jnp.float32(p.sigma2)
    bw = jnp.float32(p.B)
    bits = jnp.float32(p.model_bits)
    n_slots = plan.n_slots

    # selection (DESIGN.md §11): admission is static plan data folded into
    # the compiled program — a [M, K] mask table gates every re-schedule
    # (an unadmitted vehicle's slot gets +inf, so the argmin pop can never
    # pick it and it occupies no wave), and boundary re-admissions run at
    # trace level between scan sub-segments.  Only the eps-bandit carries
    # live state (f32 reward accumulators) through the scan — its decisions
    # still come from the host f64 replay; the accumulators exist so the
    # divergence guard can prove the device saw the same reward stream.
    sel_active = plan.sel is not None and not plan.sel.is_noop
    with_state = sel_active and plan.sel.spec.policy == "eps-bandit"

    # faults (DESIGN.md §16): the exact same fold as selection.  Dropped
    # and blacked-out re-schedules AND into the admission table (the
    # suppressed vehicle's slot goes +inf), recovery sweeps merge into the
    # boundary re-admission map, the staleness-cap verdicts become a
    # static keep column gating each pop's aggregation, and per-cycle
    # epoch counts feed the masked partial trainer.  flt is None on the
    # off path, so every branch below vanishes and the program is
    # textually the legacy one (rule FLT001, the TEL001 dual).
    from repro.faults import fold_admission, fold_readmits

    flt_plan = plan.flt
    flt_on = flt_plan is not None
    has_partial = flt_on and flt_plan.spec.has_partial
    has_cap = flt_on and flt_plan.spec.has_cap
    adm_active = sel_active or (flt_on and flt_plan.timeline_active)
    if adm_active:
        adm = (np.stack([plan.sel.mask_for_round(r) for r in range(M)])
               if sel_active else np.ones((M, K), bool))
        if flt_on and flt_plan.timeline_active:
            adm = fold_admission(adm, flt_plan, plan.veh)
        adm_tab = jnp.asarray(adm)
        readmit_at = {b: np.asarray(vs, np.int32)
                      for b, vs in fold_readmits(
                          plan.sel if sel_active else None,
                          flt_plan if flt_on else None).items() if len(vs)}
    else:
        readmit_at = {}
    if has_cap:
        keep_tab = jnp.asarray(np.asarray(flt_plan.keep, bool))
    if has_partial:
        ep_tab = jnp.asarray(np.asarray(flt_plan.epochs, np.int32))

    # telemetry (DESIGN.md §14): the same fold as selection — a static
    # MetricsSpec from the host planner, fixed-shape accumulators appended
    # to the scan carry, occupancy/pop-wait as extra ys columns.  metrics
    # is None on the off path, so every met_on branch vanishes and the
    # program is textually the legacy one (rule TEL001).
    met_on = metrics is not None
    if met_on:
        from repro.telemetry import device as tel_dev
        met_edges = jnp.asarray(metrics.edges, jnp.float32)
    # fault counters (DESIGN.md §16): per-pop i32[4] increments from the
    # fault plan, accumulated in the metrics carry and conformance-checked
    # against the f64 fault replay after the run
    fct_on = met_on and metrics.fault_counters and flt_on
    if fct_on:
        fct_tab = jnp.asarray(flt_plan.counts_table(l_iters))

    def eq36_upload_delay(gains, x0, idx, t_up):
        """Eq. 3-6 re-schedule pipeline: slot gain -> position wrap ->
        distance -> SNR -> Shannon rate -> upload delay.  ``idx`` may be
        a scalar pop or a vector of re-admissions; ONE definition serves
        the legacy and flat scan bodies and both readmit helpers — the
        arithmetic (and its op order) is part of the flat-vs-pytree
        bitwise pin, so it must never fork."""
        slot = jnp.clip(t_up.astype(jnp.int32), 0, n_slots - 1)
        gain = gains[slot, idx]
        dx = x0[idx] + v_c * t_up                       # Eq. 3
        dx = jnp.mod(dx + cov, 2.0 * cov) - cov         # re-entry wrap
        dist = jnp.sqrt(dx * dx + dy2H2)                # Eq. 4
        snr = pm * gain * dist ** (-alpha_pl) / sigma2
        rate = bw * jnp.log2(1.0 + snr)                 # Eq. 5
        return bits / jnp.maximum(rate, 1e-12)          # Eq. 6

    def aggregate(g, loc, t, cu, cl, dl_t):
        """One arrival's update — mirrors the host paths bit-for-bit in
        formula and f32 arithmetic (aggregation.mix_update_donated /
        literal_update_donated / weighted_agg kernel)."""
        if scheme == "mafl":
            weight = gamma ** (cu - 1.0) * zeta ** (cl - 1.0)   # Eqs. 7, 9
        else:
            weight = jnp.float32(1.0)
        if scheme == "mafl" and interpretation == "literal":
            if use_kernel:
                from repro.kernels.weighted_agg import ops as agg_ops
                return agg_ops.weighted_agg_tree(g, loc, beta, weight), weight
            new = jax.tree_util.tree_map(
                lambda a, b: (beta * a.astype(jnp.float32) +
                              (1.0 - beta) * weight *
                              b.astype(jnp.float32)).astype(a.dtype), g, loc)
            return new, weight
        if scheme == "mafl":
            alpha = jnp.clip((1.0 - beta) * weight, 0.0, 1.0)
        elif scheme == "afl":
            alpha = 1.0 - beta
        else:                                                   # fedasync
            stale = jnp.maximum(t - dl_t, 0.0)
            alpha = f_mix * (stale + 1.0) ** (-0.5)
        if use_kernel:
            from repro.kernels.weighted_agg import ops as agg_ops
            return agg_ops.weighted_agg_tree(g, loc, 1.0 - alpha,
                                             jnp.float32(1.0)), weight
        new = jax.tree_util.tree_map(
            lambda a, b: ((1.0 - alpha) * a.astype(jnp.float32) +
                          alpha * b.astype(jnp.float32)).astype(a.dtype),
            g, loc)
        return new, weight

    if flat_layout is not None:
        from repro.core.aggregation import chain_coeffs

        layout = flat_layout
        bf16 = ring_dtype == "bf16"
        store_dtype = jnp.bfloat16 if bf16 else jnp.float32
        store = ((lambda x: x.astype(jnp.bfloat16)) if bf16
                 else (lambda x: x))
        ring_interp = _ring_interpret(use_kernel)
        # Fused-chain mode: aggregation leaves the scan entirely and runs
        # as ring_agg chains between checkpoints (the multi-upload Pallas
        # kernel on TPU/GPU, its jnp form under use_kernel on CPU).  On
        # the CPU default the mix stays *inside* the scan instead,
        # operating on the packed [P] buffer: XLA:CPU FMA-contracts fused
        # elementwise loops by emission context (flags cannot disable it,
        # DESIGN.md §12), and the in-scan form is the one that reproduces
        # the pytree path's golden digests bit-for-bit.
        fused_chain = use_kernel or jax.default_backend() != "cpu"
        # rounds whose post-round model must materialize: later-wave
        # payloads and eval rows — everything else is never read, so the
        # chain streams straight through it
        needed = set(int(x) for x in eval_rounds)
        for T, _s, _e in plan.waves:
            needed |= {int(d[t]) + 1 for t in T if d[t] >= 0}

        def program_flat(w0, gains, x0, qt, qdl, qcu, qcl, imgs, labs,
                         lr):
            local_scan = (client_mod._local_scan_partial if has_partial
                          else client_mod._local_scan)
            g = layout.pack(w0)                 # f32[P] master weights
            locals_buf = jnp.zeros((M, layout.P), store_dtype)
            mst = ring_stats = None
            store_row = store
            if met_on:
                mst = tel_dev.fleet_state(metrics)
                if metrics.ring_guard and bf16:
                    # trace-level bf16 ring guard: every stored snapshot
                    # row is counted for non-finite / max-|x| (DESIGN §14)
                    ring_stats = tel_dev.RingStats()
                    store_row = ring_stats.wrap(store)
            snaps = {0: store_row(g)}
            rs = rc = None
            if with_state:
                rs = jnp.zeros(K, jnp.float32)
                rc = jnp.zeros(K, jnp.float32)
            traces = []

            def make_flat_body(locals_buf):
                # fused_chain: queue bookkeeping only — the model is out
                # of the scan carry entirely and aggregation streams
                # per-checkpoint afterwards.  Otherwise the [P]-buffer mix
                # rides in the scan (one fused vector op per pop instead
                # of one op per leaf), bitwise the legacy body.  Fresh
                # body per segment: locals_buf rebinds per wave (the
                # lax.scan traced-body cache pitfall, DESIGN.md §9).
                def seg_body(carry, r):
                    if met_on:
                        carry, mst = carry[:-1], carry[-1]
                    if fused_chain:
                        g = None
                        if with_state:
                            qt, qdl, qcu, rs, rc = carry
                        else:
                            qt, qdl, qcu = carry
                    elif with_state:
                        g, qt, qdl, qcu, rs, rc = carry
                    else:
                        g, qt, qdl, qcu = carry
                    i = jnp.argmin(qt)                          # pop
                    if met_on:
                        # live slots at the instant of pop (incl. this one)
                        occ = jnp.sum(jnp.isfinite(qt)).astype(jnp.int32)
                    t, cu, cl, dl_t = qt[i], qcu[i], qcl[i], qdl[i]
                    if fused_chain:
                        if scheme == "mafl":
                            weight = gamma ** (cu - 1.0) * zeta ** (cl - 1.0)
                        else:
                            weight = jnp.float32(1.0)
                    else:
                        # Eq. 10+11 on the packed buffer, one vector op;
                        # a cap-discarded pop keeps the old master exactly
                        # (the host skips the update outright)
                        g_new, weight = aggregate(g, locals_buf[r], t, cu,
                                                  cl, dl_t)
                        g = (jnp.where(keep_tab[r], g_new, g) if has_cap
                             else g_new)
                    if with_state:
                        rew = gamma ** (cu - 1.0) * zeta ** (cl - 1.0)
                        rs = rs.at[i].add(rew)
                        rc = rc.at[i].add(1.0)
                    t_up = t + cl
                    cu_new = eq36_upload_delay(gains, x0, i, t_up)
                    t_new = t_up + cu_new
                    if adm_active:
                        t_new = jnp.where(adm_tab[r, i], t_new, jnp.inf)
                    qt = qt.at[i].set(t_new)
                    qdl = qdl.at[i].set(t)
                    qcu = qcu.at[i].set(cu_new)
                    if fused_chain:
                        out = ((qt, qdl, qcu, rs, rc) if with_state
                               else (qt, qdl, qcu))
                    else:
                        out = ((g, qt, qdl, qcu, rs, rc) if with_state
                               else (g, qt, qdl, qcu))
                    ys = (i, t, cu, cl, dl_t, weight)
                    if met_on:
                        mst, gap = tel_dev.fleet_pop(
                            mst, met_edges, t=t, dl_t=dl_t,
                            fault_row=fct_tab[r] if fct_on else None)
                        out = out + (mst,)
                        ys = ys + (occ, gap)
                    return out, ys
                return seg_body

            def readmit(qt, qdl, qcu, A, t_b):
                A = jnp.asarray(A)
                t_up = t_b + qcl[A]
                cu_new = eq36_upload_delay(gains, x0, A, t_up)
                return (qt.at[A].set(t_up + cu_new), qdl.at[A].set(t_b),
                        qcu.at[A].set(cu_new))

            for T, s, e in plan.waves:
                T = np.asarray(T, np.int32)
                if len(T):
                    pay_rounds = d[T] + 1
                    shared = bool((pay_rounds == pay_rounds[0]).all())
                    if shared:
                        pay = layout.unpack(snaps[int(pay_rounds[0])])
                    else:
                        pay = layout.unpack(jnp.stack(
                            [snaps[int(pr)] for pr in pay_rounds]))
                    train = _wave_train(local_scan, mesh, len(T), shared,
                                        partial=has_partial)
                    extra = (ep_tab[jnp.asarray(T)],) if has_partial else ()
                    with jax.named_scope(f"wave_train_{s}"):
                        loc, _ = train(pay, imgs[T], labs[T], lr, *extra)
                    locals_buf = locals_buf.at[jnp.asarray(T)].set(
                        layout.pack(loc, dtype=store_dtype))
                seg_traces = []
                # sub-split at re-admission boundaries; the in-scan-mix
                # mode additionally splits at checkpoints so snapshot rows
                # store at trace level between sub-scans
                pts = {b for b in readmit_at if s < b <= e} | {e}
                if not fused_chain:
                    pts |= {b for b in needed if s < b <= e}
                a = s
                for b in sorted(pts):
                    if b > a:
                        if fused_chain:
                            carry0 = ((qt, qdl, qcu, rs, rc) if with_state
                                      else (qt, qdl, qcu))
                        else:
                            carry0 = ((g, qt, qdl, qcu, rs, rc)
                                      if with_state else (g, qt, qdl, qcu))
                        if met_on:
                            carry0 = carry0 + (mst,)
                        with jax.named_scope(f"event_scan_{a}_{b}"):
                            carry, ys = jax.lax.scan(
                                make_flat_body(locals_buf), carry0,
                                jnp.arange(a, b))
                        if met_on:
                            carry, mst = carry[:-1], carry[-1]
                        if fused_chain:
                            if with_state:
                                qt, qdl, qcu, rs, rc = carry
                            else:
                                qt, qdl, qcu = carry
                        elif with_state:
                            g, qt, qdl, qcu, rs, rc = carry
                        else:
                            g, qt, qdl, qcu = carry
                        traces.append(ys)
                        seg_traces.append(ys)
                    if not fused_chain and b in needed:
                        snaps[b] = store_row(g)
                    if b in readmit_at:
                        qt, qdl, qcu = readmit(qt, qdl, qcu, readmit_at[b],
                                               traces[-1][1][-1])
                    a = b
                if fused_chain:
                    # aggregation left the scan entirely: coefficients
                    # from the segment's own f32 trace (bitwise the legacy
                    # per-arrival expressions), then one streaming
                    # ring_agg chain per checkpoint interval
                    t_c, dlt_c, w_c = (
                        jnp.concatenate([tr[k] for tr in seg_traces])
                        for k in (1, 4, 5))
                    cc, dd = chain_coeffs(scheme, interpretation, p.beta,
                                          w_c, t=t_c, dl_t=dlt_c,
                                          fedasync_mix=fedasync_mix)
                    if has_cap:
                        # cap-discarded pops become exact chain no-ops
                        keep_seg = keep_tab[s:e]
                        cc = jnp.where(keep_seg, cc, 1.0)
                        dd = jnp.where(keep_seg, dd, 0.0)
                    coeffs = jnp.stack([cc, dd], axis=1)
                    with jax.named_scope(f"ring_chain_{s}_{e}"):
                        g = _chain_segment(g, locals_buf, coeffs, snaps,
                                           s, e, needed, store_row,
                                           ring_interp)
            trace = tuple(jnp.concatenate([tr[k] for tr in traces])
                          for k in range(6))
            evals = jnp.stack([snaps[rr] for rr in eval_rounds])
            if with_state:
                ret = (layout.unpack(g), evals, trace, (rs, rc))
            else:
                ret = (layout.unpack(g), evals, trace)
            if met_on:
                met_out = {
                    "stale_hist": mst[0],
                    "occupancy": jnp.concatenate(
                        [tr[6] for tr in traces]),
                    "gap": jnp.concatenate([tr[7] for tr in traces]),
                }
                if fct_on:
                    met_out["fault_counts"] = mst[2]
                if ring_stats is not None:
                    met_out.update(ring_stats.out())
                ret = ret + (met_out,)
            return ret

        return jax.jit(program_flat)

    def program(w0, gains, x0, qt, qdl, qcu, qcl, imgs, labs, lr):
        local_scan = (client_mod._local_scan_partial if has_partial
                      else client_mod._local_scan)
        ring = jax.tree_util.tree_map(
            lambda x: jnp.zeros((M + 1,) + x.shape, x.dtype).at[0].set(x), w0)
        locals_buf = jax.tree_util.tree_map(
            lambda x: jnp.zeros((M,) + x.shape, x.dtype), w0)
        g = w0
        mst = tel_dev.fleet_state(metrics) if met_on else None
        rs = rc = None
        if with_state:
            rs = jnp.zeros(K, jnp.float32)
            rc = jnp.zeros(K, jnp.float32)
        traces = []

        def make_seg_body(locals_buf):
            # A *fresh* body function per scan segment: lax.scan caches the
            # traced body jaxpr on the function's identity plus per-step
            # avals, which are identical for every segment — reusing one
            # closure across segments silently replays the first segment's
            # capture of ``locals_buf`` and aggregates zeros for every
            # later wave.
            def seg_body(carry, r):
                if met_on:
                    carry, mst = carry[:-1], carry[-1]
                if with_state:
                    g, ring, qt, qdl, qcu, rs, rc = carry
                else:
                    g, ring, qt, qdl, qcu = carry
                i = jnp.argmin(qt)                              # pop
                if met_on:
                    # live slots at the instant of pop (incl. this one)
                    occ = jnp.sum(jnp.isfinite(qt)).astype(jnp.int32)
                t, cu, cl, dl_t = qt[i], qcu[i], qcl[i], qdl[i]
                loc = jax.tree_util.tree_map(lambda B: B[r], locals_buf)
                g_new, weight = aggregate(g, loc, t, cu, cl,
                                          dl_t)             # Eq. 10+11
                if has_cap:
                    # cap-discarded pop: the global model stays exactly
                    # put (the host skips the update outright)
                    g = jax.tree_util.tree_map(
                        lambda old, new: jnp.where(keep_tab[r], new, old),
                        g, g_new)
                else:
                    g = g_new
                ring = jax.tree_util.tree_map(
                    lambda R, G: R.at[r + 1].set(G), ring, g)
                if with_state:
                    # the bandit reward is the paper's delay weight, folded
                    # into the carried accumulators (Eqs. 7, 9)
                    rew = gamma ** (cu - 1.0) * zeta ** (cl - 1.0)
                    rs = rs.at[i].add(rew)
                    rc = rc.at[i].add(1.0)
                # re-schedule vehicle i: download now, train C_l, upload C_u
                t_up = t + cl
                cu_new = eq36_upload_delay(gains, x0, i, t_up)
                t_new = t_up + cu_new
                if adm_active:
                    # admission mask folded into the slot queue: a parked
                    # (or dropped / blacked-out) vehicle's slot is +inf,
                    # invisible to the argmin
                    t_new = jnp.where(adm_tab[r, i], t_new, jnp.inf)
                qt = qt.at[i].set(t_new)
                qdl = qdl.at[i].set(t)
                qcu = qcu.at[i].set(cu_new)
                out = ((g, ring, qt, qdl, qcu, rs, rc) if with_state
                       else (g, ring, qt, qdl, qcu))
                ys = (i, t, cu, cl, dl_t, weight)
                if met_on:
                    mst, gap = tel_dev.fleet_pop(
                        mst, met_edges, t=t, dl_t=dl_t,
                        fault_row=fct_tab[r] if fct_on else None)
                    out = out + (mst,)
                    ys = ys + (occ, gap)
                return out, ys
            return seg_body

        def readmit(qt, qdl, qcu, A, t_b):
            """Boundary re-admission: schedule vehicles ``A`` (static) at
            the traced boundary timestamp — the same Eq. 3-6 pipeline as
            the in-scan re-schedule, vectorized over the newly admitted."""
            A = jnp.asarray(A)
            t_up = t_b + qcl[A]
            cu_new = eq36_upload_delay(gains, x0, A, t_up)
            return (qt.at[A].set(t_up + cu_new), qdl.at[A].set(t_b),
                    qcu.at[A].set(cu_new))

        for T, s, e in plan.waves:
            T = np.asarray(T, np.int32)
            if len(T):
                pay_rounds = d[T] + 1
                shared = bool((pay_rounds == pay_rounds[0]).all())
                if shared:
                    pay = jax.tree_util.tree_map(
                        lambda R: R[int(pay_rounds[0])], ring)
                else:
                    idx = jnp.asarray(pay_rounds)
                    pay = jax.tree_util.tree_map(lambda R: R[idx], ring)
                train = _wave_train(local_scan, mesh, len(T), shared,
                                    partial=has_partial)
                extra = (ep_tab[jnp.asarray(T)],) if has_partial else ()
                with jax.named_scope(f"wave_train_{s}"):
                    loc, _ = train(pay, imgs[T], labs[T], lr, *extra)
                T_dev = jnp.asarray(T)
                locals_buf = jax.tree_util.tree_map(
                    lambda B, L: B.at[T_dev].set(L), locals_buf, loc)
            # sub-split [s, e) at re-admission boundaries (static), so the
            # boundary scheduling runs at trace level between scans
            pts = sorted({b for b in readmit_at if s < b <= e} | {e})
            a = s
            for b in pts:
                if b > a:
                    carry0 = ((g, ring, qt, qdl, qcu, rs, rc) if with_state
                              else (g, ring, qt, qdl, qcu))
                    if met_on:
                        carry0 = carry0 + (mst,)
                    with jax.named_scope(f"event_scan_{a}_{b}"):
                        carry, ys = jax.lax.scan(
                            make_seg_body(locals_buf), carry0,
                            jnp.arange(a, b))
                    if met_on:
                        carry, mst = carry[:-1], carry[-1]
                    if with_state:
                        g, ring, qt, qdl, qcu, rs, rc = carry
                    else:
                        g, ring, qt, qdl, qcu = carry
                    traces.append(ys)
                if b in readmit_at:
                    # t_b = the boundary pop's timestamp (last of the
                    # sub-segment that just ran)
                    qt, qdl, qcu = readmit(qt, qdl, qcu, readmit_at[b],
                                           traces[-1][1][-1])
                a = b
        trace = tuple(jnp.concatenate([tr[k] for tr in traces])
                      for k in range(6))
        if with_state:
            ret = (g, ring, trace, (rs, rc))
        else:
            ret = (g, ring, trace)
        if met_on:
            met_out = {
                "stale_hist": mst[0],
                "occupancy": jnp.concatenate([tr[6] for tr in traces]),
                "gap": jnp.concatenate([tr[7] for tr in traces]),
            }
            if fct_on:
                met_out["fault_counts"] = mst[2]
            ret = ret + (met_out,)
        return ret

    return jax.jit(program)


def _get_program(plan: FleetPlan, p: ChannelParams, *, scheme, interpretation,
                 use_kernel, mesh, fedasync_mix, shapes, flat_layout=None,
                 ring_dtype="f32", eval_rounds=(), metrics=None,
                 l_iters=1):
    # the trainer function rides in the key as the object itself, not its
    # id(): ids are reused after GC, which could silently replay a program
    # traced against a different (monkeypatched) trainer.  metrics=off is
    # normalized to None *before* this key, so an off run shares the legacy
    # executable object outright (rule TEL001); faults=off likewise
    # contributes a constant None (rule FLT001).
    key = (plan.waves, tuple(plan.dl_round.tolist()), plan.n_slots, p,
           scheme, interpretation, use_kernel, fedasync_mix,
           _mesh_key(mesh), shapes,
           None if plan.sel is None else plan.sel.signature(),
           client_mod._local_scan,
           None if flat_layout is None else flat_layout.signature(),
           ring_dtype, eval_rounds if flat_layout is not None else (),
           None if metrics is None else metrics.signature(),
           None if plan.flt is None else (plan.flt.signature(), l_iters,
                                          client_mod._local_scan_partial))
    prog = _PROGRAM_CACHE.get(key)
    if prog is None:
        prog = _build_program(plan, p, scheme=scheme,
                              interpretation=interpretation,
                              use_kernel=use_kernel, mesh=mesh,
                              fedasync_mix=fedasync_mix,
                              flat_layout=flat_layout, ring_dtype=ring_dtype,
                              eval_rounds=eval_rounds, metrics=metrics,
                              l_iters=l_iters)
        _PROGRAM_CACHE[key] = prog
        while len(_PROGRAM_CACHE) > _PROGRAM_CACHE_SIZE:
            _PROGRAM_CACHE.popitem(last=False)
    else:
        _PROGRAM_CACHE.move_to_end(key)
    return prog


def _stage_run(vehicles_data, *, scheme, rounds, l_iters, lr, params, seed,
               eval_every, use_kernel, init_params, interpretation,
               batch_size, mesh, selection, flat, ring_dtype,
               metrics=None, faults=None, timers=None):
    """Validate, plan, and stage one fleet run — everything up to (but not
    including) executing the compiled program.  Split out of
    :func:`run_simulation_jit` so ``repro.check.dtype_flow`` can build the
    jaxpr of the exact program the engine would run.

    Returns ``(prog, args, plan, layout, eval_rounds, with_state, met)``
    where ``prog(*args)`` is the staged round loop and ``met`` is the
    resolved :class:`MetricsSpec` (None on the exact legacy off path)."""
    from repro.core.flat import ParamLayout
    from repro.telemetry.spec import resolve_metrics
    from repro.telemetry.timers import PhaseTimers

    timers = timers if timers is not None else PhaseTimers()
    if scheme not in _SUPPORTED_SCHEMES:
        raise ValueError(
            f"engine='jit' supports schemes {_SUPPORTED_SCHEMES}, not "
            f"{scheme!r} (fedbuff keeps host-side buffer state — use the "
            "serial or batched engine)")
    if ring_dtype not in ("f32", "bf16"):
        raise ValueError(f"unknown ring_dtype {ring_dtype!r}; "
                         "expected 'f32' or 'bf16'")
    if ring_dtype == "bf16" and not flat:
        raise ValueError("ring_dtype='bf16' requires the flat fast path "
                         "(flat=True): only the packed ring stores bf16 "
                         "snapshots around f32 master weights")
    p = params or ChannelParams()
    assert len(vehicles_data) == p.K, (len(vehicles_data), p.K)
    if rounds < 1:
        raise ValueError("rounds must be >= 1")

    with timers.phase("plan"):
        plan = plan_fleet(p, seed, rounds, selection, faults=faults,
                          l_iters=l_iters)
        # the telemetry spec is plan data (DESIGN.md §14): histogram edges
        # derive from the dry run's f64 staleness/pop times, and metrics=off
        # normalizes to None — the exact legacy program
        met = resolve_metrics(
            metrics, stale=plan.times - plan.download_time,
            times=plan.times, n_rsus=1, ring_guard=(ring_dtype == "bf16"),
            fault_counters=plan.flt is not None)
    M = rounds

    _t0 = time.perf_counter()
    key = jax.random.PRNGKey(seed)
    w0 = init_params if init_params is not None else init_cnn(key)

    # one minibatch stack per consumed round, drawn from the same
    # per-vehicle RNG streams in the same per-cycle order as the host
    # engines (DESIGN.md §3), so every engine trains identical batches
    fleet_batch = min(batch_size, min(d.size for d in vehicles_data))
    clients = [Vehicle(d, lr=lr, batch_size=fleet_batch, seed=seed)
               for d in vehicles_data]
    im_list, lab_list = [], []
    for r in range(M):
        im, lab = clients[plan.veh[r]].sample_batches(l_iters)
        im_list.append(im)
        lab_list.append(lab)
    imgs = jnp.asarray(np.stack(im_list))
    labs = jnp.asarray(np.stack(lab_list))

    gains = jnp.asarray(slot_gain_table(p, seed, plan.n_slots), jnp.float32)
    x0 = jnp.asarray(Mobility(p).x0, jnp.float32)
    qt = jnp.asarray(plan.q0["time"], jnp.float32)
    qdl = jnp.asarray(plan.q0["download_time"], jnp.float32)
    qcu = jnp.asarray(plan.q0["upload_delay"], jnp.float32)
    qcl = jnp.asarray(plan.q0["train_delay"], jnp.float32)

    shapes = (imgs.shape, tuple(
        (str(path), v.shape, str(v.dtype))
        for path, v in jax.tree_util.tree_leaves_with_path(w0)))
    layout = ParamLayout.from_tree(w0) if flat else None
    eval_rounds = tuple(rr for rr in range(1, M + 1)
                        if rr % eval_every == 0 or rr == rounds)
    prog = _get_program(plan, p, scheme=scheme, interpretation=interpretation,
                        use_kernel=use_kernel, mesh=mesh,
                        fedasync_mix=DEFAULT_FEDASYNC_MIX, shapes=shapes,
                        flat_layout=layout, ring_dtype=ring_dtype,
                        eval_rounds=eval_rounds, metrics=met,
                        l_iters=l_iters)
    with_state = (plan.sel is not None and not plan.sel.is_noop
                  and plan.sel.spec.policy == "eps-bandit")
    args = (w0, gains, x0, qt, qdl, qcu, qcl, imgs, labs, jnp.float32(lr))
    timers.add("stage", time.perf_counter() - _t0)
    return prog, args, plan, layout, eval_rounds, with_state, met


# ---------------------------------------------------------------------------
# public entry point — signature mirrors mafl.run_simulation
# ---------------------------------------------------------------------------
def run_simulation_jit(
    vehicles_data: Sequence[VehicleData],
    test_images: np.ndarray,
    test_labels: np.ndarray,
    *,
    scheme: str = "mafl",
    rounds: int = 60,
    l_iters: int = 5,
    lr: float = 0.01,
    params: Optional[ChannelParams] = None,
    seed: int = 0,
    eval_every: int = 1,
    use_kernel: bool = False,
    init_params=None,
    interpretation: str = "mixing",
    progress=None,
    batch_size: int = 128,
    mesh=None,
    selection=None,
    flat: bool = True,
    ring_dtype: str = "f32",
    metrics=None,
    faults=None,
):
    """Run M rounds entirely on device; returns the same ``SimResult`` the
    host engines produce (same record fields, same eval cadence).

    ``flat=True`` (the native layout, DESIGN.md §12) runs the packed
    flat-parameter fast path: one ``[P]`` buffer per model state, queue
    bookkeeping alone in the scan, fused ``ring_agg`` chains for the
    aggregation — bitwise-identical outputs in f32 (golden-pinned);
    ``flat=False`` keeps the legacy pytree program (the benchmark
    baseline).  ``ring_dtype="bf16"`` (flat only) stores snapshot-ring
    rows and upload buffers in bf16 around f32 master weights/accumulation
    — halves ring memory at a documented sub-1e-2 parameter rounding
    (EXPERIMENTS.md §Flat); it must be requested explicitly.

    One behavioral difference from the host engines: the whole round loop
    is a single device program, so ``progress`` fires post-hoc — every
    callback arrives in round order *after* the simulation completes, not
    live per arrival.

    ``metrics="on"`` folds device-resident telemetry into the scan
    (DESIGN.md §14): staleness histogram, slot-queue occupancy and
    argmin-pop wait traces accumulate in fixed-shape carry state, surfaced
    on ``result.report.channels``.  Any falsy value ("off"/None/False)
    stages the *exact* legacy program — same cache entry, bitwise-identical
    outputs (pinned by ``tests/test_telemetry.py``).

    ``faults`` activates the fault-injection layer (DESIGN.md §16): the
    host f64 planner samples the stochastic client-state processes into
    static fault tables folded into the program exactly like selection —
    suppressed re-schedules via the admission table, recovery sweeps via
    boundary re-admissions, staleness-cap discards via a keep column, and
    partial computation via the masked epoch trainer.  Off stages the
    exact legacy program (rule FLT001, pinned by ``tests/test_faults.py``)."""
    from repro.core.mafl import SimResult, evaluate
    from repro.telemetry import RunReport, memory_stats
    from repro.telemetry.report import wave_stats
    from repro.telemetry.timers import PhaseTimers

    timers = PhaseTimers()
    prog, args, plan, layout, eval_rounds, with_state, met = _stage_run(
        vehicles_data, scheme=scheme, rounds=rounds, l_iters=l_iters,
        lr=lr, params=params, seed=seed, eval_every=eval_every,
        use_kernel=use_kernel, init_params=init_params,
        interpretation=interpretation, batch_size=batch_size, mesh=mesh,
        selection=selection, flat=flat, ring_dtype=ring_dtype,
        metrics=metrics, faults=faults, timers=timers)
    M = rounds
    with timers.phase("run"):
        out = jax.block_until_ready(prog(*args))
    met_dev = None
    if met is not None:
        out, met_dev = out[:-1], out[-1]
    if with_state:
        g, ring, trace, (dev_rs, dev_rc) = out
    else:
        g, ring, trace = out
    t_veh, t_time, t_cu, t_cl, t_dlt, t_w = (np.asarray(x) for x in trace)

    # divergence guard: the minibatch stacks were paired to rounds by the
    # host plan — if the device pop order ever disagreed, fail loudly
    # (mirrors the batched engine's dry-run guard) instead of silently
    # training the wrong vehicle's batches.
    if not np.array_equal(t_veh, plan.veh):
        bad = int(np.argmax(t_veh != plan.veh))
        raise RuntimeError(
            "jit engine: device pop order diverged from the host dry run "
            f"at round {bad} (device vehicle {int(t_veh[bad])}, host "
            f"{int(plan.veh[bad])}) — f32 time ties are not expected")
    if not np.allclose(t_time, plan.times, rtol=1e-4, atol=1e-3):
        bad = int(np.argmax(~np.isclose(t_time, plan.times,
                                        rtol=1e-4, atol=1e-3)))
        raise RuntimeError(
            "jit engine: device event times diverged from the host dry run "
            f"at round {bad}: {t_time[bad]} vs {plan.times[bad]}")
    if with_state:
        # selection divergence guard (DESIGN.md §11): the f32 reward
        # accumulators carried through the scan must reproduce the host
        # f64 replay's — the admission decisions were planned from that
        # reward stream, so disagreement means the device saw different
        # arrivals than the masks were computed for
        exp_rs, exp_rc = plan.sel_bandit
        if not np.array_equal(np.asarray(dev_rc), exp_rc):
            raise RuntimeError(
                "jit engine: device bandit arrival counts diverged from "
                "the host selection replay")
        if not np.allclose(np.asarray(dev_rs), exp_rs,
                           rtol=1e-4, atol=1e-3):
            raise RuntimeError(
                "jit engine: device bandit reward accumulators diverged "
                "from the host selection replay")

    if flat and ring_dtype == "bf16":
        # bf16 divergence guard (DESIGN.md §12): the timeline guards above
        # stay exact (times never depend on params); the parameters may
        # only diverge by bf16 rounding — a non-finite master means the
        # quantized chain blew up, so fail loudly instead of returning it
        if not all(bool(jnp.isfinite(x).all())
                   for x in jax.tree_util.tree_leaves(g)):
            raise RuntimeError(
                "jit engine: non-finite master weights under "
                "ring_dtype='bf16' — the quantized snapshot ring diverged "
                "(rerun with ring_dtype='f32' to bisect)")
    eval_idx = {rr: k for k, rr in enumerate(eval_rounds)}
    result = SimResult(scheme=scheme, rounds=[], acc_history=[],
                       loss_history=[], final_params=g)
    with timers.phase("eval"):
        for r in range(M):
            rec = RoundRecord(round=r + 1, time=float(t_time[r]),
                              vehicle=int(t_veh[r]),
                              upload_delay=float(t_cu[r]),
                              train_delay=float(t_cl[r]),
                              weight=float(t_w[r]))
            rr = r + 1
            if rr % eval_every == 0 or rr == rounds:
                if flat:
                    params_r = layout.unpack(ring[eval_idx[rr]])
                else:
                    params_r = jax.tree_util.tree_map(
                        lambda R: R[rr], ring)
                acc, loss = evaluate(params_r, test_images, test_labels)
                rec.accuracy, rec.loss = acc, loss
                result.acc_history.append((rr, acc))
                result.loss_history.append((rr, loss))
                if progress:
                    progress(rr, acc)
            result.rounds.append(rec)
    sel_summary = None if plan.sel is None else plan.sel.summary()
    flt_plan = plan.flt
    flt_report = None
    if flt_plan is not None:
        import dataclasses
        result.extras["faults"] = flt_plan.summary(l_iters)
        flt_report = {"spec": dataclasses.asdict(flt_plan.spec),
                      "counts": flt_plan.counts(l_iters)}
    p = params or ChannelParams()
    channels = {}
    if met is not None:
        channels = {k: np.asarray(v) for k, v in met_dev.items()}
        if flt_plan is not None and "fault_counts" in channels:
            # fault-counter divergence guard (DESIGN.md §16): the scan-
            # carry accumulators must reproduce the f64 fault replay's
            # totals — disagreement means the device consumed a different
            # pop sequence than the fault tables were planned for
            exp = flt_plan.counts_table(l_iters).sum(axis=0)
            if not np.array_equal(channels["fault_counts"], exp):
                raise RuntimeError(
                    "jit engine: device fault counters diverged from the "
                    f"host fault replay ({channels['fault_counts']} vs "
                    f"{exp})")
        # bandit-style reward trace derived from the pop trace — the
        # per-arrival quality signal the selection layer would score
        # (gamma^(cu-1) * zeta^(cl-1)), published whether or not a
        # bandit policy is active
        channels["reward"] = (p.gamma ** (t_cu.astype(np.float64) - 1.0)
                              * p.zeta ** (t_cl.astype(np.float64) - 1.0))
        if with_state:
            channels["reward_sum"] = np.asarray(dev_rs)
            channels["reward_count"] = np.asarray(dev_rc)
    result.report = RunReport(
        engine="jit", scheme=scheme, rounds=rounds, seed=seed,
        metrics_on=met is not None,
        spec=None if met is None else met.to_json(),
        phases=timers.snapshot(), memory=memory_stats(),
        selection=sel_summary, faults=flt_report,
        waves=wave_stats(plan.waves, p.K),
        channels=channels)
    return result

"""Event-driven asynchronous scheduler.

TPU pods are bulk-synchronous, so wall-clock asynchrony is *simulated*: every
vehicle's (train -> upload) cycle produces an upload-completion event at

    t_done = t_download + C_l^i + C_u^i(t_upload_start)

and the RSU consumes events in time order — exactly the paper's arrival
semantics (Fig. 2), with each local-training burst itself a synchronous jit
program.  See DESIGN.md §2 (hardware adaptation).

The vehicle-batched engine (DESIGN.md §3) additionally stashes the result of
a wave-trained local update on the event itself (``local_params`` /
``local_loss``): an event's payload snapshot is frozen at schedule time, so
its local training is independent of every other pending event and can be
computed early without changing the time-ordered aggregation semantics.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Any, Iterator, Optional


@dataclass(order=True)
class UploadEvent:
    time: float
    seq: int
    vehicle: int = field(compare=False)          # 0-based
    download_time: float = field(compare=False, default=0.0)
    train_delay: float = field(compare=False, default=0.0)
    upload_delay: float = field(compare=False, default=0.0)
    payload: Any = field(compare=False, default=None)
    # which train/upload cycle of this vehicle the event belongs to
    cycle: int = field(compare=False, default=0)
    # wave-precomputed local update (vehicle-batched engine only)
    local_params: Any = field(compare=False, default=None, repr=False)
    local_loss: Optional[float] = field(compare=False, default=None)


class EventQueue:
    def __init__(self):
        self._heap: list[UploadEvent] = []
        self._seq = 0

    def push(self, time: float, vehicle: int, **kw) -> UploadEvent:
        ev = UploadEvent(time=time, seq=self._seq, vehicle=vehicle, **kw)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> UploadEvent:
        return heapq.heappop(self._heap)

    def peek(self) -> UploadEvent:
        return self._heap[0]

    def pending(self) -> Iterator[UploadEvent]:
        """All queued events, unordered (the heap as-is)."""
        return iter(self._heap)

    def earliest_time(self) -> float:
        return self._heap[0].time if self._heap else float("inf")

    def as_struct_arrays(self) -> dict:
        """Pending events as structure-of-arrays, sorted by (time, seq).

        The columnar face of the queue: the device-resident engine
        (DESIGN.md §9) seeds its fixed-capacity slot arrays from this —
        payloads are deliberately excluded (the jit engine keeps snapshots
        in its own device-side ring)."""
        import numpy as np
        evs = sorted(self._heap, key=lambda e: (e.time, e.seq))
        return {
            "time": np.array([e.time for e in evs], np.float64),
            "vehicle": np.array([e.vehicle for e in evs], np.int32),
            "download_time": np.array([e.download_time for e in evs],
                                      np.float64),
            "train_delay": np.array([e.train_delay for e in evs],
                                    np.float64),
            "upload_delay": np.array([e.upload_delay for e in evs],
                                     np.float64),
            "cycle": np.array([e.cycle for e in evs], np.int32),
        }

    def __len__(self):
        return len(self._heap)

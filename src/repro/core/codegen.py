"""Codegen-environment fingerprint for the golden-digest gate (DESIGN.md §12).

The golden fixtures pin *bitwise* sha256 digests of trained parameters.
Those digests depend on more than the (jax, numpy) versions the fixtures
record: XLA:CPU's f32 codegen is hardware-dependent — FMA contraction and
vectorization vary with the host CPU's feature set, so the same program on
the same library versions can legitimately produce different low bits on a
different machine (the flat==pytree *relationship* still holds there; only
the absolute bits move).  Version equality alone is therefore the wrong
gate: it passes on a host whose codegen differs from the fixture machine
and the digest assertions fire spuriously.

This module computes a compact fingerprint of the codegen environment by
actually *running* a deterministic probe program through the same kernels
the simulations exercise — local CNN training (solo and vmapped, the two
emission contexts the engines use) plus the staleness-weighted mix / pow /
log2 chain of Eqs. 5-11 — and digesting the f32 results.  Two hosts that
agree on the probe digest agree on the codegen of everything the fixtures
pin; the fixtures record the fingerprint at refresh time and the tests
compare digests only when it matches (``tests/golden/refresh.py``,
``tests/test_golden_traces.py``, ``tests/test_flat_conformance.py``).
"""
from __future__ import annotations

from functools import lru_cache


@lru_cache(maxsize=1)
def codegen_fingerprint() -> dict:
    """``{"backend": ..., "probe": <sha256>}`` for this process's default
    backend.  Deterministic by construction: fixed PRNG keys, synthetic
    data, no dependence on datasets or wall-clock."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpointing.checkpoint import tree_digest
    from repro.core import client as client_mod
    from repro.models.cnn import init_cnn

    params = init_cnn(jax.random.PRNGKey(0))
    l_iters, batch = 2, 8
    imgs = jnp.asarray(
        np.linspace(-1.0, 1.0, l_iters * batch * 28 * 28,
                    dtype=np.float32).reshape(l_iters, batch, 28, 28, 1))
    labs = jnp.asarray((np.arange(l_iters * batch) % 10).astype(np.int32)
                       .reshape(l_iters, batch))
    lr = jnp.float32(0.03)

    # the two training emission contexts the engines use: a solo local
    # scan and a payload-stacked vmap (grouped-convolution lowering)
    solo, _ = jax.jit(client_mod._local_scan)(params, imgs, labs, lr)
    stacked = jax.tree_util.tree_map(lambda x: jnp.stack([x, x * 0.5]),
                                     params)
    wave, _ = jax.jit(jax.vmap(client_mod._local_scan,
                               in_axes=(0, 0, 0, None)))(
        stacked, jnp.stack([imgs, imgs]), jnp.stack([labs, labs]), lr)

    # the Eq. 5-11 arithmetic whose FMA contraction is context-dependent:
    # pow-weighted mix + log2 Shannon rate on a deterministic vector
    @jax.jit
    def chain(a, b):
        weight = jnp.float32(0.9) ** (a - 1.0) * jnp.float32(0.9) ** (b - 1.0)
        alpha = jnp.clip((1.0 - jnp.float32(0.5)) * weight, 0.0, 1.0)
        mix = (1.0 - alpha) * a + alpha * b
        rate = jnp.float32(1e5) * jnp.log2(1.0 + a * b ** jnp.float32(-2.0))
        return mix, rate

    x = jnp.asarray(np.linspace(0.1, 3.0, 1024, dtype=np.float32))
    mix, rate = chain(x, x[::-1])

    probe = {"solo": solo, "wave": wave, "mix": mix, "rate": rate}
    return {"backend": jax.default_backend(),
            "probe": tree_digest(probe)}


def codegen_matches(recorded) -> bool:
    """True iff ``recorded`` (a fixture's ``codegen`` field) matches this
    host.  Fixtures written before the fingerprint existed (no field)
    never match — their digests were pinned blind to the codegen
    environment."""
    if not recorded:
        return False
    return recorded == codegen_fingerprint()

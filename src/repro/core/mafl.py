"""Top-level MAFL simulation (Algorithm 1) — the paper's experiment engine.

Couples the channel/mobility simulator, the event-driven async scheduler, the
vehicle clients, and the RSU aggregation into ``run_simulation``, which
reproduces Figs. 3-5.

Two engines share identical event semantics (DESIGN.md §2-§3):

``engine="serial"``
    One event at a time, exactly Algorithm 1's arrival order.  Each local
    update is a single ``lax.scan`` dispatch.

``engine="batched"`` (default)
    Wave-based: every pending upload's payload snapshot is frozen at
    schedule time, so all pending local updates are mutually independent
    and train together — full ``wave_chunk``-sized slices under
    ``jax.vmap`` of the same scan (one dispatch per chunk, one compiled
    program for the whole run), remainders through the shared serial
    program.  Aggregation still consumes events strictly in time order, so
    the (round, vehicle, time) sequence is bit-identical to the serial
    engine — verified by ``tests/test_engine_equivalence.py``.

``engine="jit"``
    Device-resident (DESIGN.md §9, ``core/jit_engine.py``): the event
    queue becomes fixed per-vehicle slot arrays, slot gains a precomputed
    table, payload snapshots a round-indexed ring, and pop → aggregate →
    re-schedule for all M rounds runs inside one compiled program with
    training hoisted into per-wave vmap blocks.  Same (round, vehicle)
    trace as the host engines with times carried in f32 — pinned by
    ``tests/test_engine_conformance.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel import (ChannelParams, Mobility, RayleighAR1,
                           SlotGainCache, shannon_rate, training_delay,
                           upload_delay)
from repro.core.client import Vehicle, VehicleData, local_update_many
from repro.core.events import EventQueue
from repro.core.server import RSUServer
from repro.faults import arrival_step, initial_vehicles, make_fault_state
from repro.models.cnn import cnn_forward, init_cnn
from repro.selection import make_selection_state


# accepted run_simulation/run_scenario engine names ('unbatched' is a
# legacy alias for 'serial')
ENGINES = ("batched", "serial", "unbatched", "jit")


@dataclass
class SimResult:
    scheme: str
    rounds: list
    acc_history: list          # (round, accuracy)
    loss_history: list         # (round, loss)
    final_params: object = None
    # engine-specific additions (e.g. the corridor engine's per-RSU trace
    # and cohort snapshots) that don't fit the common record schema
    extras: dict = field(default_factory=dict)
    # typed, versioned run telemetry (repro.telemetry.report.RunReport):
    # phase timers and plan statics always; device/host channel data when
    # the run asked for metrics (DESIGN.md §14)
    report: object = None

    def final_accuracy(self) -> float:
        return self.acc_history[-1][1] if self.acc_history else float("nan")


@jax.jit
def _eval_step(params, images, labels, mask):
    """Masked per-batch eval: (#correct, summed NLL) over mask==1 rows."""
    logits = cnn_forward(params, images)
    correct = jnp.sum((jnp.argmax(logits, -1) == labels).astype(
        jnp.float32) * mask)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return correct, jnp.sum(nll * mask)


def evaluate(params, images, labels, batch: int = 1000):
    """Global-model metrics on the test set (Eqs. 1, 12).

    Every slice — including the ragged final one — is padded to exactly
    ``batch`` rows with the padding masked out of both metrics, so all
    rounds of all simulations share one compiled eval program instead of
    retracing ``cnn_forward`` on the leftover shape.  ``batch`` is capped
    at the test-set size — padding a small set up to a large slice would
    waste forward compute on every call."""
    images = np.asarray(images)
    labels = np.asarray(labels)
    n = len(labels)
    batch = max(min(batch, n), 1)
    correct = loss_sum = 0.0
    for s in range(0, n, batch):
        img, lab = images[s:s + batch], labels[s:s + batch]
        m = len(lab)
        if m < batch:
            img = np.concatenate(
                [img, np.zeros((batch - m,) + img.shape[1:], img.dtype)])
            lab = np.concatenate([lab, np.zeros(batch - m, lab.dtype)])
        mask = (np.arange(batch) < m).astype(np.float32)
        c, l = _eval_step(params, jnp.asarray(img), jnp.asarray(lab),
                          jnp.asarray(mask))
        correct += float(c)
        loss_sum += float(l)
    return correct / n, loss_sum / n


def run_simulation(
    vehicles_data: Sequence[VehicleData],
    test_images: np.ndarray,
    test_labels: np.ndarray,
    *,
    scheme: str = "mafl",
    rounds: int = 60,
    l_iters: int = 5,
    lr: float = 0.01,
    params: Optional[ChannelParams] = None,
    seed: int = 0,
    eval_every: int = 1,
    use_kernel: bool = False,
    init_params=None,
    interpretation: str = "mixing",
    progress: Optional[Callable[[int, float], None]] = None,
    engine: str = "batched",
    wave_chunk: int = 16,
    batch_size: int = 128,
    selection=None,
    flat: bool = True,
    ring_dtype: str = "f32",
    metrics=None,
    faults=None,
) -> SimResult:
    """Run M rounds of the chosen aggregation scheme (Algorithm 1).

    Every vehicle uses the same minibatch size — ``min(batch_size, min_i
    D_i)`` — so one world compiles exactly one local-training shape (the
    per-vehicle *data volume* heterogeneity that Eq. 8 feeds on lives in
    the delays, not the minibatch; DESIGN.md §6).

    ``selection`` (None | policy name | ``SelectionSpec``) activates the
    vehicle-selection layer (DESIGN.md §11): unadmitted vehicles are parked
    at (re-)schedule time — they occupy no queue slot and train no wave —
    and epoch boundaries (``spec.resel_every`` arrivals) re-score the fleet.
    ``None`` runs the exact legacy path.

    ``metrics`` (None/'off' | 'on' | ``MetricsSpec``) activates the
    telemetry channels (DESIGN.md §14); the host engines collect them in
    f64 alongside the event loop, the device engines accumulate them in
    the scan carry.  Off is the exact legacy path; phase timers and the
    ``result.report`` record are always attached.

    ``faults`` (None/'off' | profile name | ``FaultSpec``) activates the
    fault-injection layer (DESIGN.md §16): seeded stochastic dropout,
    blackout, partial computation, straggler inflation and staleness-cap
    discard, identical decision-for-decision on every engine.  Off is the
    exact legacy path."""
    from repro.telemetry import metrics_requested
    from repro.telemetry.timers import PhaseTimers

    if engine not in ENGINES:
        raise ValueError(
            f"unknown engine {engine!r}; expected one of {ENGINES}")
    if engine == "jit":
        # device-resident mega-fleet engine (DESIGN.md §9/§12): whole round
        # loop in one compiled program, same event semantics and records
        from repro.core.jit_engine import run_simulation_jit
        return run_simulation_jit(
            vehicles_data, test_images, test_labels, scheme=scheme,
            rounds=rounds, l_iters=l_iters, lr=lr, params=params, seed=seed,
            eval_every=eval_every, use_kernel=use_kernel,
            init_params=init_params, interpretation=interpretation,
            progress=progress, batch_size=batch_size, selection=selection,
            flat=flat, ring_dtype=ring_dtype, metrics=metrics,
            faults=faults)
    if ring_dtype != "f32":
        # the bf16 snapshot ring exists only on the packed flat layout of
        # the device engines (DESIGN.md §12) — an explicit gate, never a
        # silent precision change on the host paths
        raise ValueError(
            f"ring_dtype={ring_dtype!r} requires engine='jit' (or the "
            "corridor engine); the host engines keep full-precision "
            "pytrees")
    p = params or ChannelParams()
    assert len(vehicles_data) == p.K, (len(vehicles_data), p.K)
    key = jax.random.PRNGKey(seed)
    global_params = init_params if init_params is not None else init_cnn(key)

    server = RSUServer(global_params, p, scheme=scheme, use_kernel=use_kernel,
                       interpretation=interpretation)
    fleet_batch = min(batch_size, min(d.size for d in vehicles_data))
    clients = [Vehicle(d, lr=lr, batch_size=fleet_batch, seed=seed)
               for d in vehicles_data]

    timers = PhaseTimers()
    met_req = metrics_requested(metrics)
    # host-side channel collection (DESIGN.md §14): the event loop already
    # sees every value the device accumulators fold, so the host engines
    # record the channels directly in f64
    ch_stale: list = []
    ch_occ: list = []
    ch_gap: list = []
    ch_times: list = []

    sel = make_selection_state(selection, p, Mobility(p), seed, rounds)
    flt = make_fault_state(faults, p, seed, rounds, l_iters)
    timeline = _Timeline(p, seed,
                         cl_scale=None if flt is None else flt.cl_scale)
    queue = timeline.queue
    if engine == "batched":
        # The event timeline depends only on the channel/mobility/data-size
        # processes, never on training results — so a cheap time-only dry
        # run tells us *exactly* which (vehicle, cycle) uploads the M
        # rounds consume, and the wave engine trains nothing else.  (The
        # replay carries its own SelectionState/FaultState, so admission
        # and fault decisions are reproduced byte-for-byte.)
        with timers.phase("plan"):
            consumed = _consumed_events(p, seed, rounds, selection,
                                        faults=faults, l_iters=l_iters)

    def schedule(vehicle: int, t_download: float):
        timeline.schedule(vehicle, t_download, server.global_params)

    for k in initial_vehicles(sel, flt, p.K):
        schedule(k, 0.0)

    result = SimResult(scheme=scheme, rounds=[], acc_history=[],
                       loss_history=[])

    def consume(ev) -> None:
        """One arrival: aggregate in time order, eval, re-download (Fig. 2).

        ``ev.local_params`` must already hold the local update trained from
        the stale payload snapshot."""
        r = server.round                    # 0-based index of this pop
        if met_req:
            # the pop already happened (+1) and the re-schedule has not:
            # the same instant the device engines count isfinite slots at
            ch_occ.append(len(queue) + 1)
            ch_stale.append(ev.time - ev.download_time)
            ch_gap.append(ev.time - (ch_times[-1] if ch_times else 0.0))
            ch_times.append(ev.time)
        # staleness-cap verdict BEFORE aggregation: a discarded arrival
        # still counts as a round, only the model update is skipped
        keep = True if flt is None else flt.on_pop(ev.vehicle, r)[0]
        rec = server.receive(
            ev.local_params, time=ev.time, vehicle=ev.vehicle,
            upload_delay=ev.upload_delay, train_delay=ev.train_delay,
            download_time=ev.download_time, discard=not keep)
        ev.local_params = ev.payload = None
        if server.round % eval_every == 0 or server.round == rounds:
            with timers.phase("eval"):
                acc, loss = evaluate(server.global_params, test_images,
                                     test_labels)
            rec.accuracy, rec.loss = acc, loss
            result.acc_history.append((server.round, acc))
            result.loss_history.append((server.round, loss))
            if progress:
                progress(server.round, acc)
        # mask at schedule: the vehicle re-downloads the fresh global model
        # (Fig. 2) only while admitted AND live; epoch boundaries re-score,
        # recovery sweeps wake dark vehicles whose blackout has passed
        arrival_step(sel, flt, r=r, vehicle=ev.vehicle, time=ev.time,
                     upload_delay=ev.upload_delay,
                     train_delay=ev.train_delay, pending=len(queue),
                     schedule=lambda v: schedule(v, ev.time))
        timeline.prune()

    if engine in ("serial", "unbatched"):
        with timers.phase("run"):
            while server.round < rounds and len(queue):
                ev = queue.pop()
                # local training from the model the vehicle downloaded (the
                # stale snapshot in the payload); the compute runs now, but
                # the ordering and delays follow the event times
                # (DESIGN.md §2).
                ev.local_params, _ = clients[ev.vehicle].local_update(
                    ev.payload, l_iters,
                    n_ep=(flt.epoch_of(ev.vehicle)
                          if flt is not None and flt.spec.has_partial
                          else None))
                consume(ev)
    else:
        with timers.phase("run"):
            while server.round < rounds and len(queue):
                # Wave: train every pending upload that the dry-run proved
                # will be consumed and whose result is missing.  Payload
                # snapshots are frozen at schedule time, so these trainings
                # are mutually independent and zero of them are wasted.
                untrained = sorted(
                    (ev for ev in queue.pending()
                     if ev.local_params is None
                     and (ev.vehicle, ev.cycle) in consumed),
                    key=lambda ev: (ev.time, ev.seq))
                batches = [clients[ev.vehicle].sample_batches(l_iters)
                           for ev in untrained]
                # partial computation (DESIGN.md §16): the epoch count of
                # each pending cycle was fixed at its schedule, so the wave
                # can read it here — all l_iters batches are still drawn
                # (RNG-stream alignment across engines)
                n_eps = ([flt.epoch_of(ev.vehicle) for ev in untrained]
                         if flt is not None and flt.spec.has_partial
                         else None)
                outs, losses = local_update_many(
                    [ev.payload for ev in untrained], batches, lr,
                    chunk=wave_chunk, n_eps=n_eps)
                for ev, out, lo in zip(untrained, outs, losses):
                    ev.local_params, ev.local_loss = out, lo
                # Drain in time order until an event without a precomputed
                # result (freshly re-scheduled) reaches the front —
                # identical arrival semantics to the serial engine.  A
                # front event that is outside the consumed set can only
                # mean rounds are exhausted (the dry run replicates this
                # pop sequence).
                while (server.round < rounds and len(queue)
                       and queue.peek().local_params is not None):
                    consume(queue.pop())
                if (not untrained and server.round < rounds and len(queue)
                        and queue.peek().local_params is None):
                    # the dry run said the front event is never consumed,
                    # yet rounds remain — the timelines have diverged; fail
                    # loudly rather than silently returning a truncated run
                    raise RuntimeError(
                        "batched engine: dry-run consumed-set diverged "
                        f"from live timeline at round {server.round} "
                        f"(front event vehicle={queue.peek().vehicle} "
                        f"cycle={queue.peek().cycle})")

    result.rounds = server.rounds
    result.final_params = server.global_params
    sel_summary = None if sel is None else sel.plan().summary()
    flt_plan = None if flt is None else flt.plan()
    if flt_plan is not None:
        result.extras["faults"] = flt_plan.summary(l_iters)
    result.report = _host_report(
        engine=engine, scheme=scheme, rounds=rounds, seed=seed,
        metrics=metrics, met_req=met_req, p=p, timers=timers,
        selection=sel_summary, records=result.rounds, stale=ch_stale,
        occ=ch_occ, gap=ch_gap, times=ch_times, faults=flt_plan,
        l_iters=l_iters)
    return result


def _host_report(*, engine, scheme, rounds, seed, metrics, met_req, p,
                 timers, selection, records, stale, occ, gap, times,
                 n_rsus=1, up_rsu=None, handover=None,
                 handover_count=None, faults=None, l_iters=1):
    """Build the host engines' :class:`RunReport` (DESIGN.md §14): f64
    channels collected alongside the event loop, bucketed through the same
    planner edges the device path would use (identical by construction —
    the host values ARE the planner replay)."""
    from repro.telemetry.report import RunReport
    from repro.telemetry.spec import resolve_metrics, stale_histogram
    from repro.telemetry.timers import memory_stats

    report = RunReport(engine=engine, scheme=scheme, rounds=rounds,
                       seed=seed, metrics_on=met_req,
                       phases=timers.snapshot(), memory=memory_stats(),
                       selection=selection)
    if faults is not None:
        import dataclasses
        report.faults = {"spec": dataclasses.asdict(faults.spec),
                         "counts": faults.counts(l_iters)}
    if met_req:
        st = np.asarray(stale)
        spec = resolve_metrics(metrics, stale=st, times=np.asarray(times),
                               n_rsus=n_rsus,
                               fault_counters=faults is not None)
        report.spec = spec.to_json()
        channels = {
            "stale_hist": stale_histogram(spec.edges, st, rsu=up_rsu,
                                          n_rsus=n_rsus),
            "occupancy": np.asarray(occ, np.int64),
            "gap": np.asarray(gap),
        }
        if records:
            # the bandit reward IS the paper's delay weight (Eqs. 7, 9) —
            # derived per-pop from the recorded delays for every scheme
            cu = np.array([r.upload_delay for r in records])
            cl = np.array([r.train_delay for r in records])
            channels["reward"] = p.gamma ** (cu - 1.0) * p.zeta ** (cl - 1.0)
        if handover is not None:
            channels["handover"] = np.asarray(handover, np.int64)
            channels["handover_count"] = np.asarray(handover_count,
                                                    np.int64)
        report.channels = channels
    return report


class _Timeline:
    """The event timeline: channel gains, mobility, and the pending-upload
    queue.  Times depend only on (params, seed) — never on training — so a
    payload-free instance replays the identical schedule (DESIGN.md §3).

    ``distance_fn(vehicle, t) -> meters`` defaults to the single-RSU
    :class:`Mobility`; the multi-RSU scenario engine substitutes its
    corridor geometry while keeping every other scheduling rule identical.

    Channel gains are sampled per discrete slot and kept only for the live
    event window (``SlotGainCache``): pops are globally time-ordered, so
    slots below the earliest pending event can never be read again."""

    def __init__(self, p: ChannelParams, seed: int, distance_fn=None,
                 cl_scale=None):
        self.p = p
        self.distance = distance_fn or Mobility(p).distance
        self.gains = SlotGainCache(RayleighAR1(p, seed=seed))
        self.queue = EventQueue()
        self._cycle = [0] * p.K
        # per-vehicle straggler multipliers on the Eq. 8 training delay
        # (DESIGN.md §16) — f64, constant over the run, default identity
        self.cl_scale = cl_scale

    def schedule(self, vehicle: int, t_download: float, payload=None):
        """Vehicle downloads w_g at t_download, trains C_l, uploads C_u.

        The *snapshot of the global model at download time* rides along in
        the event payload — by upload time other vehicles have advanced the
        global model, so this is what makes the uploads genuinely stale
        (the dynamics the paper's weighting is designed around)."""
        p = self.p
        i1 = vehicle + 1                                    # 1-based index
        c_l = training_delay(p, i1)
        if self.cl_scale is not None:
            c_l = c_l * float(self.cl_scale[vehicle])
        t_up = t_download + c_l
        gain = self.gains.at(t_up)[vehicle]
        rate = shannon_rate(p, gain, self.distance(vehicle, t_up))
        c_u = upload_delay(p, rate)
        cyc = self._cycle[vehicle]
        self._cycle[vehicle] += 1
        return self.queue.push(t_up + c_u, vehicle,
                               download_time=t_download, train_delay=c_l,
                               upload_delay=c_u, payload=payload, cycle=cyc)

    def prune(self):
        if len(self.queue):
            self.gains.prune_below(self.queue.earliest_time())


def _consumed_events(p: ChannelParams, seed: int, rounds: int,
                     selection=None, faults=None,
                     l_iters: int = 5) -> set[tuple[int, int]]:
    """Dry-run the timeline (no training, no payloads): the exact set of
    (vehicle, cycle) uploads consumed within ``rounds`` arrivals.  With a
    selection policy or a fault model, the replay drives identical
    ``SelectionState``/``FaultState`` instances so parked, dropped, and
    blacked-out cycles never enter the set."""
    flt = make_fault_state(faults, p, seed, rounds, l_iters)
    tl = _Timeline(p, seed, cl_scale=None if flt is None else flt.cl_scale)
    sel = make_selection_state(selection, p, Mobility(p), seed, rounds)
    for k in initial_vehicles(sel, flt, p.K):
        tl.schedule(k, 0.0)
    out: set[tuple[int, int]] = set()
    while len(out) < rounds and len(tl.queue):
        ev = tl.queue.pop()
        r = len(out)
        out.add((ev.vehicle, ev.cycle))
        if flt is not None:
            flt.on_pop(ev.vehicle, r)
        if sel is None and flt is None:
            tl.schedule(ev.vehicle, ev.time)
        else:
            arrival_step(
                sel, flt, r=r, vehicle=ev.vehicle, time=ev.time,
                upload_delay=ev.upload_delay, train_delay=ev.train_delay,
                pending=len(tl.queue),
                schedule=lambda v, t=ev.time: tl.schedule(v, t))
        tl.prune()
    return out

"""Top-level MAFL simulation (Algorithm 1) — the paper's experiment engine.

Couples the channel/mobility simulator, the event-driven async scheduler, the
vehicle clients, and the RSU aggregation into ``run_simulation``, which
reproduces Figs. 3-5.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel import (ChannelParams, Mobility, RayleighAR1,
                           shannon_rate, training_delay, upload_delay)
from repro.core.client import Vehicle, VehicleData
from repro.core.events import EventQueue
from repro.core.server import RSUServer
from repro.models.cnn import accuracy, cnn_forward, cross_entropy_loss, init_cnn


@dataclass
class SimResult:
    scheme: str
    rounds: list
    acc_history: list          # (round, accuracy)
    loss_history: list         # (round, loss)
    final_params: object = None

    def final_accuracy(self) -> float:
        return self.acc_history[-1][1] if self.acc_history else float("nan")


def evaluate(params, images, labels, batch: int = 1000):
    """Global-model metrics on the test set (Eqs. 1, 12)."""
    accs, losses, n = [], [], len(labels)
    for s in range(0, n, batch):
        img = jnp.asarray(images[s:s + batch])
        lab = jnp.asarray(labels[s:s + batch])
        logits = cnn_forward(params, img)
        accs.append(float(accuracy(logits, lab)) * len(lab))
        losses.append(float(cross_entropy_loss(logits, lab)) * len(lab))
    return sum(accs) / n, sum(losses) / n


def run_simulation(
    vehicles_data: Sequence[VehicleData],
    test_images: np.ndarray,
    test_labels: np.ndarray,
    *,
    scheme: str = "mafl",
    rounds: int = 60,
    l_iters: int = 5,
    lr: float = 0.01,
    params: Optional[ChannelParams] = None,
    seed: int = 0,
    eval_every: int = 1,
    use_kernel: bool = False,
    init_params=None,
    interpretation: str = "mixing",
    progress: Optional[Callable[[int, float], None]] = None,
) -> SimResult:
    """Run M rounds of the chosen aggregation scheme (Algorithm 1)."""
    p = params or ChannelParams()
    assert len(vehicles_data) == p.K, (len(vehicles_data), p.K)
    key = jax.random.PRNGKey(seed)
    global_params = init_params if init_params is not None else init_cnn(key)

    mobility = Mobility(p)
    fading = RayleighAR1(p, seed=seed)
    server = RSUServer(global_params, p, scheme=scheme, use_kernel=use_kernel,
                       interpretation=interpretation)
    clients = [Vehicle(d, lr=lr, seed=seed) for d in vehicles_data]
    queue = EventQueue()

    # channel gains are sampled per discrete slot; cache per int(t)
    gain_cache: dict[int, np.ndarray] = {}

    def gains_at(t: float) -> np.ndarray:
        slot = int(t)
        while max(gain_cache, default=-1) < slot:
            gain_cache[max(gain_cache, default=-1) + 1] = fading.step()
        return gain_cache[slot]

    def schedule(vehicle: int, t_download: float):
        """Vehicle downloads w_g at t_download, trains C_l, uploads C_u.

        The *snapshot of the global model at download time* rides along in
        the event payload — by upload time other vehicles have advanced the
        global model, so this is what makes the uploads genuinely stale
        (the dynamics the paper's weighting is designed around)."""
        i1 = vehicle + 1                                    # 1-based index
        c_l = training_delay(p, i1)
        t_up = t_download + c_l
        gain = gains_at(t_up)[vehicle]
        dist = mobility.distance(vehicle, t_up)
        rate = shannon_rate(p, gain, dist)
        c_u = upload_delay(p, rate)
        queue.push(t_up + c_u, vehicle, download_time=t_download,
                   train_delay=c_l, upload_delay=c_u,
                   payload=server.global_params)

    for k in range(p.K):
        schedule(k, 0.0)

    result = SimResult(scheme=scheme, rounds=[], acc_history=[],
                       loss_history=[])
    while server.round < rounds and len(queue):
        ev = queue.pop()
        # local training from the model the vehicle downloaded (the stale
        # snapshot in the payload); the compute runs now, but the ordering
        # and the delays follow the event times (DESIGN.md §2).
        local_params, _ = clients[ev.vehicle].local_update(
            ev.payload, l_iters)
        rec = server.receive(
            local_params, time=ev.time, vehicle=ev.vehicle,
            upload_delay=ev.upload_delay, train_delay=ev.train_delay,
            download_time=ev.download_time)
        if server.round % eval_every == 0 or server.round == rounds:
            acc, loss = evaluate(server.global_params, test_images,
                                 test_labels)
            rec.accuracy, rec.loss = acc, loss
            result.acc_history.append((server.round, acc))
            result.loss_history.append((server.round, loss))
            if progress:
                progress(server.round, acc)
        # vehicle immediately downloads the fresh global model (Fig. 2)
        schedule(ev.vehicle, ev.time)

    result.rounds = server.rounds
    result.final_params = server.global_params
    return result

"""Global-model aggregation rules.

``mafl_update`` is the paper's Eq. (10)+(11) fused:
    w_r = beta * w_{r-1} + (1 - beta) * (beta_u * beta_l) * w_local
``afl_update`` is the conventional-AFL baseline the paper compares against
(Eq. (11) with unweighted local model).  FedAvg / FedAsync / FedBuff are
standard baselines included beyond the paper.

All rules are pure pytree transforms; the fused elementwise pass is also
available as a Pallas kernel (``repro.kernels.weighted_agg``) selected via
``use_kernel=True`` — the TPU-target implementation of the same math.

The simulation hot path uses the jitted ``mix_update_donated`` /
``literal_update_donated`` variants: the *local* model buffer is donated
(argument 1) — it is produced by one local update and consumed by exactly
one aggregation, so XLA may reuse its memory for the output.  The *global*
model is never donated: pending upload events hold stale snapshots of it
(DESIGN.md §2) that must stay alive until those events fire.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def _ema(global_params, contrib, beta: float):
    b = jnp.float32(beta)
    return jax.tree_util.tree_map(
        lambda g, c: (b * g.astype(jnp.float32) +
                      (1.0 - b) * c.astype(jnp.float32)).astype(g.dtype),
        global_params, contrib)


@partial(jax.jit, donate_argnums=(1,))
def mix_update_donated(global_params, local_params, alpha):
    """w_r = (1-alpha) w_g + alpha w_l with the upload buffer donated.

    ``alpha`` is a traced scalar so every round reuses one compiled program
    (no retrace as the per-round weight changes)."""
    a = jnp.asarray(alpha, jnp.float32)
    return jax.tree_util.tree_map(
        lambda g, l: ((1.0 - a) * g.astype(jnp.float32) +
                      a * l.astype(jnp.float32)).astype(g.dtype),
        global_params, local_params)


@partial(jax.jit, donate_argnums=(1,))
def literal_update_donated(global_params, local_params, beta, weight):
    """Eq. (10)+(11) exactly as printed, upload buffer donated."""
    b = jnp.asarray(beta, jnp.float32)
    w = jnp.asarray(weight, jnp.float32)
    return jax.tree_util.tree_map(
        lambda g, l: (b * g.astype(jnp.float32) + (1.0 - b) * w *
                      l.astype(jnp.float32)).astype(g.dtype),
        global_params, local_params)


def mafl_update(global_params, local_params, beta: float, weight: float,
                use_kernel: bool = False, interpretation: str = "mixing"):
    """The paper's Eq. (10)+(11).

    ``interpretation="literal"`` applies the equations exactly as printed:
        w_r = beta*w_g + (1-beta) * (beta_u*beta_l) * w_local
    which *scales the parameter vector itself* — with Table-I constants the
    weights straddle 1.0 and the global norm drifts (EXPERIMENTS.md ablation).

    ``interpretation="mixing"`` (default) reads the weight as modulating the
    local model's aggregation proportion — consistent with the released-code
    name (AFLweight) and the paper's own Fig. 5 discussion ("the weight of
    the local model"):
        alpha = clip((1-beta) * beta_u * beta_l, 0, 1)
        w_r   = (1-alpha)*w_g + alpha*w_local
    Both are tested; DESIGN.md §1 records the reading.
    """
    if interpretation == "literal":
        if use_kernel:
            from repro.kernels.weighted_agg import ops as agg_ops
            return agg_ops.weighted_agg_tree(global_params, local_params,
                                             beta, weight)
        wgt, b = jnp.float32(weight), jnp.float32(beta)
        return jax.tree_util.tree_map(
            lambda g, l: (b * g.astype(jnp.float32) + (1.0 - b) * wgt *
                          l.astype(jnp.float32)).astype(g.dtype),
            global_params, local_params)
    alpha = float(np.clip((1.0 - beta) * weight, 0.0, 1.0))
    if use_kernel:
        from repro.kernels.weighted_agg import ops as agg_ops
        return agg_ops.weighted_agg_tree(global_params, local_params,
                                         1.0 - alpha, 1.0)
    return _ema(global_params, local_params, 1.0 - alpha)


def chain_coeffs(scheme: str, interpretation: str, beta, weight,
                 t=None, dl_t=None, fedasync_mix=None):
    """Per-upload ``(c, d)`` mix pairs for a chain of aggregations:
    ``g <- c*g + d*l`` (the form ``ring_agg`` streams, DESIGN.md §12).

    Vectorized over a segment's trace columns (``weight``/``t``/``dl_t``
    may be arrays), and arithmetically *identical* per element to the
    per-arrival scalar path in the **device engines'** ``aggregate``
    closures — same f32 expressions (``1.0 - f32(beta)`` etc.) in the
    same order — so a fused chain built from these stays bitwise against
    the device engines' sequential mixes (verified per beta in
    ``tests/test_flat.py``).  The *host* serial path is a different
    reference: it derives mafl's alpha in Python f64 before the f32
    cast, which is why host and device digests are pinned per-engine."""
    if scheme == "mafl" and interpretation == "literal":
        b = jnp.float32(beta)
        c = jnp.broadcast_to(b, jnp.shape(weight))
        return c, (1.0 - b) * jnp.asarray(weight, jnp.float32)
    if scheme == "mafl":
        alpha = jnp.clip((1.0 - jnp.float32(beta)) *
                         jnp.asarray(weight, jnp.float32), 0.0, 1.0)
    elif scheme == "afl":
        alpha = jnp.broadcast_to(1.0 - jnp.float32(beta),
                                 jnp.shape(weight)).astype(jnp.float32)
    elif scheme == "fedasync":
        stale = jnp.maximum(jnp.asarray(t, jnp.float32) -
                            jnp.asarray(dl_t, jnp.float32), 0.0)
        alpha = jnp.float32(fedasync_mix) * (stale + 1.0) ** (-0.5)
    else:
        raise ValueError(f"no chain coefficients for scheme {scheme!r}")
    return 1.0 - alpha, alpha


def afl_update(global_params, local_params, beta: float):
    """Conventional AFL (the paper's baseline): Eq. (11), unweighted."""
    return _ema(global_params, local_params, beta)


def fedavg_update(global_params, local_list: Sequence, sizes: Sequence[int]):
    """Synchronous FedAvg: data-size-weighted mean of all K locals."""
    total = float(sum(sizes))
    ws = [s / total for s in sizes]

    def avg(*leaves):
        acc = jnp.zeros_like(leaves[0], jnp.float32)
        for w, l in zip(ws, leaves):
            acc = acc + w * l.astype(jnp.float32)
        return acc.astype(leaves[0].dtype)

    return jax.tree_util.tree_map(avg, *local_list)


def fedasync_update(global_params, local_params, base_mix: float,
                    staleness: float, a: float = 0.5):
    """FedAsync (Xie et al. 2019): polynomial staleness discount
    alpha = base_mix * (staleness + 1)^-a, w_r = (1-alpha) w_g + alpha w_l."""
    alpha = base_mix * (staleness + 1.0) ** (-a)
    return _ema(global_params, local_params, 1.0 - alpha)


class FedBuffAggregator:
    """FedBuff (Nguyen et al. 2022): buffer deltas, aggregate every Kb."""

    def __init__(self, buffer_size: int = 3, lr: float = 1.0):
        self.buffer_size = buffer_size
        self.lr = lr
        self._buf = []

    def add(self, global_params, local_params):
        delta = jax.tree_util.tree_map(
            lambda l, g: l.astype(jnp.float32) - g.astype(jnp.float32),
            local_params, global_params)
        self._buf.append(delta)
        if len(self._buf) < self.buffer_size:
            return global_params, False

        def mean_delta(*ds):
            return sum(d for d in ds) / len(ds)

        md = jax.tree_util.tree_map(mean_delta, *self._buf)
        self._buf = []
        new = jax.tree_util.tree_map(
            lambda g, d: (g.astype(jnp.float32) +
                          self.lr * d).astype(g.dtype), global_params, md)
        return new, True

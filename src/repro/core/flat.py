"""Packed flat-parameter representation (DESIGN.md §12).

The AFL update (Eq. 10+11) is an elementwise mix, so at fleet scale the
RSU-side cost is pure memory traffic over the model.  A pytree model pays
that traffic once *per leaf* per upload — eight kernel launches for the
paper CNN — and forces the snapshot ring to be a pytree of ``[M+1, ...]``
buffers.  :class:`ParamLayout` fixes the layout instead: every model state
is one lane-aligned contiguous ``f32[P]`` buffer, the ring is a single
``[M+1, P]`` array (download = one row gather, upload = one row scatter),
and a whole chain of staleness-weighted mixes streams through one fused
kernel (``repro.kernels.weighted_agg.ring_agg``).

The layout is static host data derived once from a template pytree:
per-leaf offsets (each aligned to the 128-lane boundary so unpacked views
keep TPU-friendly alignment), shapes, and the padded total ``P``.  Packing
writes each leaf into its slice; unpacking is ``lax.slice`` + ``reshape``
per leaf — under ``jit`` these are views XLA folds into the consumers, so
training code keeps operating on ordinary pytrees with zero host copies.
Both directions preserve bits exactly (``unpack(pack(t)) == t`` bitwise),
which is what lets the flat engines reproduce the PR-4 golden traces.

Leading batch axes broadcast through both directions: packing a tree whose
leaves carry ``[n, ...]`` produces ``[n, P]``; unpacking ``[n, P]`` (or
``[M+1, P]`` ring rows) returns the batched tree.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from functools import cached_property

import jax
import jax.numpy as jnp
import numpy as np

# the '/'-joined path-key convention is checkpointing's; one definition
from repro.checkpointing.checkpoint import _part

LANE = 128      # pack granularity == the kernel lane width


def _align(n: int) -> int:
    return ((n + LANE - 1) // LANE) * LANE


@dataclass(frozen=True)
class ParamLayout:
    """Static offsets/shapes of a pytree packed into one ``[P]`` buffer.

    ``names`` are '/'-joined path keys (the checkpointing convention), in
    canonical ``tree_flatten`` order; ``dtypes`` are the template dtypes
    restored by :meth:`unpack`.  Hashable, so it can ride in program-cache
    keys."""
    names: tuple            # str per leaf
    shapes: tuple           # tuple[int, ...] per leaf
    dtypes: tuple           # str per leaf
    offsets: tuple          # int per leaf, lane-aligned
    sizes: tuple            # int per leaf
    P: int                  # padded total length (multiple of LANE)
    treedef: object = None  # jax treedef (not part of eq/hash identity)

    def __eq__(self, other):
        if not isinstance(other, ParamLayout):
            return NotImplemented
        return self.signature() == other.signature()

    def __hash__(self):
        return hash(self.signature())

    def signature(self) -> tuple:
        return (self.names, self.shapes, self.dtypes, self.offsets,
                self.sizes, self.P)

    # -- construction -------------------------------------------------------
    @classmethod
    def from_tree(cls, tree) -> "ParamLayout":
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        names, shapes, dtypes, offsets, sizes = [], [], [], [], []
        off = 0
        for path, leaf in flat:
            names.append("/".join(_part(p) for p in path))
            shape = tuple(int(s) for s in leaf.shape)
            size = int(np.prod(shape)) if shape else 1
            shapes.append(shape)
            dtypes.append(str(jnp.asarray(leaf).dtype))
            offsets.append(off)
            sizes.append(size)
            off = _align(off + size)
        return cls(names=tuple(names), shapes=tuple(shapes),
                   dtypes=tuple(dtypes), offsets=tuple(offsets),
                   sizes=tuple(sizes), P=off, treedef=treedef)

    @cached_property
    def nbytes_f32(self) -> int:
        return 4 * self.P

    # -- pack / unpack ------------------------------------------------------
    def _batch_of(self, tree) -> tuple:
        leaf0 = jax.tree_util.tree_leaves(tree)[0]
        nd = len(self.shapes[0])
        batch = tuple(leaf0.shape[:leaf0.ndim - nd])
        return batch

    def pack(self, tree, dtype=jnp.float32):
        """Tree -> contiguous ``[*batch, P]`` buffer (gaps/padding zero)."""
        leaves = jax.tree_util.tree_leaves(tree)
        assert len(leaves) == len(self.names), \
            (len(leaves), len(self.names))
        batch = self._batch_of(tree)
        out = jnp.zeros(batch + (self.P,), dtype)
        for leaf, off, size, shape in zip(leaves, self.offsets, self.sizes,
                                          self.shapes):
            assert tuple(leaf.shape) == batch + shape, \
                (leaf.shape, batch, shape)
            flat = jnp.reshape(leaf, batch + (size,)).astype(dtype)
            out = out.at[..., off:off + size].set(flat)
        return out

    def unpack(self, flat):
        """``[*batch, P]`` buffer -> tree of template-dtype leaves.

        Each leaf is a slice+reshape view; a non-f32 buffer (the bf16 ring
        mode) is cast back to the template dtype leaf-by-leaf."""
        batch = tuple(flat.shape[:-1])
        assert flat.shape[-1] == self.P, (flat.shape, self.P)
        leaves = []
        for off, size, shape, dt in zip(self.offsets, self.sizes,
                                        self.shapes, self.dtypes):
            leaf = jnp.reshape(flat[..., off:off + size], batch + shape)
            leaves.append(leaf.astype(dt))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    # -- serialization (checkpointing) --------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "names": list(self.names),
            "shapes": [list(s) for s in self.shapes],
            "dtypes": list(self.dtypes),
            "offsets": list(self.offsets),
            "sizes": list(self.sizes),
            "P": self.P,
        })

    @classmethod
    def from_json(cls, text: str) -> "ParamLayout":
        """Rebuild a layout that can unpack without a template tree.

        The treedef is reconstructed as a nested *dict* keyed by the
        '/'-joined path components (a list/tuple pytree therefore
        restores as a dict with stringified indices — canonicalized, not
        silently reordered): dict flattening sorts keys lexically, which
        can differ from the stored leaf order (e.g. '10' < '2'), so the
        per-leaf columns are permuted to the rebuilt treedef's own
        flatten order — every name keeps its offsets/shape/dtype."""
        d = json.loads(text)
        nested: dict = {}
        for name in d["names"]:
            node = nested
            parts = name.split("/")
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = 0
        flat, treedef = jax.tree_util.tree_flatten_with_path(nested)
        canonical = ["/".join(_part(p) for p in path) for path, _ in flat]
        assert sorted(canonical) == sorted(d["names"]), \
            (canonical, d["names"])
        by_name = {n: i for i, n in enumerate(d["names"])}
        order = [by_name[n] for n in canonical]
        lay = cls(names=tuple(canonical),
                  shapes=tuple(tuple(d["shapes"][i]) for i in order),
                  dtypes=tuple(d["dtypes"][i] for i in order),
                  offsets=tuple(d["offsets"][i] for i in order),
                  sizes=tuple(d["sizes"][i] for i in order),
                  P=int(d["P"]), treedef=None)
        object.__setattr__(lay, "treedef", treedef)
        return lay


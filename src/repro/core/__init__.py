"""The paper's primary contribution: mobility-aware asynchronous federated
learning (MAFL) — delay weights (Eqs. 3-9), weighted aggregation (Eqs. 10-11),
the RSU server, vehicle clients, the event-driven async scheduler, and the
named-scenario registry for launching fleets of any size."""
from repro.core.aggregation import (FedBuffAggregator, afl_update,
                                    fedasync_update, fedavg_update,
                                    mafl_update, mix_update_donated)
from repro.core.client import Vehicle, VehicleData, local_update_many
from repro.core.events import EventQueue, UploadEvent
from repro.core.mafl import SimResult, evaluate, run_simulation
from repro.core.scenarios import (Scenario, build_world, get_scenario,
                                  list_scenarios, run_scenario)
from repro.core.server import RSUServer, RoundRecord
from repro.core.weights import (combined_weight, training_weight,
                                upload_weight, weighted_local_model)

__all__ = [
    "FedBuffAggregator", "afl_update", "fedasync_update", "fedavg_update",
    "mafl_update", "mix_update_donated", "Vehicle", "VehicleData",
    "local_update_many", "EventQueue", "UploadEvent", "SimResult",
    "evaluate", "run_simulation", "Scenario", "build_world", "get_scenario",
    "list_scenarios", "run_scenario", "RSUServer", "RoundRecord",
    "combined_weight", "training_weight", "upload_weight",
    "weighted_local_model",
]

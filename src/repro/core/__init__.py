"""The paper's primary contribution: mobility-aware asynchronous federated
learning (MAFL) — delay weights (Eqs. 3-9), weighted aggregation (Eqs. 10-11),
the RSU server, vehicle clients, and the event-driven async scheduler."""
from repro.core.aggregation import (FedBuffAggregator, afl_update,
                                    fedasync_update, fedavg_update,
                                    mafl_update)
from repro.core.client import Vehicle, VehicleData
from repro.core.events import EventQueue, UploadEvent
from repro.core.mafl import SimResult, evaluate, run_simulation
from repro.core.server import RSUServer, RoundRecord
from repro.core.weights import (combined_weight, training_weight,
                                upload_weight, weighted_local_model)

__all__ = [
    "FedBuffAggregator", "afl_update", "fedasync_update", "fedavg_update",
    "mafl_update", "Vehicle", "VehicleData", "EventQueue", "UploadEvent",
    "SimResult", "evaluate", "run_simulation", "RSUServer", "RoundRecord",
    "combined_weight", "training_weight", "upload_weight",
    "weighted_local_model",
]

"""Scenario registry: named, parameterized simulation worlds (DESIGN.md §8).

The paper evaluates one world — K=10 vehicles under a single RSU with
Table-I heterogeneity.  The ROADMAP's north star needs fleets two orders of
magnitude larger and qualitatively different regimes (non-IID shards,
multi-RSU corridors with handover).  A ``Scenario`` bundles everything
needed to build such a world — fleet size, data heterogeneity, channel
overrides, RSU topology — so benchmarks, examples, and tests launch any of
them from a name:

    from repro.core.scenarios import run_scenario
    result = run_scenario("fleet-k100", rounds=20)

Multi-RSU scenarios (``n_rsus > 1``) run a corridor of RSUs, each with its
own cohort model; a vehicle uploads to the RSU serving its position at
arrival time (handover), and every ``reconcile_every`` arrivals the cohort
models are reconciled (FedAvg or EMA — the corridor-scale version of the
hierarchical cross-pod pmean).  Two engines exist for them:
``engine="corridor"`` (default — the device-resident ``repro.corridor``
subsystem, DESIGN.md §10) and ``engine="serial"`` (the retired host loop in
``corridor.reference``, kept as the conformance oracle).  Requesting a
single-RSU engine for a corridor world — or vice versa — raises instead of
silently substituting.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from repro.channel import ChannelParams, CorridorMobility
from repro.core.mafl import ENGINES, SimResult, run_simulation
from repro.corridor.engine import run_corridor_simulation
from repro.corridor.reference import run_handover_simulation
from repro.faults import scenario_faults

# legacy alias: the corridor geometry now lives in channel/mobility.py as
# the public, vectorized CorridorMobility (it used to be an ad-hoc
# per-vehicle helper class here)
_Corridor = CorridorMobility

# engines valid for multi-RSU corridor scenarios ('serial' is the retired
# reference loop; single-RSU worlds accept `ENGINES` instead)
CORRIDOR_ENGINES = ("corridor", "serial")


@dataclass(frozen=True)
class Scenario:
    """Everything needed to build and run one simulation world."""
    name: str
    description: str
    K: int = 10
    rounds: int = 40
    l_iters: int = 5
    lr: float = 0.03
    scheme: str = "mafl"
    # data world
    n_train: int = 6000
    n_test: int = 800
    noise: float = 0.5
    scale: float = 0.02
    dirichlet_alpha: Optional[float] = None
    max_per_vehicle: Optional[int] = None
    # topology
    n_rsus: int = 1
    reconcile_every: int = 8
    # cloud-tier reconciliation (multi-RSU only): "fedavg" = every cohort
    # adopts the cross-RSU mean; "ema" = each cohort moves reconcile_tau
    # toward it (DESIGN.md §10)
    reconcile_mode: str = "fedavg"
    reconcile_tau: float = 0.5
    # initial corridor placement: "uniform" traffic or a "rush" wave
    # packed into the westmost segment (CorridorMobility entry profiles)
    corridor_entry: str = "uniform"
    # vehicle selection (DESIGN.md §11): policy name (None = the paper's
    # admit-everyone baseline with zero selection machinery), per-RSU
    # admission cap k, per-RSU upload-airtime budget (seconds/cycle),
    # bandit exploration probability, and the single-RSU re-selection
    # epoch in rounds (corridor worlds re-score at reconcile boundaries)
    selection: Optional[str] = None
    selection_k: Optional[int] = None
    selection_budget: Optional[float] = None
    selection_eps: float = 0.1
    resel_every: Optional[int] = None
    # snapshot-ring dtype on the device engines' flat fast path (DESIGN.md
    # §12): "f32" = bitwise-exact (golden-pinned); "bf16" = half-memory
    # ring + upload buffers around f32 master weights — an explicit
    # opt-in, never a default precision change
    ring_dtype: str = "f32"
    # fault injection (DESIGN.md §16): name of a FaultSpec profile from
    # ``repro.faults.PROFILES`` (None = the fault-free world — the engines
    # compile the identical program and share its cache entry), plus
    # dataclasses.replace(...) override pairs applied to the profile
    faults: Optional[str] = None
    faults_overrides: tuple = ()
    # dataclasses.replace(...) overrides applied to ChannelParams
    channel_overrides: tuple = ()

    def channel(self) -> ChannelParams:
        return dataclasses.replace(ChannelParams(), K=self.K,
                                   **dict(self.channel_overrides))

    def selection_spec(self):
        """The scenario's :class:`repro.selection.SelectionSpec` (or None)."""
        from repro.selection import scenario_spec
        return scenario_spec(self)


_REGISTRY: dict[str, Scenario] = {}


def register(sc: Scenario) -> Scenario:
    if sc.name in _REGISTRY:
        raise ValueError(f"duplicate scenario {sc.name!r}")
    _REGISTRY[sc.name] = sc
    return sc


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown scenario {name!r}; known: {known}") from None


def list_scenarios() -> list[str]:
    return sorted(_REGISTRY)


register(Scenario(
    name="paper-k10",
    description="The paper's Section V-A world: K=10, Table-I "
                "heterogeneity, IID shards (CPU-scaled).",
))
register(Scenario(
    name="paper-k10-noniid",
    description="Paper world with Dirichlet(0.5) class-skewed shards.",
    dirichlet_alpha=0.5,
))
register(Scenario(
    name="quick-k5",
    description="Five-vehicle smoke world for tests and CI.",
    K=5, rounds=10, l_iters=2, n_train=1200, n_test=240, scale=0.01,
))
register(Scenario(
    name="fleet-k100",
    description="Fleet-scale: 100 vehicles under one RSU; shard storage "
                "capped so the wave engine batches ~uniform minibatches.",
    K=100, rounds=120, scale=0.022, max_per_vehicle=512,
    n_train=4000, n_test=800,
))
register(Scenario(
    name="fleet-k100-noniid",
    description="100-vehicle fleet with Dirichlet(0.3) heterogeneity.",
    K=100, rounds=120, scale=0.022, max_per_vehicle=512,
    n_train=4000, n_test=800, dirichlet_alpha=0.3,
))
register(Scenario(
    name="fleet-k1000",
    description="Mega-fleet: 1000 vehicles under one RSU, single local "
                "step per download (many clients x few local iterations); "
                "sized for engine='jit' (DESIGN.md §9) — the snapshot ring "
                "holds rounds+1 models instead of 1000 payloads.",
    K=1000, rounds=30, l_iters=1, scale=0.004, max_per_vehicle=256,
    n_train=4000, n_test=400,
))
register(Scenario(
    name="fleet-k1000-noniid",
    description="Mega-fleet with Dirichlet(0.3) class-skewed shards.",
    K=1000, rounds=30, l_iters=1, scale=0.004, max_per_vehicle=256,
    n_train=4000, n_test=400, dirichlet_alpha=0.3,
))
register(Scenario(
    name="fleet-k10000",
    description="Giga-fleet: 10000 vehicles under one RSU — the regime "
                "the DRL-selection literature studies (PAPERS.md) and the "
                "flat fast path unlocks: the bf16 snapshot ring + packed "
                "upload buffers halve the ring memory that caps the f32 "
                "pytree layout (DESIGN.md §12), and aggregation streams "
                "as fused ring_agg chains.",
    K=10000, rounds=60, l_iters=1, scale=0.0008, max_per_vehicle=64,
    n_train=4000, n_test=400, ring_dtype="bf16",
))
register(Scenario(
    name="platoon-burst-k500",
    description="Bursty arrivals: 500 vehicles in platoons of 25 sharing "
                "the leader's compute/data (identical training delays), so "
                "uploads land in near-simultaneous bursts — stress test "
                "for time-ordered consumption under the jit engine.",
    K=500, rounds=40, l_iters=1, scale=0.005, max_per_vehicle=256,
    n_train=4000, n_test=400,
    channel_overrides=(("platoon", 25),),
))
register(Scenario(
    name="highway-k40-handover",
    description="Four-RSU corridor, 40 vehicles with handover and "
                "periodic cross-RSU reconciliation.",
    K=40, rounds=80, n_rsus=4, reconcile_every=8,
    scale=0.02, max_per_vehicle=512, n_train=4000, n_test=800,
))
register(Scenario(
    name="corridor-quick-r2-k8",
    description="Two-RSU, eight-vehicle corridor smoke world for tests "
                "and the CI corridor bench.",
    K=8, rounds=8, l_iters=1, n_rsus=2, reconcile_every=4,
    n_train=1200, n_test=240, scale=0.01,
))
register(Scenario(
    name="corridor-r4-k400",
    description="Conformance-sized corridor: four RSUs, 400 vehicles, "
                "device-resident handover engine vs the serial reference.",
    K=400, rounds=40, l_iters=1, n_rsus=4, reconcile_every=8,
    scale=0.006, max_per_vehicle=256, n_train=4000, n_test=400,
))
register(Scenario(
    name="corridor-r8-k4000",
    description="Mega-corridor: eight RSUs, 4000 vehicles — four times "
                "the largest single-RSU fleet; sized for "
                "engine='corridor' (the serial reference is extrapolated "
                "only, DESIGN.md §10).",
    K=4000, rounds=40, l_iters=1, n_rsus=8, reconcile_every=8,
    scale=0.0015, max_per_vehicle=128, n_train=4000, n_test=400,
))
register(Scenario(
    name="fleet-k1000-topk",
    description="Mega-fleet with weighted-topk selection (DESIGN.md §11): "
                "the RSU admits the 250 best vehicles by data x compute x "
                "predicted residence time, so waves shrink 4x at equal "
                "rounds (arXiv:2304.02832's selection ingredients).",
    K=1000, rounds=30, l_iters=1, scale=0.004, max_per_vehicle=256,
    n_train=4000, n_test=400,
    selection="weighted-topk", selection_k=250,
))
register(Scenario(
    name="fleet-k1000-budget",
    description="Mega-fleet under a per-cycle upload-airtime budget "
                "(arXiv:2210.15496's binding constraint): cheapest-upload "
                "vehicles admitted until 0.5 s of slot budget is spent.",
    K=1000, rounds=30, l_iters=1, scale=0.004, max_per_vehicle=256,
    n_train=4000, n_test=400,
    selection="budget", selection_budget=0.5,
))
register(Scenario(
    name="corridor-r4-k400-bandit",
    description="Conformance-sized corridor with eps-greedy bandit "
                "selection: each RSU admits its 25 best vehicles by "
                "historical delay-weight reward (10% exploration), "
                "re-scored at every reconcile boundary so handed-over "
                "vehicles are re-scored by their new RSU.",
    K=400, rounds=40, l_iters=1, n_rsus=4, reconcile_every=8,
    scale=0.006, max_per_vehicle=256, n_train=4000, n_test=400,
    selection="eps-bandit", selection_k=25, selection_eps=0.1,
))
register(Scenario(
    name="corridor-rush-hour-r8-k4000",
    description="Rush hour on the mega-corridor: 4000 vehicles in "
                "platoons of 50 entering at the west end, a density wave "
                "propagating down the eight RSU cells (bursty arrivals + "
                "skewed per-RSU load).",
    K=4000, rounds=40, l_iters=1, n_rsus=8, reconcile_every=8,
    scale=0.0015, max_per_vehicle=128, n_train=4000, n_test=400,
    corridor_entry="rush", channel_overrides=(("platoon", 50),),
))
register(dataclasses.replace(
    get_scenario("fleet-k1000"),
    name="fleet-k1000-flaky",
    description="Mega-fleet under flaky connectivity (DESIGN.md §16): "
                "8% of uploads drop mid-flight and vehicles fall into "
                "Gilbert-Elliott blackouts (~30 s mean), with uploads "
                "staler than 12 rounds discarded at the RSU — the "
                "graceful-degradation baseline for the faults bench.",
    faults="flaky",
))
register(dataclasses.replace(
    get_scenario("corridor-rush-hour-r8-k4000"),
    name="corridor-rush-hour-deadzone-r8-k4000",
    description="Rush hour on the mega-corridor with coverage dead zones "
                "(DESIGN.md §16): 10% blackout entry per cycle with ~60 s "
                "mean outages — a platoon that enters a dead zone goes "
                "dark as a block — and a 16-round staleness cap at every "
                "RSU; recovered vehicles re-admit at reconcile "
                "boundaries.",
    faults="deadzone",
))
register(dataclasses.replace(
    get_scenario("fleet-k1000"),
    name="fleet-k1000-throttled",
    description="Mega-fleet under compute throttling (DESIGN.md §16): "
                "half the training cycles finish only a prefix of the "
                "local epochs (partial computation), 30% of vehicles are "
                "4x stragglers, and an 8-round staleness cap discards "
                "what arrives too late.",
    faults="throttled",
))


def build_world(sc: Scenario, seed: int = 0):
    """Materialize (vehicles, test_images, test_labels, params) for ``sc``."""
    # deferred: repro.data imports repro.core.client, so a module-level
    # import here would make the repro.core package circular
    from repro.data import partition_vehicles, synth_mnist
    tr_i, tr_l, te_i, te_l = synth_mnist(n_train=sc.n_train,
                                         n_test=sc.n_test, seed=0,
                                         noise=sc.noise)
    p = sc.channel()
    veh = partition_vehicles(tr_i, tr_l, p, seed=seed, scale=sc.scale,
                             dirichlet_alpha=sc.dirichlet_alpha,
                             max_per_vehicle=sc.max_per_vehicle)
    return veh, te_i, te_l, p


def run_scenario(scenario: str | Scenario, *, seed: int = 0,
                 engine: Optional[str] = None, eval_every: int = 10,
                 progress=None, use_kernel: bool = False, mesh=None,
                 record_cohorts: bool = False, flat: Optional[bool] = None,
                 metrics=None, **overrides) -> SimResult:
    """Build the named world and run it; ``overrides`` replace Scenario
    fields (e.g. ``rounds=20`` for a shortened run, or
    ``ring_dtype="bf16"`` for the explicit half-memory ring opt-in).

    ``engine=None`` auto-selects by topology: ``"batched"`` for single-RSU
    worlds, ``"corridor"`` (the device-resident ``repro.corridor`` engine)
    for multi-RSU ones.  An explicit engine that cannot run the scenario's
    topology raises — the old behavior of silently substituting the serial
    handover loop for whatever was requested is gone.  ``mesh`` /
    ``record_cohorts`` reach the corridor engine only.  ``flat`` selects
    the device engines' packed-buffer fast path (DESIGN.md §12); ``None``
    means the engine default (flat on).  ``metrics="on"`` enables the
    telemetry channels (DESIGN.md §14) on every engine; the returned
    ``result.report`` is stamped with the scenario name.  A scenario with
    a ``faults`` profile (DESIGN.md §16) threads the resolved
    :class:`~repro.faults.spec.FaultSpec` into every engine
    (``engine='vmap'`` rejects fault worlds — the sweep tier has no
    per-world program structure)."""
    sc = get_scenario(scenario) if isinstance(scenario, str) else scenario
    if overrides:
        sc = dataclasses.replace(sc, **overrides)
    flt = scenario_faults(sc)
    if sc.ring_dtype != "f32" and (engine not in (None, "jit", "corridor",
                                                  "vmap")
                                   or flat is False):
        raise ValueError(
            f"ring_dtype={sc.ring_dtype!r} needs the flat fast path of a "
            "device engine (engine='jit' or the corridor engine); the "
            "host engines and the pytree layout keep full precision")
    if sc.n_rsus > 1:
        eng = engine or "corridor"
        if eng not in CORRIDOR_ENGINES:
            raise ValueError(
                f"engine {eng!r} cannot run multi-RSU scenario "
                f"{sc.name!r} (n_rsus={sc.n_rsus}); corridor scenarios "
                f"accept {CORRIDOR_ENGINES}")
    else:
        # a non-f32 ring only exists on the jit engine's flat path, so it
        # flips the single-RSU auto-selection from "batched" to "jit"
        eng = engine or ("jit" if sc.ring_dtype != "f32" else "batched")
        if eng in CORRIDOR_ENGINES and eng not in ENGINES:
            raise ValueError(
                f"engine {eng!r} needs a multi-RSU corridor scenario; "
                f"{sc.name!r} has a single RSU — use one of {ENGINES}")
        if eng not in ENGINES and eng != "vmap":
            raise ValueError(
                f"unknown engine {eng!r}; expected one of {ENGINES} or "
                f"'vmap' (single-RSU) or {CORRIDOR_ENGINES} (multi-RSU)")
    if sc.n_rsus == 1 and eng == "vmap":
        # a W=1 sweep batch (DESIGN.md §15): the world runs through the
        # multi-world sweep program, which degenerates to the solo jit
        # program when every channel scalar is batch-uniform — same bits
        if use_kernel or mesh is not None or record_cohorts:
            raise ValueError(
                "engine='vmap' has no use_kernel/mesh/record_cohorts: "
                "the sweep tier compiles the flat in-scan program only "
                "(DESIGN.md §15) — run the world solo with engine='jit'")
        if flat is False:
            raise ValueError(
                "engine='vmap' is flat-only: the world axis lives on the "
                "packed [W, P] buffer (DESIGN.md §15)")
        from repro.core.sweep import run_simulation_vmap
        cb = None if progress is None else (
            lambda _w, rr, acc: progress(rr, acc))
        return _stamp(run_simulation_vmap(
            [(sc, seed)], eval_every=eval_every, metrics=metrics,
            progress=cb)[0], sc)
    veh, te_i, te_l, p = build_world(sc, seed=seed)
    if sc.n_rsus > 1:
        if eng == "serial":
            if mesh is not None or record_cohorts:
                # no silent substitution: these exist only on the
                # device-resident engine
                raise ValueError(
                    "mesh/record_cohorts require engine='corridor'; the "
                    "serial reference runs unsharded and keeps no cohort "
                    "snapshots")
            return _stamp(run_handover_simulation(
                sc, veh, te_i, te_l, p, seed=seed, eval_every=eval_every,
                use_kernel=use_kernel, progress=progress,
                metrics=metrics, faults=flt), sc)
        return _stamp(run_corridor_simulation(
            sc, veh, te_i, te_l, p, seed=seed, eval_every=eval_every,
            use_kernel=use_kernel, mesh=mesh,
            record_cohorts=record_cohorts, progress=progress, flat=flat,
            metrics=metrics, faults=flt), sc)
    kw = {} if flat is None else {"flat": flat}
    return _stamp(run_simulation(
        veh, te_i, te_l, scheme=sc.scheme,
        rounds=sc.rounds, l_iters=sc.l_iters, lr=sc.lr,
        params=p, seed=seed, eval_every=eval_every,
        use_kernel=use_kernel, engine=eng,
        progress=progress, selection=sc.selection_spec(),
        ring_dtype=sc.ring_dtype, metrics=metrics, faults=flt,
        **kw), sc)


def _stamp(result: SimResult, sc: Scenario) -> SimResult:
    """Stamp the scenario name onto the run's telemetry report."""
    if getattr(result, "report", None) is not None:
        result.report.scenario = sc.name
    return result


# ---------------------------------------------------------------------------
# multi-world sweeps (DESIGN.md §15)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SweepSpec:
    """A grid of worlds over one base scenario.

    ``variants`` is a tuple of override-sets — each itself a tuple of
    ``(field, value)`` pairs applied to the base scenario with
    ``dataclasses.replace`` (so e.g. a beta ablation is
    ``variants=tuple((("channel_overrides", (("beta", b),)),)
    for b in BETAS)``) — and every variant runs at every seed.
    World order is variant-major: ``w = i_variant * len(seeds) + i_seed``.
    ``overrides`` apply to the base scenario before the variants do."""
    scenario: object = "paper-k10"        # name or Scenario
    seeds: tuple = (0,)
    variants: tuple = ((),)
    overrides: tuple = ()
    eval_every: int = 10

    def worlds(self) -> list:
        """The grid as ``[(Scenario, seed), ...]``, variant-major."""
        sc = (get_scenario(self.scenario)
              if isinstance(self.scenario, str) else self.scenario)
        if self.overrides:
            sc = dataclasses.replace(sc, **dict(self.overrides))
        out = []
        for var in self.variants:
            sc_v = dataclasses.replace(sc, **dict(var)) if var else sc
            for seed in self.seeds:
                out.append((sc_v, int(seed)))
        return out


def run_sweep(spec: SweepSpec, *, engine: str = "vmap",
              progress=None) -> list[SimResult]:
    """Run every world of ``spec``; returns per-world ``SimResult``s in
    variant-major order, each stamped with its scenario and carrying an
    engine-appropriate ``RunReport``.

    ``engine="vmap"`` (default) runs the whole grid as ONE compiled
    dispatch of the multi-world sweep program (DESIGN.md §15);
    ``engine="jit"`` runs the same worlds serially through the solo
    engine — the conformance oracle and the benchmark baseline.  The two
    produce bitwise-identical per-world results (pinned by
    ``tests/test_vmap_sweep.py``).  ``progress`` fires post-hoc as
    ``progress(world_index, round, acc)`` under either engine."""
    worlds = spec.worlds()
    if engine == "vmap":
        from repro.core.sweep import run_simulation_vmap
        results = run_simulation_vmap(worlds, eval_every=spec.eval_every,
                                      progress=progress)
    elif engine == "jit":
        results = []
        for w, (sc, seed) in enumerate(worlds):
            cb = None if progress is None else (
                lambda rr, acc, _w=w: progress(_w, rr, acc))
            results.append(run_scenario(sc, seed=seed, engine="jit",
                                        eval_every=spec.eval_every,
                                        progress=cb))
    else:
        raise ValueError(
            f"run_sweep engine must be 'vmap' or 'jit', not {engine!r}")
    for (sc, _seed), r in zip(worlds, results):
        _stamp(r, sc)
    return results

"""Scenario registry: named, parameterized simulation worlds (DESIGN.md §8).

The paper evaluates one world — K=10 vehicles under a single RSU with
Table-I heterogeneity.  The ROADMAP's north star needs fleets two orders of
magnitude larger and qualitatively different regimes (non-IID shards,
multi-RSU corridors with handover).  A ``Scenario`` bundles everything
needed to build such a world — fleet size, data heterogeneity, channel
overrides, RSU topology — so benchmarks, examples, and tests launch any of
them from a name:

    from repro.core.scenarios import run_scenario
    result = run_scenario("fleet-k100", rounds=20)

Multi-RSU scenarios (``n_rsus > 1``) run a corridor of RSUs, each with its
own :class:`RSUServer` cohort model; a vehicle uploads to the RSU serving
its position at arrival time (handover), and every ``reconcile_every``
arrivals the cohort models are averaged (``hierarchical.reconcile_models``
— the host-level version of the cross-pod pmean).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from repro.channel import ChannelParams
from repro.core.client import Vehicle
from repro.core.hierarchical import reconcile_models
from repro.core.mafl import (ENGINES, SimResult, _Timeline, evaluate,
                             run_simulation)
from repro.core.server import RSUServer


@dataclass(frozen=True)
class Scenario:
    """Everything needed to build and run one simulation world."""
    name: str
    description: str
    K: int = 10
    rounds: int = 40
    l_iters: int = 5
    lr: float = 0.03
    scheme: str = "mafl"
    # data world
    n_train: int = 6000
    n_test: int = 800
    noise: float = 0.5
    scale: float = 0.02
    dirichlet_alpha: Optional[float] = None
    max_per_vehicle: Optional[int] = None
    # topology
    n_rsus: int = 1
    reconcile_every: int = 8
    # dataclasses.replace(...) overrides applied to ChannelParams
    channel_overrides: tuple = ()

    def channel(self) -> ChannelParams:
        return dataclasses.replace(ChannelParams(), K=self.K,
                                   **dict(self.channel_overrides))


_REGISTRY: dict[str, Scenario] = {}


def register(sc: Scenario) -> Scenario:
    if sc.name in _REGISTRY:
        raise ValueError(f"duplicate scenario {sc.name!r}")
    _REGISTRY[sc.name] = sc
    return sc


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown scenario {name!r}; known: {known}") from None


def list_scenarios() -> list[str]:
    return sorted(_REGISTRY)


register(Scenario(
    name="paper-k10",
    description="The paper's Section V-A world: K=10, Table-I "
                "heterogeneity, IID shards (CPU-scaled).",
))
register(Scenario(
    name="paper-k10-noniid",
    description="Paper world with Dirichlet(0.5) class-skewed shards.",
    dirichlet_alpha=0.5,
))
register(Scenario(
    name="quick-k5",
    description="Five-vehicle smoke world for tests and CI.",
    K=5, rounds=10, l_iters=2, n_train=1200, n_test=240, scale=0.01,
))
register(Scenario(
    name="fleet-k100",
    description="Fleet-scale: 100 vehicles under one RSU; shard storage "
                "capped so the wave engine batches ~uniform minibatches.",
    K=100, rounds=120, scale=0.022, max_per_vehicle=512,
    n_train=4000, n_test=800,
))
register(Scenario(
    name="fleet-k100-noniid",
    description="100-vehicle fleet with Dirichlet(0.3) heterogeneity.",
    K=100, rounds=120, scale=0.022, max_per_vehicle=512,
    n_train=4000, n_test=800, dirichlet_alpha=0.3,
))
register(Scenario(
    name="fleet-k1000",
    description="Mega-fleet: 1000 vehicles under one RSU, single local "
                "step per download (many clients x few local iterations); "
                "sized for engine='jit' (DESIGN.md §9) — the snapshot ring "
                "holds rounds+1 models instead of 1000 payloads.",
    K=1000, rounds=30, l_iters=1, scale=0.004, max_per_vehicle=256,
    n_train=4000, n_test=400,
))
register(Scenario(
    name="fleet-k1000-noniid",
    description="Mega-fleet with Dirichlet(0.3) class-skewed shards.",
    K=1000, rounds=30, l_iters=1, scale=0.004, max_per_vehicle=256,
    n_train=4000, n_test=400, dirichlet_alpha=0.3,
))
register(Scenario(
    name="platoon-burst-k500",
    description="Bursty arrivals: 500 vehicles in platoons of 25 sharing "
                "the leader's compute/data (identical training delays), so "
                "uploads land in near-simultaneous bursts — stress test "
                "for time-ordered consumption under the jit engine.",
    K=500, rounds=40, l_iters=1, scale=0.005, max_per_vehicle=256,
    n_train=4000, n_test=400,
    channel_overrides=(("platoon", 25),),
))
register(Scenario(
    name="highway-k40-handover",
    description="Four-RSU corridor, 40 vehicles with handover and "
                "periodic cross-RSU reconciliation.",
    K=40, rounds=80, n_rsus=4, reconcile_every=8,
    scale=0.02, max_per_vehicle=512, n_train=4000, n_test=800,
))


def build_world(sc: Scenario, seed: int = 0):
    """Materialize (vehicles, test_images, test_labels, params) for ``sc``."""
    # deferred: repro.data imports repro.core.client, so a module-level
    # import here would make the repro.core package circular
    from repro.data import partition_vehicles, synth_mnist
    tr_i, tr_l, te_i, te_l = synth_mnist(n_train=sc.n_train,
                                         n_test=sc.n_test, seed=0,
                                         noise=sc.noise)
    p = sc.channel()
    veh = partition_vehicles(tr_i, tr_l, p, seed=seed, scale=sc.scale,
                             dirichlet_alpha=sc.dirichlet_alpha,
                             max_per_vehicle=sc.max_per_vehicle)
    return veh, te_i, te_l, p


def run_scenario(scenario: str | Scenario, *, seed: int = 0,
                 engine: str = "batched", eval_every: int = 10,
                 progress=None, **overrides) -> SimResult:
    """Build the named world and run it; ``overrides`` replace Scenario
    fields (e.g. ``rounds=20`` for a shortened run)."""
    if engine not in ENGINES:
        raise ValueError(
            f"unknown engine {engine!r}; expected one of {ENGINES}")
    sc = get_scenario(scenario) if isinstance(scenario, str) else scenario
    if overrides:
        sc = dataclasses.replace(sc, **overrides)
    veh, te_i, te_l, p = build_world(sc, seed=seed)
    if sc.n_rsus > 1:
        # the multi-RSU engine processes arrivals one at a time (no wave
        # batching yet) regardless of the requested single-RSU engine
        return run_handover_simulation(sc, veh, te_i, te_l, p, seed=seed,
                                       eval_every=eval_every,
                                       progress=progress)
    return run_simulation(veh, te_i, te_l, scheme=sc.scheme,
                          rounds=sc.rounds, l_iters=sc.l_iters, lr=sc.lr,
                          params=p, seed=seed, eval_every=eval_every,
                          engine=engine, progress=progress)


class _Corridor:
    """Vehicle kinematics along an ``n_rsus``-segment road.

    RSU j sits at the center of segment j; a vehicle is served by the RSU
    whose segment contains it (hard handover at segment edges), wrapping at
    the corridor ends to keep the population constant (same re-entry
    convention as the single-RSU :class:`~repro.channel.Mobility`)."""

    def __init__(self, p: ChannelParams, n_rsus: int):
        self.p = p
        self.n_rsus = n_rsus
        self.span = 2 * p.coverage * n_rsus
        self.centers = np.array(
            [-self.span / 2 + (j + 0.5) * 2 * p.coverage
             for j in range(n_rsus)])
        self.x0 = -self.span / 2 + self.span * (np.arange(p.K) / p.K)

    def x(self, i: int, t: float) -> float:
        dx = self.x0[i] + self.p.v * t
        return ((dx + self.span / 2) % self.span) - self.span / 2

    def serving_rsu(self, i: int, t: float) -> int:
        x = self.x(i, t)
        j = int((x + self.span / 2) // (2 * self.p.coverage))
        return min(max(j, 0), self.n_rsus - 1)

    def distance(self, i: int, t: float) -> float:
        x = self.x(i, t)
        j = self.serving_rsu(i, t)
        return float(np.sqrt((x - self.centers[j]) ** 2 +
                             self.p.d_y ** 2 + self.p.H ** 2))


def run_handover_simulation(sc: Scenario, vehicles_data: Sequence,
                            test_images, test_labels, p: ChannelParams,
                            *, seed: int = 0, eval_every: int = 10,
                            interpretation: str = "mixing",
                            progress=None) -> SimResult:
    """Multi-RSU MAFL with handover (beyond paper, DESIGN.md §8).

    Each RSU keeps its own cohort model and applies the paper's per-arrival
    aggregation; a vehicle downloads from the RSU serving it at download
    time and uploads to the RSU serving it at arrival time.  Every
    ``sc.reconcile_every`` arrivals all cohort models are averaged — the
    corridor-scale version of the hierarchical cross-pod reconcile."""
    import jax
    from repro.models.cnn import init_cnn

    init = init_cnn(jax.random.PRNGKey(seed))
    servers = [RSUServer(init, p, scheme=sc.scheme,
                         interpretation=interpretation)
               for _ in range(sc.n_rsus)]
    corridor = _Corridor(p, sc.n_rsus)
    # same scheduling rules as the single-RSU engine — only the geometry
    # (distance to the serving RSU) differs
    timeline = _Timeline(p, seed, distance_fn=corridor.distance)
    queue = timeline.queue
    fleet_batch = min(128, min(d.size for d in vehicles_data))
    clients = [Vehicle(d, lr=sc.lr, batch_size=fleet_batch, seed=seed)
               for d in vehicles_data]

    def schedule(vehicle: int, t_download: float):
        rsu = corridor.serving_rsu(vehicle, t_download)
        timeline.schedule(vehicle, t_download,
                          payload=servers[rsu].global_params)

    for k in range(p.K):
        schedule(k, 0.0)

    result = SimResult(scheme=f"{sc.scheme}+handover", rounds=[],
                       acc_history=[], loss_history=[])
    total = 0
    while total < sc.rounds and len(queue):
        ev = queue.pop()
        local_params, _ = clients[ev.vehicle].local_update(ev.payload,
                                                           sc.l_iters)
        rsu = corridor.serving_rsu(ev.vehicle, ev.time)   # handover target
        rec = servers[rsu].receive(
            local_params, time=ev.time, vehicle=ev.vehicle,
            upload_delay=ev.upload_delay, train_delay=ev.train_delay,
            download_time=ev.download_time)
        total += 1
        consensus = None
        if total % sc.reconcile_every == 0:
            consensus = reconcile_models([s.global_params for s in servers])
            for s in servers:
                s.global_params = consensus
        if total % eval_every == 0 or total == sc.rounds:
            if consensus is None:
                consensus = reconcile_models(
                    [s.global_params for s in servers])
            acc, loss = evaluate(consensus, test_images, test_labels)
            rec.accuracy, rec.loss = acc, loss
            result.acc_history.append((total, acc))
            result.loss_history.append((total, loss))
            if progress:
                progress(total, acc)
        result.rounds.append(rec)
        schedule(ev.vehicle, ev.time)
        timeline.prune()

    result.final_params = reconcile_models(
        [s.global_params for s in servers])
    return result

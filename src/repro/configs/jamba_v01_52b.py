"""jamba-v0.1-52b [arXiv:2403.19887].

Hybrid Mamba+attention, 1 attention layer per 8 (attn at offset 4 of each
period, matching the released interleave), MoE 16e top-2 on every other layer.
SSM layers make ``long_500k`` legal (decode state is O(1) for Mamba layers;
the sparse attention layers pay O(S) per step).
"""
from repro.configs.base import ArchConfig, register


@register("jamba-v0.1-52b")
def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        source="arXiv:2403.19887",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        attn_every=8,
        scan_period=8,
        n_routed_experts=16,
        n_shared_experts=0,
        moe_top_k=2,
        moe_d_ff=14336,
        moe_every=2,
        mamba_d_state=16,
        mamba_d_conv=4,
        mamba_expand=2,
        notes="1:7 attn:mamba interleave; MoE every other layer",
    )

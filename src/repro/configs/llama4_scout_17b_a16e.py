"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E].

MoE 16 routed (top-1) + 1 shared expert on every layer; iRoPE-style local
chunked attention with one global-attention layer per 4 — which is what makes
``long_500k`` legal for this arch (DESIGN.md §Arch-applicability).
Dense path d_ff=16384, expert d_ff=8192 (assignment's d_ff=8192 is the expert
hidden size; the shared/dense MLP on Scout is 16384).
"""
from repro.configs.base import ArchConfig, register


@register("llama4-scout-17b-a16e")
def config() -> ArchConfig:
    return ArchConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=202048,
        rope_theta=5e5,
        attn_chunk=8192,
        global_attn_every=4,
        n_routed_experts=16,
        n_shared_experts=1,
        moe_top_k=1,
        moe_d_ff=8192,
        moe_every=1,
        scan_period=4,          # chunked,chunked,chunked,global
        notes="early-fusion card; text backbone here, chunked attn => long_500k legal",
    )

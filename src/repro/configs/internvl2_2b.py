"""internvl2-2b [arXiv:2404.16821].

InternLM2-1.8B language decoder consuming InternViT patch embeddings.  Per the
brief's carve-out the ViT+projector are a STUB — ``input_specs()`` provides
``n_frontend_tokens`` precomputed patch embeddings of shape (B, 256, d_model)
prepended to the text stream.  Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ArchConfig, register


@register("internvl2-2b")
def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-2b",
        family="vlm",
        source="arXiv:2404.16821",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92553,
        rope_theta=1e6,
        frontend="vision",
        n_frontend_tokens=256,
        notes="InternViT stubbed; decoder = InternLM2-style GQA",
    )

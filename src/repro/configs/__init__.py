from repro.configs.base import ArchConfig, get_config, list_archs, register
from repro.configs.shapes import SHAPES, InputShape, get_shape, legal_shapes

__all__ = [
    "ArchConfig", "get_config", "list_archs", "register",
    "SHAPES", "InputShape", "get_shape", "legal_shapes",
]

"""The four assigned input shapes.

``train_*`` shapes lower ``train_step`` (fwd + bwd + SGD); ``decode_*`` shapes
lower ``serve_step`` (ONE new token against a ``seq_len`` KV cache);
``prefill_*`` lowers the forward+cache-build pass.
"""
from __future__ import annotations

from dataclasses import dataclass

TRAIN, PREFILL, DECODE = "train", "prefill", "decode"


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, TRAIN),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, PREFILL),
    "decode_32k": InputShape("decode_32k", 32_768, 128, DECODE),
    "long_500k": InputShape("long_500k", 524_288, 1, DECODE),
}


def get_shape(name: str) -> InputShape:
    return SHAPES[name]


def legal_shapes(cfg) -> list[str]:
    """Shapes legal for an arch (long_500k requires sub-quadratic attention)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        out.append("long_500k")
    return out

"""qwen1.5-4b [hf:Qwen/Qwen1.5-4B; assignment cites the 0.5B card for family].

Dense decoder with QKV bias; kv=20 with 20 heads => MHA.  Full attention ->
``long_500k`` skipped.
"""
from repro.configs.base import ArchConfig, register


@register("qwen1.5-4b")
def config() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-4b",
        family="dense",
        source="hf:Qwen/Qwen1.5-0.5B (family card)",
        n_layers=40,
        d_model=2560,
        n_heads=20,
        n_kv_heads=20,
        head_dim=128,
        d_ff=6912,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1e6,
        notes="QKV bias; MHA",
    )

"""smollm-360m [hf:HuggingFaceTB/SmolLM-360M; assignment cites the 135M card].

Llama-arch small dense model — the realistic "on-vehicle" FL client size and
the paper-representative hillclimb target (EXPERIMENTS.md §Perf).
Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ArchConfig, register


@register("smollm-360m")
def config() -> ArchConfig:
    return ArchConfig(
        name="smollm-360m",
        family="dense",
        source="hf:HuggingFaceTB/SmolLM-135M (family card)",
        n_layers=32,
        d_model=960,
        n_heads=15,
        n_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab_size=49152,
        rope_theta=1e4,
        tie_embeddings=True,
        notes="FL-client-scale dense model",
    )

"""rwkv6-1.6b (Finch) [arXiv:2404.05892].

Attention-free: data-dependent-decay linear recurrence (time-mix) + squared
ReLU channel-mix.  O(1) decode state -> every input shape incl. ``long_500k``.
MAFL aggregation applies unchanged (structure-agnostic) — DESIGN.md
§Arch-applicability.
"""
from repro.configs.base import ArchConfig, register


@register("rwkv6-1.6b")
def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-1.6b",
        family="ssm",
        source="arXiv:2404.05892",
        n_layers=24,
        d_model=2048,
        n_heads=32,            # time-mix heads = d_model / rwkv_head_size
        n_kv_heads=32,
        d_ff=7168,
        vocab_size=65536,
        rwkv_head_size=64,
        notes="attention-free; all four shapes legal",
    )

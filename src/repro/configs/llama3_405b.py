"""llama3-405b [arXiv:2407.21783].

Dense GQA flagship.  Pure full attention -> ``long_500k`` skipped (DESIGN.md).
FSDP sharding is mandatory: bf16 weights alone are ~810 GB.
"""
from repro.configs.base import ArchConfig, register


@register("llama3-405b")
def config() -> ArchConfig:
    return ArchConfig(
        name="llama3-405b",
        family="dense",
        source="arXiv:2407.21783",
        n_layers=126,
        d_model=16384,
        n_heads=128,
        n_kv_heads=8,
        head_dim=128,
        d_ff=53248,
        vocab_size=128256,
        rope_theta=5e5,
        notes="full attention; long_500k skipped per brief",
    )

"""musicgen-large [arXiv:2306.05284].

Decoder-only transformer over EnCodec tokens (backbone only, per the brief's
carve-out): the EnCodec conv codec is NOT implemented — ``input_specs()``
supplies precomputed token ids / frame embeddings.  kv=32 with 32 heads => MHA.
Full attention -> ``long_500k`` skipped.
"""
from repro.configs.base import ArchConfig, register


@register("musicgen-large")
def config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-large",
        family="audio",
        source="arXiv:2306.05284",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=2048,
        frontend="audio",
        n_frontend_tokens=0,   # EnCodec codes arrive as ordinary token ids
        notes="EnCodec frontend stubbed; decoder backbone only",
    )

"""mistral-nemo-12b [hf:mistralai/Mistral-Nemo-Base-2407].

Dense GQA decoder, 128k context.  The released model uses full attention; the
``long_500k`` decode shape is only legal under the sliding-window variant
(Mistral-family SWA) — ``sliding_window_variant()`` below — as recorded in
DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ArchConfig, register


@register("mistral-nemo-12b")
def config() -> ArchConfig:
    return ArchConfig(
        name="mistral-nemo-12b",
        family="dense",
        source="hf:mistralai/Mistral-Nemo-Base-2407",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        rope_theta=1e6,
        notes="128k ctx; long_500k via sliding_window_variant()",
    )


def sliding_window_variant(window: int = 4096) -> ArchConfig:
    return config().variant(sliding_window=window,
                            notes="SWA variant for long_500k")

"""deepseek-v2-lite-16b [arXiv:2405.04434].

MLA (kv_lora_rank=512) + MoE.  The assignment line lists both "MoE 64e top-6"
and "2 shared+160 routed"; the released V2-Lite card is 2 shared + 64 routed
top-6 (160 routed is full V2) — we implement 64 routed and record the
discrepancy in DESIGN.md §Arch-applicability.
First layer dense (first_k_dense_replace=1), dense d_ff=10944, expert d_ff=1408.
"""
from repro.configs.base import ArchConfig, register


@register("deepseek-v2-lite-16b")
def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        source="arXiv:2405.04434",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=10944,
        vocab_size=102400,
        rope_theta=1e4,
        # MLA
        use_mla=True,
        kv_lora_rank=512,
        q_lora_rank=0,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        # MoE
        n_routed_experts=64,
        n_shared_experts=2,
        moe_top_k=6,
        moe_d_ff=1408,
        moe_every=1,
        first_k_dense=1,
        notes="MLA compressed KV cache; absorbed decode via variant(mla_absorb=True)",
    )

"""Architecture config system.

Every assigned architecture is a frozen ``ArchConfig`` registered under its
public id (``--arch <id>``).  Configs are *data only* — model code interprets
them (``repro.models.transformer``).  ``reduced()`` returns the smoke-test
variant mandated by the brief (2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable, Optional, Sequence

# ---------------------------------------------------------------------------
# Sub-layer kinds (per position inside one scan period)
# ---------------------------------------------------------------------------
MIXER_ATTN = "attn"           # softmax attention (GQA / MHA / SWA / chunked)
MIXER_ATTN_GLOBAL = "attn_global"  # full-context attention inside a local arch
MIXER_MLA = "mla"             # DeepSeek multi-head latent attention
MIXER_MAMBA = "mamba"         # selective SSM
MIXER_RWKV = "rwkv"           # RWKV6 time-mix

MLP_DENSE = "dense"
MLP_MOE = "moe"
MLP_RWKV = "rwkv_cm"          # RWKV channel-mix (token-shifted squared-relu)


@dataclass(frozen=True)
class SubLayer:
    """One (mixer, mlp) pair inside a scan period."""
    mixer: str
    mlp: str


@dataclass(frozen=True)
class ArchConfig:
    # identity -------------------------------------------------------------
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    source: str                      # citation bracket from the assignment
    # trunk ------------------------------------------------------------------
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0                # 0 -> d_model // n_heads
    d_ff: int = 0                    # dense-MLP hidden size
    vocab_size: int = 0
    norm_eps: float = 1e-5
    qkv_bias: bool = False
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    # attention variants -----------------------------------------------------
    sliding_window: Optional[int] = None   # SWA width (None = full)
    attn_chunk: Optional[int] = None       # chunked/local attention width
    global_attn_every: int = 0             # 0 = never; k -> every k-th sublayer global
    # MLA (DeepSeek) -----------------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0                   # 0 -> full-rank q projection
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mla_absorb: bool = False               # absorbed decode (beyond-paper perf opt)
    # MoE ----------------------------------------------------------------------
    n_routed_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_every: int = 0                     # k -> sublayer idx % k == k-1 is MoE; 1 -> all
    first_k_dense: int = 0                 # leading layers forced dense (DeepSeek)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    # hybrid / SSM ---------------------------------------------------------------
    attn_every: int = 0                    # jamba: one attention layer per k
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    rwkv_head_size: int = 64
    # modality frontend (stubbed per the brief's carve-out) ----------------------
    frontend: Optional[str] = None         # None | 'vision' | 'audio'
    n_frontend_tokens: int = 0             # prefix embeddings supplied by the stub
    # distribution / memory knobs --------------------------------------------------
    shard_activations: bool = False        # with_sharding_constraint d_model->model
                                           # between layers (sequence-parallel analog)
    microbatches: int = 1                  # grad-accumulation splits of the batch
    grad_accum_dtype: str = "float32"      # bf16 halves accumulator HBM (405B)
    remat_sublayer: bool = False           # checkpoint each sublayer (not just
                                           # the period) — heavy hybrid periods
    no_remat: bool = False                 # skip layer-scan checkpointing
                                           # (small models: trade HBM for the
                                           # ~fwd-worth of recompute FLOPs)
    remat_policy: str = "full"             # full | dots (save matmul outputs,
                                           # recompute elementwise only)
    loss_chunk: int = 0                    # 0=auto: vocab-chunked flash-CE for
                                           # V>32k (avoids [B,S,V] f32 logits)
    # misc -----------------------------------------------------------------------
    scan_period: int = 1                   # layers per scan step (heterogeneous stacks)
    notes: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return all(s.mixer == MIXER_RWKV for s in self.sublayers())

    @property
    def supports_long_context(self) -> bool:
        """True iff a 500k-token decode is legal (sub-quadratic / local attention)."""
        kinds = {s.mixer for s in self.sublayers()}
        if kinds <= {MIXER_RWKV, MIXER_MAMBA}:
            return True
        if self.attn_every:
            return True  # hybrid: SSM-dominant, sparse attention is O(S)/step
        if MIXER_MLA in kinds:
            return False
        if MIXER_ATTN in kinds and self.sliding_window is None and self.attn_chunk is None:
            return False
        return True  # SWA / chunked (+ optional sparse globals) or hybrid SSM

    @property
    def n_periods(self) -> int:
        assert (self.n_layers - self.first_k_dense) % self.scan_period == 0, self.name
        return (self.n_layers - self.first_k_dense) // self.scan_period

    def sublayers(self) -> Sequence[SubLayer]:
        """The (mixer, mlp) pattern of ONE scan period."""
        subs = []
        for j in range(self.scan_period):
            if self.attn_every:  # hybrid (jamba): attention once per attn_every
                mixer = MIXER_ATTN if (j % self.attn_every) == self.attn_every // 2 \
                    else MIXER_MAMBA
            elif self.family == "ssm":
                mixer = MIXER_RWKV
            elif self.use_mla:
                mixer = MIXER_MLA
            elif self.global_attn_every and (j % self.global_attn_every) == \
                    self.global_attn_every - 1:
                mixer = MIXER_ATTN_GLOBAL
            else:
                mixer = MIXER_ATTN
            if self.family == "ssm":
                mlp = MLP_RWKV
            elif self.moe_every and (j % self.moe_every) == self.moe_every - 1:
                mlp = MLP_MOE
            else:
                mlp = MLP_DENSE
            subs.append(SubLayer(mixer, mlp))
        return tuple(subs)

    def prefix_sublayer(self) -> SubLayer:
        """Structure of the unrolled leading dense layers (first_k_dense)."""
        base = self.sublayers()[0]
        return SubLayer(base.mixer, MLP_DENSE)

    # -- variants ---------------------------------------------------------------
    def variant(self, **kw) -> "ArchConfig":
        return replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        head_dim = max(d // n_heads, 32)
        ratio = max(self.n_heads // max(self.n_kv_heads, 1), 1)
        n_kv = max(n_heads // ratio, 1)
        # keep the heterogeneous pattern but shrink the period to 2 so the
        # smoke variant is a genuine 2-layer model (one scan period).
        period = min(self.scan_period, 2)
        kw = dict(
            n_layers=2 + self.first_k_dense,
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            scan_period=period,
        )
        if self.attn_every:
            kw.update(attn_every=2)         # pattern: [mamba, attn]
        if self.global_attn_every:
            kw.update(global_attn_every=2)  # pattern: [chunked, global]
        if self.n_routed_experts:
            kw.update(
                n_routed_experts=min(self.n_routed_experts, 4),
                n_shared_experts=min(self.n_shared_experts, 1),
                moe_top_k=min(self.moe_top_k, 2),
                moe_d_ff=min(self.moe_d_ff, 256),
            )
        if self.use_mla:
            kw.update(kv_lora_rank=64, q_lora_rank=0, qk_nope_head_dim=32,
                      qk_rope_head_dim=16, v_head_dim=32, head_dim=0)
        if self.sliding_window:
            kw.update(sliding_window=64)
        if self.attn_chunk:
            kw.update(attn_chunk=64)
        if self.frontend:
            kw.update(n_frontend_tokens=min(self.n_frontend_tokens, 16))
        if self.family == "ssm":
            kw.update(rwkv_head_size=32)
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Callable[[], ArchConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    from repro.configs import (  # noqa: F401
        mistral_nemo_12b, deepseek_v2_lite_16b, llama4_scout_17b_a16e,
        llama3_405b, jamba_v01_52b, musicgen_large, rwkv6_1_6b,
        internvl2_2b, qwen1_5_4b, smollm_360m,
    )
    _LOADED = True

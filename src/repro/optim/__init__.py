from repro.optim.optimizers import (Optimizer, adam, momentum_sgd, sgd,
                                    apply_updates, global_norm, clip_by_global_norm)
from repro.optim.schedule import constant, cosine_decay, linear_warmup_cosine

__all__ = ["Optimizer", "adam", "momentum_sgd", "sgd", "apply_updates",
           "global_norm", "clip_by_global_norm", "constant", "cosine_decay",
           "linear_warmup_cosine"]

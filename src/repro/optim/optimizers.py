"""Optimizers as pure pytree transforms (optax-style, zero deps).

The paper's local update is plain SGD (Eq. 2) — ``sgd`` is the faithful one;
momentum / Adam are provided for the transformer training driver.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]   # (grads, state, params)


def sgd(lr) -> Optimizer:
    """Eq. (2): w <- w - eta * grad.  ``lr`` may be a float or schedule fn."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        eta = lr_fn(state["step"])
        upd = jax.tree_util.tree_map(lambda g: -eta * g, grads)
        return upd, {"step": state["step"] + 1}

    return Optimizer(init, update)


def momentum_sgd(lr, mu: float = 0.9) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree_util.tree_map(
                    lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params):
        eta = lr_fn(state["step"])
        m = jax.tree_util.tree_map(
            lambda mm, g: mu * mm + g.astype(jnp.float32), state["m"], grads)
        upd = jax.tree_util.tree_map(lambda mm: -eta * mm, m)
        return upd, {"step": state["step"] + 1, "m": m}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        eta = lr_fn(step)
        m = jax.tree_util.tree_map(
            lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda vv, g: b2 * vv + (1 - b2) *
            jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def leaf(mm, vv, p):
            upd = -(eta * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps))
            if weight_decay:
                upd = upd - eta * weight_decay * p.astype(jnp.float32)
            return upd

        upd = jax.tree_util.tree_map(leaf, m, v, params)
        return upd, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params,
        updates)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm

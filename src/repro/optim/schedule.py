"""Learning-rate schedules (pure fns of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine_decay(lr: float, total_steps: int, floor: float = 0.0):
    def fn(step):
        frac = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return floor + (lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return fn


def linear_warmup_cosine(lr: float, warmup: int, total_steps: int,
                         floor: float = 0.0):
    cos = cosine_decay(lr, max(total_steps - warmup, 1), floor)

    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr * s / max(warmup, 1)
        return jnp.where(step < warmup, warm, cos(step - warmup))
    return fn

from repro.checkpointing.checkpoint import (latest_checkpoint,
                                            load_checkpoint,
                                            load_flat_checkpoint,
                                            save_checkpoint,
                                            save_flat_checkpoint)

__all__ = ["load_checkpoint", "save_checkpoint", "latest_checkpoint",
           "load_flat_checkpoint", "save_flat_checkpoint"]

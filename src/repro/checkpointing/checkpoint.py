"""Flat-npz pytree checkpointing with retention, for the RSU global model
and training driver state.  Path-keyed so any nested-dict pytree round-trips
exactly (arrays only; scalars stored as 0-d arrays)."""
from __future__ import annotations

import hashlib
import json
import os
import re
from typing import Any

import jax
import numpy as np

_SEP = "/"


def tree_digest(tree: Any) -> str:
    """sha256 over a pytree's (path, raw bytes) stream — a bitwise identity
    for model parameters.  The golden-trace suite pins engine outputs with
    this, and checkpoint round-trip tests use it to prove bit-exactness."""
    h = hashlib.sha256()
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_part(p) for p in path)
        arr = np.asarray(leaf)
        h.update(key.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_part(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":       # npz can't round-trip bf16
            arr = arr.view(np.uint16)
            key = key + "::bf16"
        flat[key] = arr
    return flat


def _part(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Any, keep: int = 3,
                    meta: dict | None = None) -> str:
    """Write ``ckpt_NNNNNNNN.npz`` (+ optional sidecar json) atomically.

    Both files are written to ``.tmp`` siblings, fsynced, and published
    with ``os.replace`` — a process killed mid-write can never leave a
    truncated checkpoint where ``latest_checkpoint`` would find it.  The
    npz replace is the commit point: the sidecar json (when given) is
    published first, so any visible npz already has its sidecar."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **_flatten(tree))
        f.flush()
        os.fsync(f.fileno())
    if meta is not None:
        jtmp = path + ".json.tmp"
        with open(jtmp, "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(jtmp, path + ".json")
    os.replace(tmp, path)
    _retain(directory, keep)
    return path


def _retain(directory: str, keep: int):
    names = os.listdir(directory)
    ckpts = sorted(f for f in names if re.fullmatch(r"ckpt_\d+\.npz", f))
    for old in ckpts[:-keep]:
        os.remove(os.path.join(directory, old))
        if os.path.exists(os.path.join(directory, old + ".json")):
            os.remove(os.path.join(directory, old + ".json"))
    # orphaned .tmp siblings from a killed writer are dead weight, never
    # visible to latest_checkpoint — sweep them on the next save
    for stale in names:
        if re.fullmatch(r"ckpt_\d+\.npz(\.json)?\.tmp", stale):
            try:
                os.remove(os.path.join(directory, stale))
            except FileNotFoundError:
                pass


def latest_checkpoint(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(f for f in os.listdir(directory)
                   if re.fullmatch(r"ckpt_\d+\.npz", f))
    return os.path.join(directory, ckpts[-1]) if ckpts else None


def save_flat_checkpoint(directory: str, step: int, flat, layout,
                         keep: int = 3, meta: dict | None = None) -> str:
    """Checkpoint a packed flat-parameter buffer (DESIGN.md §12) together
    with its :class:`repro.core.flat.ParamLayout`, so a flat engine state
    restores without a template pytree — and round-trips bit-exactly for
    both f32 and bf16 ring buffers (the bf16 view trick of ``_flatten``).
    Shares the ``ckpt_NNNNNNNN.npz`` naming/retention with the pytree
    checkpoints; the layout rides in the sidecar json under ``"layout"``."""
    m = dict(meta or {})
    m["layout"] = layout.to_json()
    return save_checkpoint(directory, step, {"flat": flat}, keep=keep,
                           meta=m)


def load_flat_checkpoint(path: str):
    """Restore ``(flat_buffer, layout)`` from a flat checkpoint; use
    ``layout.unpack(flat_buffer)`` for the pytree view."""
    import ml_dtypes

    from repro.core.flat import ParamLayout
    with open(path + ".json") as f:
        layout = ParamLayout.from_json(json.load(f)["layout"])
    data = np.load(path)
    if "flat::bf16" in data:
        flat = data["flat::bf16"].view(ml_dtypes.bfloat16)
    else:
        flat = data["flat"]
    assert flat.shape[-1] == layout.P, (flat.shape, layout.P)
    return flat, layout


def load_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (a template pytree)."""
    import ml_dtypes
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in paths:
        key = _SEP.join(_part(x) for x in p)
        if key + "::bf16" in data:
            arr = data[key + "::bf16"].view(ml_dtypes.bfloat16)
        else:
            arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(np.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)

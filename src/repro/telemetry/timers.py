"""Host-side phase timers and memory probes (DESIGN.md §14).

Pure host instrumentation around the compiled region: phase timers never
touch traced code, so they are always on — enabling them cannot perturb
the program (the off-is-no-op invariant only concerns the *device*
channels).  The canonical phases the engines record:

- ``plan``    — the f64 dry-run planner (``plan_fleet`` / ``plan_corridor``)
- ``stage``   — world staging: packing slot arrays, flat layouts, rings
- ``build``   — Python tracing of the program body (cache misses only)
- ``run``     — the compiled region end-to-end (includes XLA compile on
                the first call; the bench layer separates compile time by
                differencing a cold and a warm invocation)
- ``eval``    — host-side accuracy evaluation of returned snapshots

``memory_stats()`` reports the process peak RSS and, when the backend
exposes it (TPU/GPU allocators), per-device ``live_bytes`` peaks.
"""
from __future__ import annotations

import time
from contextlib import contextmanager


class PhaseTimers:
    """Accumulating wall-clock phase timers.

    >>> timers = PhaseTimers()
    >>> with timers.phase("plan"):
    ...     do_planning()
    >>> timers.snapshot()
    {'plan': 0.0123}

    Phases nest and repeat; repeated entries accumulate.  ``snapshot``
    returns plain floats (seconds) suitable for JSON."""

    def __init__(self):
        self._acc: dict[str, float] = {}

    @contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            self._acc[name] = self._acc.get(name, 0.0) + dt

    def add(self, name: str, seconds: float) -> None:
        """Fold an externally measured duration into a phase."""
        self._acc[name] = self._acc.get(name, 0.0) + float(seconds)

    def snapshot(self) -> dict:
        return dict(self._acc)


def memory_stats() -> dict:
    """Process peak RSS plus backend allocator stats when available.

    ``ru_maxrss`` is KiB on Linux; ``device.memory_stats()`` is only
    populated on backends with an instrumented allocator (absent on the
    CPU backend — the keys are simply omitted there)."""
    out: dict = {}
    try:
        import resource
        out["peak_rss_bytes"] = int(
            resource.getrusage(resource.RUSAGE_SELF).ru_maxrss) * 1024
    except Exception:  # pragma: no cover - non-POSIX
        pass
    try:
        import jax
        stats = jax.local_devices()[0].memory_stats()
        if stats:
            for k in ("bytes_in_use", "peak_bytes_in_use", "bytes_limit"):
                if k in stats:
                    out[f"device_{k}"] = int(stats[k])
    except Exception:  # pragma: no cover - backend without allocator stats
        pass
    return out

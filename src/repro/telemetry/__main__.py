"""CLI for structured run logs: ``python -m repro.telemetry <cmd>``.

- ``report LOG``      render every run in a JSONL log
- ``diff A B``        compare two runs (last line of each log by default)
- ``run SCENARIO``    run a registered scenario with ``metrics=on`` and
                      append its RunReport to a JSONL log (the CI
                      telemetry-smoke entry point)
"""
from __future__ import annotations

import argparse
import sys

from repro.telemetry import runlog


def _cmd_report(args) -> int:
    runs = runlog.load(args.log)
    if not runs:
        print(f"{args.log}: no runs")
        return 1
    if args.index is not None:
        runs = [runs[args.index]]
    print(runlog.render(runs))
    return 0


def _cmd_diff(args) -> int:
    a = runlog.load(args.log_a)[args.index_a]
    b = runlog.load(args.log_b)[args.index_b]
    print(runlog.diff(a, b))
    return 0


def _cmd_run(args) -> int:
    from repro.core.scenarios import run_scenario

    result = run_scenario(args.scenario, seed=args.seed, engine=args.engine,
                          eval_every=args.eval_every, metrics=args.metrics)
    if result.report is None:
        print("engine returned no RunReport", file=sys.stderr)
        return 1
    runlog.append(args.out, result.report)
    acc = result.acc_history[-1][1] if result.acc_history else float("nan")
    print(f"{args.scenario} [{args.engine or 'auto'}] metrics={args.metrics}"
          f" final acc {acc:.4f} -> {args.out}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.telemetry")
    sub = ap.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser("report", help="render a JSONL run log")
    rp.add_argument("log")
    rp.add_argument("--index", type=int, default=None,
                    help="render only run N (negative indexes from the end)")
    rp.set_defaults(fn=_cmd_report)

    dp = sub.add_parser("diff", help="compare two runs")
    dp.add_argument("log_a")
    dp.add_argument("log_b")
    dp.add_argument("--index-a", type=int, default=-1)
    dp.add_argument("--index-b", type=int, default=-1)
    dp.set_defaults(fn=_cmd_diff)

    rn = sub.add_parser("run", help="run a scenario and log its report")
    rn.add_argument("scenario")
    rn.add_argument("--engine", default=None)
    rn.add_argument("--seed", type=int, default=0)
    rn.add_argument("--eval-every", type=int, default=10)
    rn.add_argument("--metrics", default="on", choices=("on", "off"))
    rn.add_argument("--out", default="telemetry.jsonl")
    rn.set_defaults(fn=_cmd_run)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())

"""Device-resident telemetry subsystem (DESIGN.md §14).

The compiled engines are black boxes once ``lax.scan`` starts — this
package opens them up without breaking the DESIGN §3 host-plans /
device-executes invariant:

- :mod:`repro.telemetry.spec` — the host f64 planner side: a static
  :class:`MetricsSpec` (staleness-histogram bin edges placed a safe margin
  away from every planned sample, so f32 device values bucket identically).
- :mod:`repro.telemetry.device` — the device side: fixed-shape counter /
  histogram state carried through the scan, plus the bf16 snapshot-ring
  finiteness guard.  No host round-trips.
- :mod:`repro.telemetry.replay` — the f64 conformance oracle: re-drives the
  event timeline on the host and produces the exact channel values the
  device accumulators must reproduce.
- :mod:`repro.telemetry.timers` — host-side phase timers (plan / stage /
  compile / run / eval wall clock, peak memory) around the compiled region.
- :mod:`repro.telemetry.report` — the typed, versioned :class:`RunReport`
  every engine attaches to ``SimResult.report`` (replacing the ad-hoc
  ``extras["selection"]`` dict entries).
- :mod:`repro.telemetry.runlog` — versioned JSONL structured run logs;
  ``python -m repro.telemetry report|diff`` renders or compares them.

The hard invariant: ``metrics=off`` (the default) compiles the exact
legacy program — a bitwise no-op, machine-checked by ``repro.check``
rule TEL001 and golden-pinned by ``tests/test_telemetry.py``.
"""
from repro.telemetry.report import RunReport
from repro.telemetry.spec import MetricsSpec, metrics_requested, resolve_metrics
from repro.telemetry.timers import PhaseTimers, memory_stats

__all__ = ["MetricsSpec", "RunReport", "PhaseTimers", "memory_stats",
           "metrics_requested", "resolve_metrics"]

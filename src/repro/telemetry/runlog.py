"""Versioned JSONL structured run logs (DESIGN.md §14).

One :class:`~repro.telemetry.report.RunReport` JSON object per line —
append-only, so a sweep (or a CI job) accumulates runs into one file that
``python -m repro.telemetry report`` renders and ``... diff`` compares.
The schema tag rides in every line; readers reject lines they do not
understand instead of mis-parsing them.
"""
from __future__ import annotations

import json
from typing import Union

from repro.telemetry.report import RunReport


def append(path: str, report: Union[RunReport, dict]) -> None:
    """Append one run to a JSONL log (creating it if needed)."""
    d = report.to_json() if isinstance(report, RunReport) else report
    with open(path, "a") as f:
        f.write(json.dumps(d, sort_keys=True) + "\n")


def load(path: str) -> list[dict]:
    """All runs in a JSONL log, as schema-checked dicts."""
    out = []
    with open(path) as f:
        for ln, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            RunReport.from_json(d)      # schema check only
            out.append(d)
    return out


def _fmt_seconds(s: float) -> str:
    return f"{s * 1e3:.1f} ms" if s < 1.0 else f"{s:.2f} s"


def _channel_summary(ch: dict) -> list[str]:
    import numpy as np
    lines = []
    if "stale_hist" in ch:
        h = np.asarray(ch["stale_hist"])
        lines.append(f"  staleness hist     {h.tolist()}")
    if "occupancy" in ch:
        o = np.asarray(ch["occupancy"])
        lines.append(f"  occupancy          mean {o.mean(0).tolist() if o.ndim > 1 else float(o.mean()):} "
                     f"max {int(o.max())}")
    if "gap" in ch:
        g = np.asarray(ch["gap"], float)
        lines.append(f"  pop wait           mean {g.mean():.4f} max {g.max():.4f}")
    if "handover_count" in ch:
        lines.append(f"  handovers per RSU  {list(ch['handover_count'])}")
    if "reward" in ch:
        rw = np.asarray(ch["reward"], float)
        lines.append(f"  reward trace       mean {rw.mean():.4f} last {rw[-1]:.4f}")
    if "ring_nonfinite" in ch:
        lines.append(f"  bf16 ring          nonfinite {ch['ring_nonfinite']} "
                     f"max|row| {float(ch.get('ring_max_abs', 0.0)):.3g}")
    return lines


def render(runs: list[dict]) -> str:
    """Human-readable multi-run summary of a loaded log."""
    out = []
    for k, d in enumerate(runs):
        head = (f"run {k}: engine={d.get('engine')} scheme={d.get('scheme')} "
                f"rounds={d.get('rounds')} seed={d.get('seed')}")
        if d.get("scenario"):
            head += f" scenario={d['scenario']}"
        head += f" metrics={'on' if d.get('metrics_on') else 'off'}"
        out.append(head)
        phases = d.get("phases") or {}
        if phases:
            out.append("  phases: " + "  ".join(
                f"{n}={_fmt_seconds(s)}" for n, s in sorted(phases.items())))
        mem = d.get("memory") or {}
        if "peak_rss_bytes" in mem:
            out.append(f"  peak rss: {mem['peak_rss_bytes'] / 2**30:.2f} GiB")
        if "device_peak_bytes_in_use" in mem:
            out.append("  device live_bytes peak: "
                       f"{mem['device_peak_bytes_in_use'] / 2**30:.2f} GiB")
        sel = d.get("selection")
        if sel:
            out.append(f"  selection: policy={sel.get('policy')} "
                       f"admitted={sel.get('n_admitted_final')}")
        waves = d.get("waves")
        if waves:
            out.append(f"  waves: {waves.get('n_waves')} "
                       f"(mean fill {waves.get('mean_fill'):.1f}, "
                       f"utilization {waves.get('utilization_vs_fleet'):.3f})")
        spec = d.get("spec")
        if spec:
            out.append(f"  staleness edges: {spec.get('edges')}")
        out.extend(_channel_summary(d.get("channels") or {}))
    return "\n".join(out)


def diff(a: dict, b: dict) -> str:
    """Compare two runs: identity fields, phase timings (with relative
    delta), and summary statistics of the shared channels."""
    import numpy as np
    out = []
    for f in ("engine", "scheme", "rounds", "seed", "scenario",
              "metrics_on"):
        va, vb = a.get(f), b.get(f)
        mark = "" if va == vb else "   <-- differs"
        out.append(f"{f:12} {va!r:>20} | {vb!r:<20}{mark}")
    pa, pb = a.get("phases") or {}, b.get("phases") or {}
    for n in sorted(set(pa) | set(pb)):
        sa, sb = pa.get(n), pb.get(n)
        if sa is not None and sb is not None and sa > 0:
            rel = f"  ({(sb - sa) / sa * 100.0:+.1f}%)"
        else:
            rel = ""
        out.append(f"phase {n:10} "
                   f"{_fmt_seconds(sa) if sa is not None else '-':>12} | "
                   f"{_fmt_seconds(sb) if sb is not None else '-':<12}{rel}")
    ca, cb = a.get("channels") or {}, b.get("channels") or {}
    for n in sorted(set(ca) & set(cb)):
        xa = np.asarray(ca[n], float).ravel()
        xb = np.asarray(cb[n], float).ravel()
        if xa.shape == xb.shape and np.array_equal(xa, xb):
            out.append(f"channel {n:18} identical")
        elif xa.shape == xb.shape:
            out.append(f"channel {n:18} max|Δ| "
                       f"{float(np.max(np.abs(xa - xb))):.4g}")
        else:
            out.append(f"channel {n:18} shape {xa.shape} | {xb.shape}")
    return "\n".join(out)

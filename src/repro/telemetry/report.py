"""Typed, versioned run reports (DESIGN.md §14).

Every engine attaches a :class:`RunReport` to ``SimResult.report`` —
replacing the ad-hoc ``extras["selection"]`` dict entries with a stable,
schema-tagged record that serializes to JSON deterministically.  The
report splits into:

- identity: engine / scheme / rounds / seed (+ scenario name when run
  through ``run_scenario``),
- host instrumentation (always on): ``phases`` wall-clock seconds and
  ``memory`` peaks from :mod:`repro.telemetry.timers`,
- plan-derived statics: ``selection`` (the former extras entry) and
  ``waves`` fill/utilization — known before the device runs,
- device channels (``metrics=on`` only): staleness histogram, occupancy
  and pop-wait traces, per-RSU handover counters, bandit reward traces,
  bf16 ring guards — everything the scan carry accumulated.

``channels`` values arrive as numpy/JAX arrays and are converted to
plain lists at serialization time; ``from_json`` round-trips them as
lists (the JSONL log is the interchange format, not a tensor store).
"""
from __future__ import annotations

from dataclasses import dataclass, field, asdict
from typing import Optional

import numpy as np

SCHEMA = "repro.telemetry/v1"


def _plain(x):
    """Recursively convert numpy/JAX scalars and arrays to JSON-safe
    Python values."""
    if isinstance(x, dict):
        return {k: _plain(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_plain(v) for v in x]
    if hasattr(x, "tolist"):          # np.ndarray, jax.Array, np scalars
        return _plain(np.asarray(x).tolist())
    if isinstance(x, (np.floating, float)):
        return float(x)
    if isinstance(x, (np.integer, int)) and not isinstance(x, bool):
        return int(x)
    return x


@dataclass
class RunReport:
    """One run's structured telemetry record (schema ``repro.telemetry/v1``)."""
    engine: str = ""
    scheme: str = ""
    rounds: int = 0
    seed: int = 0
    scenario: Optional[str] = None
    metrics_on: bool = False
    spec: Optional[dict] = None          # MetricsSpec.to_json() when on
    phases: dict = field(default_factory=dict)
    memory: dict = field(default_factory=dict)
    selection: Optional[dict] = None     # SelectionPlan.summary()
    faults: Optional[dict] = None        # fault spec + decision counts
    waves: Optional[dict] = None         # wave_stats() (device engines)
    channels: dict = field(default_factory=dict)
    schema: str = SCHEMA

    def to_json(self) -> dict:
        d = asdict(self)
        return {k: _plain(v) for k, v in d.items()}

    @classmethod
    def from_json(cls, d: dict) -> "RunReport":
        if d.get("schema") != SCHEMA:
            raise ValueError(
                f"unsupported run-report schema {d.get('schema')!r} "
                f"(this reader understands {SCHEMA})")
        known = {f for f in cls.__dataclass_fields__}
        return cls(**{k: v for k, v in d.items() if k in known})


def wave_stats(waves, k: int) -> dict:
    """Fill/utilization statistics for a plan's wave partition.

    ``waves`` is the planner tuple ``((train_rounds, seg_start, seg_end),
    ...)``: each wave batch-trains ``len(train_rounds)`` uploads in one
    vmapped ``_wave_train`` call.  Fill is measured against the fleet
    size ``k`` (the widest batch the wave trainer could ever form)."""
    sizes = [len(T) for T, _s, _e in waves]
    n = len(sizes)
    total = int(sum(sizes))
    return {
        "n_waves": n,
        "sizes": sizes,
        "total_trained": total,
        "mean_fill": (total / n) if n else 0.0,
        "max_fill": max(sizes) if sizes else 0,
        "utilization_vs_fleet": (total / (n * k)) if n and k else 0.0,
    }

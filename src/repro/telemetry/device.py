"""Device-side metrics accumulators (DESIGN.md §14).

Fixed-shape counter state carried through the engines' event-loop scans —
no host round-trips, no data-dependent shapes, and every helper is gated
behind the engines' static ``metrics is not None`` check so the off path
compiles the exact legacy program (rule TEL001).

State layout (one nested tuple appended to the scan carry):

- fleet (jit engine): ``(stale_hist i32[B], prev_t f32)``
- corridor: ``(stale_hist i32[R, B], prev_t f32, handover_count i32[R])``

Per-pop scalar channels (occupancy, argmin-pop wait, handover flag) ride
as extra ``ys`` columns of the same scan — stacked by ``lax.scan`` into
per-round arrays with zero additional carries.
"""
from __future__ import annotations

import jax.numpy as jnp


def fleet_state(spec):
    """Initial metrics carry for the single-RSU engines.  When the spec
    arms ``fault_counters`` (a fault model is active, DESIGN.md §16) the
    carry gains an ``i32[4]`` accumulator — (dropped, blackout, partial,
    discarded) — fed per pop from the fault plan's static counts table
    and conformance-checked against the f64 fault replay."""
    st = (jnp.zeros(spec.n_bins, jnp.int32), jnp.float32(0.0))
    if spec.fault_counters:
        st = st + (jnp.zeros(4, jnp.int32),)
    return st


def corridor_state(spec):
    """Initial metrics carry for the corridor engine."""
    st = (jnp.zeros((spec.n_rsus, spec.n_bins), jnp.int32),
          jnp.float32(0.0),
          jnp.zeros(spec.n_rsus, jnp.int32))
    if spec.fault_counters:
        st = st + (jnp.zeros(4, jnp.int32),)
    return st


def stale_bin(edges, stale):
    """Bucket a (traced f32) staleness value against the static edges —
    ``searchsorted`` side='left', the same rule as the f64 replay's
    ``np.searchsorted`` (the planner placed every edge a safe margin away
    from every sample, so both sides agree exactly)."""
    return jnp.searchsorted(edges, stale)


def fleet_pop(mst, edges, *, t, dl_t, fault_row=None):
    """Fold one pop into the fleet metrics carry; returns the new carry
    and the pop's ``(gap,)`` wait column.  ``fault_row`` is the pop's
    ``i32[4]`` fault-counter increment (required iff the carry holds the
    fault accumulator)."""
    hist, prev_t, *rest = mst
    hist = hist.at[stale_bin(edges, t - dl_t)].add(1)
    if rest:
        rest = [rest[0] + fault_row]
    return (hist, t, *rest), t - prev_t


def corridor_pop(mst, edges, *, t, dl_t, j, handover, fault_row=None):
    """Fold one pop into the corridor metrics carry (per-RSU histogram
    row ``j`` — the RSU the upload landed on; handover counted at the
    source row).  Returns the new carry and the pop's wait."""
    hist, prev_t, ho_cnt, *rest = mst
    hist = hist.at[j, stale_bin(edges, t - dl_t)].add(1)
    ho_cnt = ho_cnt.at[j].add(jnp.asarray(handover, jnp.int32))
    if rest:
        rest = [rest[0] + fault_row]
    return (hist, t, ho_cnt, *rest), t - prev_t


class RingStats:
    """Trace-level bf16 snapshot-ring guard counters (DESIGN.md §12/§14).

    Wraps the flat fast path's ``store`` closure: every checkpoint row
    stored to the ring is scanned for non-finite values (bf16 overflow
    saturates to inf) and folded into running counters.  All ``store``
    call sites execute at trace level (between scan segments), so plain
    Python attribute mutation is safe — the accumulation is ordinary
    traced arithmetic, not side effects inside a scan body."""

    def __init__(self):
        self.nonfinite = jnp.int32(0)
        self.max_abs = jnp.float32(0.0)

    def wrap(self, store):
        def wrapped(x):
            y = store(x)
            f = y.astype(jnp.float32)
            finite = jnp.isfinite(f)
            self.nonfinite = (self.nonfinite
                              + jnp.sum(~finite).astype(jnp.int32))
            self.max_abs = jnp.maximum(
                self.max_abs,
                jnp.max(jnp.where(finite, jnp.abs(f), 0.0)))
            return y
        return wrapped

    def out(self) -> dict:
        return {"ring_nonfinite": self.nonfinite,
                "ring_max_abs": self.max_abs}

"""f64 host replay of the telemetry channels (DESIGN.md §14).

The conformance oracle: re-drive the exact event timeline the planners
dry-run (``plan_fleet`` / ``plan_corridor`` — same ``_Timeline``, same
selection driving, same pop order) while recording the channel values the
device accumulators must reproduce:

- ``stale[r]``      pop time minus download time (f64) — binned through
                    :func:`repro.telemetry.spec.stale_histogram` this must
                    match the device histogram *exactly* (safe-margin edges),
- ``occupancy[r]``  live slots at the moment of pop ``r`` (the popped
                    upload included) — the device's ``isfinite(qt)`` count,
- ``gap[r]``        argmin-pop wait ``times[r] - times[r-1]`` (f32 on
                    device, so compared within the divergence-guard
                    tolerance, not exactly),
- corridor only: per-RSU occupancy ``[M, R]``, the per-pop handover flag
  (re-schedule lands on a different RSU than the upload arrived on) and
  its per-source-RSU count.

Planner discipline applies (rule PLN002): everything here is pure f64
numpy over the host timeline — no jax, no device state.
"""
from __future__ import annotations

import numpy as np

from repro.channel import ChannelParams, CorridorMobility, Mobility
from repro.faults import arrival_step, initial_vehicles, make_fault_state
from repro.selection import make_selection_state


def replay_fleet_channels(p: ChannelParams, seed: int, rounds: int,
                          selection=None, faults=None,
                          l_iters: int = 5) -> dict:
    """Re-drive the single-RSU fleet timeline; returns the f64 channel
    record for ``rounds`` pops.  A fault model drives the identical
    :class:`~repro.faults.runtime.FaultState` composition the engines
    replay (DESIGN.md §16), so the channels stay conformant under
    injected faults too."""
    from repro.core.mafl import _Timeline

    sel = make_selection_state(selection, p, Mobility(p), seed, rounds)
    flt = make_fault_state(faults, p, seed, rounds, l_iters)
    tl = _Timeline(p, seed, cl_scale=None if flt is None else flt.cl_scale)
    for k in initial_vehicles(sel, flt, p.K):
        tl.schedule(k, 0.0)

    M = rounds
    veh = np.empty(M, np.int64)
    stale = np.empty(M)
    occ = np.empty(M, np.int64)
    gap = np.empty(M)
    times = np.empty(M)
    prev_t = 0.0
    for r in range(M):
        occ[r] = len(tl.queue)            # live slots incl. the pop itself
        ev = tl.queue.pop()
        veh[r] = ev.vehicle
        times[r] = ev.time
        stale[r] = ev.time - ev.download_time
        gap[r] = ev.time - prev_t
        prev_t = ev.time
        if sel is None and flt is None:
            tl.schedule(ev.vehicle, ev.time)
        else:
            if flt is not None:
                flt.on_pop(ev.vehicle, r)
            arrival_step(
                sel, flt, r=r, vehicle=ev.vehicle, time=ev.time,
                upload_delay=ev.upload_delay, train_delay=ev.train_delay,
                pending=len(tl.queue),
                schedule=lambda v, t=ev.time: tl.schedule(v, t))
        tl.prune()
    return {"veh": veh, "times": times, "stale": stale,
            "occupancy": occ, "gap": gap}


def replay_corridor_channels(p: ChannelParams, n_rsus: int, seed: int,
                             rounds: int, entry: str = "uniform",
                             selection=None,
                             reconcile_every: int = 0, faults=None,
                             l_iters: int = 1) -> dict:
    """Re-drive the corridor timeline; adds the per-RSU channels.

    A pending slot's RSU row is the cell serving the vehicle at *arrival*
    time (positions are pure in t — the same rule the engine bakes into
    the slot migration), so per-RSU occupancy is computable from the
    pending events alone.  The handover flag marks an admitted
    re-schedule whose new arrival is served by a different RSU than the
    upload it follows; it is counted at the source RSU (a fault-suppressed
    re-schedule never migrates, so it never counts)."""
    from repro.core.mafl import _Timeline

    corridor = CorridorMobility(p, n_rsus, entry=entry)
    sel = make_selection_state(selection, p, corridor, seed, rounds,
                               resel_every=reconcile_every)
    flt = make_fault_state(faults, p, seed, rounds, l_iters,
                           recheck_every=reconcile_every)
    tl = _Timeline(p, seed, distance_fn=corridor.distance,
                   cl_scale=None if flt is None else flt.cl_scale)
    for k in initial_vehicles(sel, flt, p.K):
        tl.schedule(k, 0.0)

    M = rounds
    R = n_rsus
    veh = np.empty(M, np.int64)
    stale = np.empty(M)
    occ = np.zeros((M, R), np.int64)
    gap = np.empty(M)
    times = np.empty(M)
    up_rsu = np.empty(M, np.int64)
    handover = np.zeros(M, bool)
    prev_t = 0.0
    for r in range(M):
        pend = list(tl.queue.pending())
        if pend:
            vs = np.array([pe.vehicle for pe in pend], np.int64)
            ts = np.array([pe.time for pe in pend])
            occ[r] = np.bincount(
                np.asarray(corridor.serving_rsu(vs, ts), np.int64),
                minlength=R)
        ev = tl.queue.pop()
        j = int(corridor.serving_rsu(ev.vehicle, ev.time))
        veh[r] = ev.vehicle
        times[r] = ev.time
        up_rsu[r] = j
        stale[r] = ev.time - ev.download_time
        gap[r] = ev.time - prev_t
        prev_t = ev.time
        if sel is None and flt is None:
            nev = tl.schedule(ev.vehicle, ev.time)
            handover[r] = int(
                corridor.serving_rsu(ev.vehicle, nev.time)) != j
        else:
            if flt is not None:
                flt.on_pop(ev.vehicle, r)
            res = {}
            arrival_step(
                sel, flt, r=r, vehicle=ev.vehicle, time=ev.time,
                upload_delay=ev.upload_delay, train_delay=ev.train_delay,
                pending=len(tl.queue),
                schedule=lambda v, t=ev.time: res.__setitem__(
                    "nev", tl.schedule(v, t)),
                readmit=lambda v, t=ev.time: tl.schedule(v, t))
            nev = res.get("nev")
            if nev is not None:
                handover[r] = int(
                    corridor.serving_rsu(ev.vehicle, nev.time)) != j
        tl.prune()
    return {"veh": veh, "times": times, "stale": stale,
            "occupancy": occ, "gap": gap, "up_rsu": up_rsu,
            "handover": handover,
            "handover_count": np.bincount(up_rsu[handover], minlength=R)}

"""The host f64 planner side of the telemetry subsystem (DESIGN.md §14).

A :class:`MetricsSpec` is static plan data, exactly like the selection
layer's admission tables (DESIGN.md §11): the host f64 dry run declares it,
the compiled programs fold it in as trace-time constants, and the device
never makes a data-dependent shape decision.  The one subtle piece is the
staleness histogram: the device computes staleness in f32 while the
conformance oracle replays it in f64, so a bin edge sitting close to a
sample could bucket differently on the two sides.  The planner prevents
this by construction — it knows every staleness value the run will ever
produce (times never depend on training, DESIGN.md §3), so it places each
edge in a gap at least ``2 * margin`` wide, where ``margin`` bounds the
f32 time error the engines' divergence guards already enforce.  The f64
replay and the f32 device program then produce *identical* histograms,
checked exactly by ``tests/test_telemetry.py``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

# default staleness-histogram bin count (small and fixed: the histogram
# rides in the scan carry, so its size is a compiled-program constant)
DEFAULT_BINS = 8


@dataclass(frozen=True)
class MetricsSpec:
    """Everything static the compiled programs need about metrics.

    ``edges`` are the *interior* staleness-bin boundaries (``n_bins =
    len(edges) + 1`` bins, open-ended on both sides), pre-rounded to f32
    so the device and the f64 replay bucket against bit-identical
    constants.  ``n_rsus`` sizes the per-RSU axes of the corridor
    channels; ``ring_guard`` arms the bf16 snapshot-ring finiteness /
    overflow counters on the flat fast path."""
    enabled: bool = True
    edges: tuple = ()
    n_rsus: int = 1
    ring_guard: bool = False
    # fault-injection counters (dropped uploads / partial epochs / blackout
    # rounds / cap discards) ride the scan carry when a fault model is on
    fault_counters: bool = False

    @property
    def n_bins(self) -> int:
        return len(self.edges) + 1

    def signature(self) -> tuple:
        """Hashable identity for the engines' program-cache keys.  A
        disabled spec must never reach a cache key — the engines map it
        to None first, so ``metrics=off`` shares the legacy executable."""
        return (self.enabled, self.edges, self.n_rsus, self.ring_guard,
                self.fault_counters)

    def to_json(self) -> dict:
        return {"enabled": self.enabled, "edges": list(self.edges),
                "n_bins": self.n_bins, "n_rsus": self.n_rsus,
                "ring_guard": self.ring_guard,
                "fault_counters": self.fault_counters}


def _f32(x: float) -> float:
    """Round to the nearest f32 value (kept as a Python float): the device
    compares staleness against exactly this constant."""
    # repro-check: waive[PLN002] edges are deliberately f32-rounded so the device and the f64 replay bucket against bit-identical constants
    return float(np.float32(x))


def stale_margin(times: np.ndarray) -> float:
    """Upper bound on |f32 staleness - f64 staleness| for this timeline.

    Staleness is ``t - dl_t`` with both carried in f32 on device; the
    engines' divergence guards pin device times to the host dry run at
    ``rtol=1e-4, atol=1e-3``, so the staleness error is bounded by twice
    that envelope at the largest time in the run."""
    t_max = float(np.max(times)) if len(times) else 0.0
    return 2.0 * (1e-3 + 1e-4 * abs(t_max))


def plan_stale_edges(stale: np.ndarray, times: np.ndarray,
                     n_bins: int = DEFAULT_BINS) -> tuple:
    """Quantile-ish interior bin edges with every edge at least
    ``stale_margin`` away from every planned staleness sample.

    For each target quantile the candidate edge is the midpoint of the
    gap between the two neighbouring sorted samples; if that gap is too
    narrow the search walks outward to the nearest gap wide enough.
    Degenerate timelines (all staleness equal) simply yield fewer bins —
    the histogram shape stays static per world either way."""
    s = np.sort(np.asarray(stale, np.float64))
    m = len(s)
    if m < 2 or n_bins < 2:
        return ()
    margin = stale_margin(times)
    edges: list[float] = []
    for j in range(1, n_bins):
        q = min(max(int(round(j * m / n_bins)), 1), m - 1)
        e = _safe_edge(s, q, margin)
        if e is not None and (not edges or e > edges[-1] + 2 * margin):
            edges.append(e)
    return tuple(_f32(e) for e in edges)


def _safe_edge(s: np.ndarray, q: int, margin: float) -> Optional[float]:
    """Midpoint of the nearest inter-sample gap wider than 2*margin,
    searching outward from the gap below ``s[q]``."""
    m = len(s)
    for d in range(m):
        for qq in (q + d, q - d):
            if 1 <= qq <= m - 1 and s[qq] - s[qq - 1] > 2.0 * margin:
                return (s[qq] + s[qq - 1]) / 2.0
    return None


def bucket_indices(edges, stale: np.ndarray) -> np.ndarray:
    """f64 reference bucketing — ``np.searchsorted`` against the same
    f32-rounded edges the device uses (``jnp.searchsorted``, same 'left'
    side), so both sides share one bucketing rule."""
    return np.searchsorted(np.asarray(edges, np.float64),
                           np.asarray(stale, np.float64))


def stale_histogram(edges, stale: np.ndarray,
                    rsu: Optional[np.ndarray] = None,
                    n_rsus: int = 1) -> np.ndarray:
    """f64 reference staleness histogram: ``[n_bins]``, or ``[n_rsus,
    n_bins]`` when per-upload serving RSUs are given."""
    n_bins = len(edges) + 1
    idx = bucket_indices(edges, stale)
    if rsu is None:
        return np.bincount(idx, minlength=n_bins).astype(np.int64)
    out = np.zeros((n_rsus, n_bins), np.int64)
    np.add.at(out, (np.asarray(rsu, np.int64), idx), 1)
    return out


# ---------------------------------------------------------------------------
# engine-facing normalization
# ---------------------------------------------------------------------------
def metrics_requested(metrics) -> bool:
    """True iff the engines' ``metrics`` argument asks for collection.
    Anything falsy — None, False, "off", a disabled spec — is the legacy
    path with zero telemetry machinery."""
    if metrics is None or metrics is False or metrics == "off":
        return False
    if isinstance(metrics, MetricsSpec):
        return metrics.enabled
    if metrics is True or metrics == "on":
        return True
    raise ValueError(
        f"unknown metrics setting {metrics!r}: expected None/'off', "
        "'on'/True, or a MetricsSpec")


def resolve_metrics(metrics, *, stale, times, n_rsus: int = 1,
                    ring_guard: bool = False,
                    n_bins: int = DEFAULT_BINS,
                    fault_counters: bool = False) -> Optional[MetricsSpec]:
    """Normalize the engines' ``metrics`` argument into a MetricsSpec (or
    None for the exact legacy program).  ``stale``/``times`` are the host
    dry run's f64 per-round staleness and pop times — the planner derives
    safe histogram edges from them.  ``fault_counters`` arms the fault
    channels when the run carries a fault model (DESIGN.md §16)."""
    if not metrics_requested(metrics):
        return None
    if isinstance(metrics, MetricsSpec):
        return metrics
    return MetricsSpec(enabled=True,
                       edges=plan_stale_edges(stale, times, n_bins),
                       n_rsus=n_rsus, ring_guard=ring_guard,
                       fault_counters=fault_counters)

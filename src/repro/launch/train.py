"""End-to-end MAFL training driver for transformer clients.

Runs the paper's Algorithm 1 with a *transformer LM* as the per-vehicle model
(the aggregation layer is structure-agnostic — DESIGN.md §4): K vehicles hold
private token shards, train locally with plain SGD (Eq. 2) on next-token loss
(Eq. 1), and the RSU merges each upload with the MAFL weights (Eqs. 7-11).

Usage (reduced arch sizes are CPU-sized; full sizes lower via dryrun.py):
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --reduced --rounds 20 --l-iters 4 --scheme mafl
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel import (ChannelParams, Mobility, RayleighAR1,
                           shannon_rate, training_delay, upload_delay)
from repro.checkpointing import save_checkpoint
from repro.configs import get_config
from repro.core.aggregation import afl_update, mafl_update
from repro.core.events import EventQueue
from repro.core.weights import combined_weight
from repro.data import synth_tokens
from repro.models import transformer as T


def lm_loss_and_grad(cfg):
    def loss_fn(params, tokens):
        logits, aux = T.forward(cfg, params, tokens[:, :-1])
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(logp, tokens[:, 1:, None], -1)
        return jnp.mean(nll) + aux

    return jax.jit(jax.value_and_grad(loss_fn))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized variant of the arch family")
    ap.add_argument("--scheme", default="mafl", choices=["mafl", "afl"])
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--l-iters", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--use-kernel", action="store_true",
                    help="aggregate with the Pallas weighted_agg kernel")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    p = ChannelParams()
    key = jax.random.PRNGKey(args.seed)
    global_params = T.init_params(cfg, key)
    vg = lm_loss_and_grad(cfg)

    # private token shards, sized per the paper's D_i profile
    shards = [synth_tokens(max(8, p.data_count(i + 1) // 500),
                           args.seq_len + 1, cfg.vocab_size, seed=i)
              for i in range(p.K)]
    held_out = synth_tokens(32, args.seq_len + 1, cfg.vocab_size, seed=999)

    mobility, fading = Mobility(p), RayleighAR1(p, seed=args.seed)
    queue = EventQueue()
    rng = np.random.default_rng(args.seed)
    gains = fading.step()

    def schedule(vehicle, t_dl):
        c_l = training_delay(p, vehicle + 1)
        t_up = t_dl + c_l
        rate = shannon_rate(p, gains[vehicle],
                            mobility.distance(vehicle, t_up))
        c_u = upload_delay(p, rate)
        queue.push(t_up + c_u, vehicle, download_time=t_dl, train_delay=c_l,
                   upload_delay=c_u, payload=global_params)

    for k in range(p.K):
        schedule(k, 0.0)

    print(f"arch={cfg.name} reduced={args.reduced} scheme={args.scheme} "
          f"params={T.param_count(cfg):,}")
    t0 = time.time()
    for r in range(1, args.rounds + 1):
        ev = queue.pop()
        local = ev.payload
        shard = shards[ev.vehicle]
        for _ in range(args.l_iters):
            rows = rng.integers(0, len(shard), args.batch)
            loss, grads = vg(local, jnp.asarray(shard[rows]))
            local = jax.tree_util.tree_map(
                lambda w, g: w - args.lr * g, local, grads)
        if args.scheme == "mafl":
            w = combined_weight(p, ev.upload_delay, ev.train_delay)
            global_params = mafl_update(global_params, local, p.beta, w,
                                        use_kernel=args.use_kernel)
        else:
            global_params = afl_update(global_params, local, p.beta)
        gains = fading.step()
        schedule(ev.vehicle, ev.time)
        if r % 5 == 0 or r == args.rounds:
            val, _ = vg(global_params, jnp.asarray(held_out))
            print(f"round {r:3d} vehicle {ev.vehicle} local_loss "
                  f"{float(loss):.4f} heldout {float(val):.4f} "
                  f"({time.time() - t0:.0f}s)")
    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, args.rounds, global_params,
                               meta={"arch": cfg.name,
                                     "scheme": args.scheme})
        print("saved", path)
    return global_params


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init) — see the brief's MULTI-POD DRY-RUN §0.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_config, get_shape, legal_shapes, list_archs  # noqa: E402
from repro.configs.shapes import DECODE, PREFILL, TRAIN  # noqa: E402
from repro.launch import steps as steps_mod  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.transformer import param_count  # noqa: E402
from repro.roofline import parse_hlo_module  # noqa: E402
from repro.roofline.analysis import model_flops_estimate, roofline_terms  # noqa: E402
from repro.sharding import batch_spec, cache_specs, param_specs  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results")


# Production memory knobs per arch for the TRAIN shape: grad-accumulation
# splits + activation sharding (DESIGN.md §5).  Tuned so each train step's
# per-device residency fits 16 GB v5e HBM (see EXPERIMENTS.md §Dry-run).
TRAIN_OVERRIDES = {
    # batch-anchored activation sharding (see transformer._maybe_shard_h)
    # + grad-accumulation splits, tuned per EXPERIMENTS.md §Perf so every
    # train step except llama3-405b fits 16 GB v5e HBM.
    "llama3-405b": dict(microbatches=4, shard_activations=True,
                        grad_accum_dtype="bfloat16"),
    "llama4-scout-17b-a16e": dict(microbatches=16, remat_sublayer=True,
                                  shard_activations=True,
                                  grad_accum_dtype="bfloat16"),
    "jamba-v0.1-52b": dict(microbatches=16, shard_activations=True,
                           grad_accum_dtype="bfloat16", remat_sublayer=True),
    "mistral-nemo-12b": dict(microbatches=8, shard_activations=True),
    "deepseek-v2-lite-16b": dict(microbatches=16, remat_sublayer=True,
                                 shard_activations=True),
    "qwen1.5-4b": dict(microbatches=8, shard_activations=True),
    "musicgen-large": dict(microbatches=4, shard_activations=True),
    "internvl2-2b": dict(microbatches=4, shard_activations=True),
    "rwkv6-1.6b": dict(microbatches=2),
    "smollm-360m": dict(microbatches=2),
}


def arch_for(arch: str, shape_name: str):
    """Arch config, applying long-context and train-memory variants."""
    if arch == "mistral-nemo-12b" and shape_name == "long_500k":
        from repro.configs.mistral_nemo_12b import sliding_window_variant
        cfg = sliding_window_variant()
    else:
        cfg = get_config(arch)
    if shape_name == "train_4k" and arch in TRAIN_OVERRIDES:
        cfg = cfg.variant(**TRAIN_OVERRIDES[arch])
    if cfg.vocab_size % 256:
        # pad the vocab to a shardable multiple (standard production
        # practice; the model card's tokenizer ids are unaffected) so the
        # embedding/lm_head shard over the 16-way model axis.
        cfg = cfg.variant(vocab_size=-(-cfg.vocab_size // 256) * 256)
    return cfg


def lower_one(cfg, shape, mesh, mesh_name: str, extra_opts=None):
    """Lower + compile one (arch, shape, mesh) and return the record dict."""
    opts = extra_opts or {}
    dtype = jnp.bfloat16
    pspecs = param_specs(cfg, mesh, fsdp=opts.get("fsdp"))
    psh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)
    pshapes = steps_mod.param_shapes(cfg, dtype)
    specs = steps_mod.input_specs(cfg, shape, dtype)
    t0 = time.time()
    ctx = jax.set_mesh(mesh)
    ctx.__enter__()

    if opts.get("dp_over_model"):
        pspecs = jax.tree_util.tree_map(
            lambda s: type(s)(*([None] * len(s))), pspecs)
        psh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                     pspecs)

    def batch_spec_fn(m, b):
        if opts.get("dp_over_model"):
            axes = tuple(m.axis_names)
            return P(axes)
        return batch_spec(m, b)

    if shape.kind == TRAIN:
        gspecs = None if opts.get("no_grad_specs") else pspecs
        step = steps_mod.make_train_step(cfg, grad_specs=gspecs)
        bspec = batch_spec_fn(mesh, shape.global_batch)
        bsh = {"tokens": NamedSharding(mesh, P(*bspec))}
        if "patch_embeds" in specs:
            bsh["patch_embeds"] = NamedSharding(mesh, P(*bspec, None, None))
        fn = jax.jit(step, in_shardings=(psh, bsh),
                     out_shardings=(psh, None), donate_argnums=(0,))
        lowered = fn.lower(pshapes, specs)
    elif shape.kind == PREFILL:
        step = steps_mod.make_prefill_step(cfg)
        bspec = batch_spec_fn(mesh, shape.global_batch)
        bsh = {"tokens": NamedSharding(mesh, P(*bspec))}
        if "patch_embeds" in specs:
            bsh["patch_embeds"] = NamedSharding(mesh, P(*bspec, None, None))
        csh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s),
            cache_specs(cfg, mesh, shape.global_batch, shape.seq_len))
        fn = jax.jit(step, in_shardings=(psh, bsh),
                     out_shardings=(None, csh))
        lowered = fn.lower(pshapes, specs)
    else:
        assert shape.kind == DECODE
        step = steps_mod.make_serve_step(cfg)
        bspec = batch_spec_fn(mesh, shape.global_batch)
        csh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s),
            cache_specs(cfg, mesh, shape.global_batch, shape.seq_len))
        tsh = NamedSharding(mesh, P(*bspec))
        fn = jax.jit(step, in_shardings=(psh, tsh, csh, None),
                     out_shardings=(tsh, csh), donate_argnums=(2,))
        lowered = fn.lower(pshapes, specs["token"], specs["cache"],
                           specs["pos"])

    compiled = lowered.compile()
    ctx.__exit__(None, None, None)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = parse_hlo_module(compiled.as_text())
    n_chips = mesh.devices.size
    terms = roofline_terms(
        arch=cfg.name, shape=shape.name, mesh_name=mesh_name,
        n_chips=n_chips, hlo_stats=hlo, memory_stats=mem,
        cost_flops=float(cost.get("flops", 0.0)),
        model_flops=model_flops_estimate(cfg, shape),
        tokens=shape.tokens)
    rec = terms.to_dict()
    rec.update(
        n_chips=n_chips,
        param_count=param_count(cfg),
        param_count_active=param_count(cfg, active_only=True),
        argument_bytes=mem.argument_size_in_bytes,
        output_bytes=mem.output_size_in_bytes,
        temp_bytes=mem.temp_size_in_bytes,
        compile_seconds=round(t_compile, 1),
        while_trips=hlo.while_trips,
    )
    return rec


def mafl_agg_record(cfg, mesh, mesh_name: str):
    """Lower the RSU aggregation (Eq. 10+11) over the full param pytree —
    the paper's technique as its own program."""
    dtype = jnp.bfloat16
    pspecs = param_specs(cfg, mesh)
    psh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)
    pshapes = steps_mod.param_shapes(cfg, dtype)
    scal = jax.ShapeDtypeStruct((), jnp.float32)
    step = steps_mod.make_mafl_step(cfg)
    t0 = time.time()
    compiled = jax.jit(step, in_shardings=(psh, psh, None, None),
                       out_shardings=psh,
                       donate_argnums=(0,)).lower(pshapes, pshapes, scal,
                                                  scal).compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = parse_hlo_module(compiled.as_text())

    class _Shape:
        name, kind, tokens, global_batch, seq_len = "mafl_agg", "agg", 0, 0, 0
    terms = roofline_terms(
        arch=cfg.name, shape="mafl_agg", mesh_name=mesh_name,
        n_chips=mesh.devices.size, hlo_stats=hlo, memory_stats=mem,
        cost_flops=float(cost.get("flops", 0.0)),
        model_flops=3.0 * param_count(cfg),   # 3 flops per param (Eq. 10+11)
        tokens=0)
    rec = terms.to_dict()
    rec.update(n_chips=mesh.devices.size, param_count=param_count(cfg),
               compile_seconds=round(time.time() - t0, 1))
    return rec


def main():
    ap = argparse.ArgumentParser(description="Multi-pod dry-run matrix")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--mafl-agg", action="store_true",
                    help="also lower the MAFL aggregation step per arch")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fsdp", default=None,
                    help="override FSDP auto-rule: on|off")
    ap.add_argument("--override", default="",
                    help="cfg variant overrides, e.g. "
                         "'microbatches=8,mla_absorb=True'")
    ap.add_argument("--tag", default="",
                    help="suffix for the output record filename")
    ap.add_argument("--no-grad-specs", action="store_true",
                    help="disable the grad reduce-scatter constraint")
    ap.add_argument("--dp-over-model", action="store_true",
                    help="shard the batch over BOTH mesh axes (pure data "
                         "parallel; params replicated)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    fsdp = {None: None, "on": True, "off": False}[args.fsdp]

    for multi_pod in meshes:
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch in archs:
            base_cfg = get_config(arch)
            shapes = (legal_shapes(base_cfg) if args.shape == "all"
                      else args.shape.split(","))
            if arch == "mistral-nemo-12b" and args.shape == "all":
                shapes = shapes + ["long_500k"]   # via the SWA variant
            for shape_name in shapes:
                tag = f"_{args.tag}" if args.tag else ""
                out_path = os.path.join(
                    args.out,
                    f"dryrun_{arch}_{shape_name}_{mesh_name}{tag}.json")
                if os.path.exists(out_path) and not args.force:
                    print(f"skip {out_path} (exists)")
                    continue
                cfg = arch_for(arch, shape_name)
                if args.override:
                    kw = {}
                    for kv in args.override.split(","):
                        k, v = kv.split("=")
                        kw[k] = {"True": True, "False": False}.get(
                            v, int(v) if v.isdigit() else v)
                    cfg = cfg.variant(**kw)
                shape = get_shape(shape_name)
                print(f"[{mesh_name}] {arch} x {shape_name} ...", flush=True)
                try:
                    rec = lower_one(cfg, shape, mesh, mesh_name,
                                    {"fsdp": fsdp,
                                     "dp_over_model": args.dp_over_model,
                                     "no_grad_specs": args.no_grad_specs})
                    with open(out_path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"  ok: flops/dev={rec['flops_per_device']:.3e} "
                          f"coll={rec['collective_bytes_per_device']:.3e} "
                          f"bottleneck={rec['bottleneck']} "
                          f"fits={rec['fits_hbm']} "
                          f"({rec['compile_seconds']}s)", flush=True)
                except Exception as e:
                    print(f"  FAIL: {e}")
                    traceback.print_exc()
            if args.mafl_agg:
                out_path = os.path.join(
                    args.out, f"dryrun_{arch}_mafl-agg_{mesh_name}.json")
                if os.path.exists(out_path) and not args.force:
                    continue
                try:
                    rec = mafl_agg_record(get_config(arch), mesh, mesh_name)
                    with open(out_path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"  mafl-agg ok ({rec['compile_seconds']}s)",
                          flush=True)
                except Exception as e:
                    print(f"  mafl-agg FAIL: {e}")


if __name__ == "__main__":
    main()

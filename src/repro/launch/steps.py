"""jit-able train / prefill / serve steps + ``input_specs`` for every
(arch x input-shape) combination.

``train_step`` is paper-faithful plain SGD (Eq. 2) over the mean next-token
cross-entropy (Eq. 1) + MoE aux loss.  ``serve_step`` decodes ONE token
against a ``seq_len`` KV cache (the decode shapes' contract).  ``mafl_step``
is the RSU aggregation (Eq. 10+11) as its own lowered program — the paper's
technique at datacenter scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import DECODE, InputShape, PREFILL, TRAIN
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------
def make_train_step(cfg: ArchConfig, lr: float = 1e-2, grad_specs=None):
    """(params, batch) -> (params, metrics).  batch: {'tokens': [B, S+1]}
    (+ 'patch_embeds' for vlm).  Plain SGD per the paper's Eq. (2).

    ``cfg.microbatches > 1`` runs grad accumulation over batch splits
    (scanned) — the production memory knob for deep models whose per-pass
    activations would not fit HBM otherwise.

    ``grad_specs`` (pytree of PartitionSpec matching params): constrains
    per-microbatch grads to the FSDP param sharding so GSPMD emits
    reduce-scatter into the sharded accumulator instead of a full
    all-reduce per layer per microbatch (≈3x collective traffic on
    llama3-405b — EXPERIMENTS.md §Perf)."""
    P = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0

    def constrain(g):
        if grad_specs is None:
            return g
        return jax.tree_util.tree_map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), g,
            grad_specs)

    # Vocab-chunked CE helps ONLY when the lm_head cannot shard (odd vocab):
    # with a model-sharded head, XLA keeps [B,S,V/16] logit shards, which
    # beats replicated [B,S,chunk] tiles (measured — EXPERIMENTS.md §Perf).
    # The dry-run pads vocabs to shardable sizes, so this is opt-in.
    chunk = cfg.loss_chunk

    def loss_fn(p, mb):
        if not chunk:
            logits, aux = T.forward(cfg, p, mb["inputs"],
                                    mb.get("patch_embeds"))
            logits = logits[:, P:, :]                     # text positions
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            nll = -jnp.take_along_axis(logp, mb["targets"][..., None], -1)
            return jnp.mean(nll) + aux.astype(jnp.float32)
        h, aux = T.forward_hidden(cfg, p, mb["inputs"],
                                  mb.get("patch_embeds"))
        nll = _chunked_nll(cfg, p, h[:, P:, :], mb["targets"], chunk)
        return jnp.mean(nll) + aux.astype(jnp.float32)

    def train_step(params, batch):
        tokens = batch["tokens"]
        mb = {"inputs": tokens[:, :-1], "targets": tokens[:, 1:]}
        if "patch_embeds" in batch:
            mb["patch_embeds"] = batch["patch_embeds"]
        M = cfg.microbatches
        if M == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
        else:
            acc_dt = jnp.dtype(cfg.grad_accum_dtype)
            splits = jax.tree_util.tree_map(
                lambda a: a.reshape(M, a.shape[0] // M, *a.shape[1:]), mb)

            def mb_body(carry, mb_i):
                acc, loss_acc = carry
                loss_i, g_i = jax.value_and_grad(loss_fn)(params, mb_i)
                g_i = constrain(g_i)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(acc_dt), acc, g_i)
                return (acc, loss_acc + loss_i), None

            zeros = constrain(jax.tree_util.tree_map(
                lambda w: jnp.zeros(w.shape, acc_dt), params))
            (grads, loss), _ = jax.lax.scan(
                mb_body, (zeros, jnp.zeros((), jnp.float32)), splits)
            grads = jax.tree_util.tree_map(lambda g: g / M, grads)
            loss = loss / M
        new_params = jax.tree_util.tree_map(
            lambda w, g: (w.astype(jnp.float32) -
                          lr * g.astype(jnp.float32)).astype(w.dtype),
            params, grads)
        return new_params, {"loss": loss}

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        logits, cache = T.prefill(cfg, params, batch["tokens"],
                                  batch.get("patch_embeds"))
        return logits[:, -1:, :], cache

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    """ONE new token against a pre-filled cache (decode shapes)."""

    def serve_step(params, token, cache, pos):
        logits, new_cache = T.decode_step(cfg, params, token, cache, pos)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, new_cache

    return serve_step


def make_mafl_step(cfg: ArchConfig):
    """RSU aggregation (Eq. 10+11) over the full parameter pytree, with the
    scalar weights as traced inputs (one compiled program serves all rounds).
    """

    def mafl_step(global_params, local_params, beta, weight):
        b = beta.astype(jnp.float32)
        w = weight.astype(jnp.float32)
        return jax.tree_util.tree_map(
            lambda g, l: (b * g.astype(jnp.float32) + (1 - b) * w *
                          l.astype(jnp.float32)).astype(g.dtype),
            global_params, local_params)

    return mafl_step


def _chunked_nll(cfg, params, h, targets, chunk):
    """Vocab-chunked flash-CE (jnp mirror of ``kernels/cross_entropy``):
    streams [B,S,chunk] logit tiles keeping only running (max, sumexp,
    label-logit) per position — never materializes [B,S,V] logits.  The
    Pallas kernel is the TPU-target form of the same recurrence."""
    W = T.head_weight(cfg, params)                         # [d, V]
    V = cfg.vocab_size
    n_chunks = -(-V // chunk)
    padV = n_chunks * chunk - V
    if padV:
        W = jnp.pad(W, ((0, 0), (0, padV)))
    B, S, _ = h.shape
    m0 = jnp.full((B, S), -1e30, jnp.float32)
    s0 = jnp.zeros((B, S), jnp.float32)
    c0 = jnp.full((B, S), -1e30, jnp.float32)

    @jax.checkpoint
    def body(carry, i):
        m, s, c = carry
        W_c = jax.lax.dynamic_slice_in_dim(W, i * chunk, chunk, axis=1)
        logits = jnp.einsum("bsd,dv->bsv", h, W_c).astype(jnp.float32)
        idx = i * chunk + jnp.arange(chunk)
        logits = jnp.where(idx < V, logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        s = s * jnp.exp(m - m_new) + jnp.exp(
            logits - m_new[..., None]).sum(-1)
        local = targets - i * chunk
        hit = (local >= 0) & (local < chunk)
        picked = jnp.take_along_axis(
            logits, jnp.clip(local, 0, chunk - 1)[..., None], -1)[..., 0]
        c = jnp.where(hit, picked, c)
        return (m_new, s, c), None

    (m, s, c), _ = jax.lax.scan(body, (m0, s0, c0), jnp.arange(n_chunks))
    return jnp.log(s) + m - c


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — never allocated)
# ---------------------------------------------------------------------------
def param_shapes(cfg: ArchConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(T.init_params, cfg, dtype=dtype),
        jax.random.PRNGKey(0))


def cache_shapes(cfg: ArchConfig, batch: int, seq_len: int,
                 dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, batch, seq_len, dtype))


def input_specs(cfg: ArchConfig, shape: InputShape, dtype=jnp.bfloat16):
    """Model inputs for the given shape, as ShapeDtypeStructs.

    train/prefill: {'tokens': [B, S(+1 train)]} (+ patch embeds for vlm;
    text seq shortened so frontend + text == seq_len).
    decode: (token [B,1], cache(seq_len), pos scalar)."""
    B, S = shape.global_batch, shape.seq_len
    P = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
    if shape.kind == TRAIN:
        batch = {"tokens": jax.ShapeDtypeStruct((B, S - P + 1), jnp.int32)}
        if P:
            batch["patch_embeds"] = jax.ShapeDtypeStruct((B, P, cfg.d_model),
                                                         dtype)
        return batch
    if shape.kind == PREFILL:
        batch = {"tokens": jax.ShapeDtypeStruct((B, S - P), jnp.int32)}
        if P:
            batch["patch_embeds"] = jax.ShapeDtypeStruct((B, P, cfg.d_model),
                                                         dtype)
        return batch
    assert shape.kind == DECODE
    return {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "cache": cache_shapes(cfg, B, S, dtype),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }

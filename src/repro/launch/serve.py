"""Serving driver: prefill a batch of prompts, then decode tokens
autoregressively against the KV cache (the decode shapes' runtime path).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
        --prompt-len 32 --gen 16 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as T


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    max_seq = args.prompt_len + args.gen

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    fe = None
    if cfg.frontend == "vision":
        fe = jax.random.normal(
            key, (args.batch, cfg.n_frontend_tokens, cfg.d_model)) * 0.02

    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, t: T.prefill(cfg, p, t, fe))(params, prompts)
    cache = T.grow_cache(cfg, cache, args.batch, max_seq +
                         (cfg.n_frontend_tokens if fe is not None else 0))
    print(f"prefill {args.batch}x{args.prompt_len}: "
          f"{time.time() - t0:.2f}s")

    decode = jax.jit(lambda p, t, c, pos: T.decode_step(cfg, p, t, c, pos))
    token = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
    out = [token]
    offset = cfg.n_frontend_tokens if fe is not None else 0
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, token, cache,
                               jnp.int32(offset + args.prompt_len + i))
        token = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(token)
    dt = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"decoded {args.gen - 1} steps x {args.batch} seqs in {dt:.2f}s "
          f"({(args.gen - 1) * args.batch / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", toks[0].tolist())
    return toks


if __name__ == "__main__":
    main()

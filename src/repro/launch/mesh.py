"""Production meshes (DESIGN.md §5).

Built inside functions so importing this module never touches jax device
state; only ``launch/dryrun.py`` forces the 512-placeholder-device platform.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16,16) ("data","model") = 256 chips (v5e pod).
    Multi-pod: (2,16,16) ("pod","data","model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for smoke tests on the real CPU device."""
    return jax.make_mesh((1, 1), ("data", "model"))

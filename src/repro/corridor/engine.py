"""Device-resident corridor engine: R RSU cohorts, handover, and the cloud
reconciliation tier in one compiled program (``engine="corridor"``,
DESIGN.md §10).

The retired serial loop (``corridor.reference``) pays Python dispatch per
arrival *and* per RSU bookkeeping step, capping corridors at K≈40.  This
engine extends the mega-fleet layout (DESIGN.md §9) with an RSU axis:

- **Per-RSU slot queues, ``f32[R, K]``.**  The jit engine's per-vehicle
  slot columns gain a leading RSU axis: vehicle i's single in-flight upload
  occupies slot ``(j, i)`` where j is the RSU serving it at *arrival* time
  (positions are pure in t, so the handover target is known at schedule
  time).  Pop is an argmin over the flattened ``R*K`` time column; a
  **handover is a vectorized slot migration** — the re-schedule writes
  ``+inf`` into the old row and the new arrival time into the row of the
  RSU the vehicle will have reached, moving the slot (and with it the
  vehicle's download-time/staleness column and in-flight payload pointer)
  between RSU shards whenever the trajectory crosses a coverage boundary.

- **Cohort stack, ``[R, ...]``.**  The R cohort models are one stacked
  pytree; an arrival updates exactly one row (dynamic one-row scatter, or a
  masked local-row update under the ``"rsu"``-sharded mesh path).

- **Snapshot ring: one model per round, exactly.**  Each round re-schedules
  exactly one vehicle, whose next download reads exactly one cohort — the
  one its upload just landed on (download happens at the arrival position).
  So ``ring[r+1]`` stores that single post-round-r cohort row, and
  ``ring[0]`` is the common init (every cohort starts from the same
  model).  Payload indexing is therefore identical to the single-RSU jit
  engine — the RSU choice is already baked into the row — and rows that no
  later wave reads are dead code to XLA.

- **Reconciliation between scan segments.**  Cloud-tier reconcile rounds
  (every ``reconcile_every`` arrivals) are statically known, so scan
  segments are split at those boundaries and the reconcile runs *between*
  scans at trace level: FedAvg (all cohorts adopt the stack mean) or EMA
  (each cohort moves ``tau`` toward it, optionally through the Pallas
  ``weighted_agg`` kernel).  Because the re-download payload of the
  boundary round must see the *post*-reconcile cohort (the serial
  reference schedules after reconciling), the boundary's ring row is
  overwritten with the reconciled row.

- **Optional ``shard_map`` over the RSU axis.**  With a mesh that has an
  ``"rsu"`` axis (R divisible by its size), the cohort stack is sharded
  over it for the whole scan segment: the queue columns are replicated
  (scalar bookkeeping, computed redundantly per device — zero traffic),
  each arrival updates a cohort row on the owning shard only, and ring
  rows leave the shards as one psum per segment.  Between reconciliations
  the cohorts exchange exactly nothing; the reconcile itself is one pmean
  per leaf — the corridor-scale instance of
  ``hierarchical.cross_pod_reconcile``.

Local training is wave-hoisted exactly as in the jit engine (same wave
rule, same shared-payload broadcast fast path, optional ``"data"``-axis
sharding), and the same host dry-run (``corridor.plan``) plans the program
and cross-checks the device trace afterwards — vehicle *and* serving-RSU
divergence raise instead of silently mis-pairing batches or cohorts.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel import ChannelParams, CorridorMobility, slot_gain_table
from repro.core import client as client_mod
from repro.core.client import Vehicle, VehicleData
from repro.core.jit_engine import _mesh_key, _wave_train
from repro.core.server import DEFAULT_FEDASYNC_MIX, RoundRecord
from repro.corridor.plan import CorridorPlan, plan_corridor
from repro.models.cnn import init_cnn

_SUPPORTED_SCHEMES = ("mafl", "afl", "fedasync")
_RSU_AXIS = "rsu"

_PROGRAM_CACHE: OrderedDict = OrderedDict()
_PROGRAM_CACHE_SIZE = 16


def _rsu_shards(mesh, n_rsus: int) -> int:
    """Number of RSU shards the mesh requests (1 = unsharded).  A mesh
    whose ``"rsu"`` axis cannot tile the corridor raises — the caller
    explicitly asked for RSU sharding, and silently running replicated
    would misrepresent the measured scaling/memory behavior."""
    if mesh is None or _RSU_AXIS not in mesh.shape:
        return 1
    n = mesh.shape[_RSU_AXIS]
    if n > 1 and n_rsus % n != 0:
        raise ValueError(
            f"mesh '{_RSU_AXIS}' axis of size {n} cannot shard "
            f"{n_rsus} RSU cohorts (n_rsus must be divisible)")
    return n if n > 1 else 1


def _build_program(plan: CorridorPlan, p: ChannelParams, *, scheme: str,
                   interpretation: str, use_kernel: bool, mesh,
                   reconcile_every: int, reconcile_mode: str,
                   reconcile_tau: float, eval_rounds: tuple,
                   fedasync_mix: float, record_cohorts: bool,
                   flat_layout=None, ring_dtype: str = "f32",
                   metrics=None, l_iters: int = 1):
    """Trace-time constants live in the closure; cached per world structure
    like the jit engine's program.

    ``flat_layout`` selects the packed flat-parameter fast path (DESIGN.md
    §12): the cohort stack becomes one ``f32[R, P]`` buffer, ring rows are
    single ``[P]`` vectors, and aggregation is either the in-scan
    one-vector-op mix (CPU default — bitwise the pytree path on the golden
    worlds) or fused per-RSU ``ring_agg`` chains (``use_kernel`` /
    accelerator backends).  Unsharded only — the ``"rsu"``-mesh path keeps
    the pytree layout."""
    M = len(plan.veh)
    K = p.K
    R = plan.n_rsus
    d = np.asarray(plan.dl_round)
    up_rsu = np.asarray(plan.up_rsu)
    beta = jnp.float32(p.beta)
    gamma = jnp.float32(p.gamma)
    zeta = jnp.float32(p.zeta)
    f_mix = jnp.float32(fedasync_mix)
    tau = jnp.float32(reconcile_tau if reconcile_mode == "ema" else 1.0)
    v_c = jnp.float32(p.v)
    span = jnp.float32(2.0 * p.coverage * R)
    cell = jnp.float32(2.0 * p.coverage)
    centers = jnp.asarray(
        -float(span) / 2 + (np.arange(R) + 0.5) * float(cell), jnp.float32)
    dy2H2 = jnp.float32(p.d_y ** 2 + p.H ** 2)
    pm = jnp.float32(p.p_m)
    alpha_pl = jnp.float32(p.alpha)
    sigma2 = jnp.float32(p.sigma2)
    bw = jnp.float32(p.B)
    bits = jnp.float32(p.model_bits)
    n_slots = plan.n_slots
    n_shards = _rsu_shards(mesh, R)
    Rl = R // n_shards

    # selection (DESIGN.md §11): same fold as the jit engine — a [M, K]
    # static mask table gates every re-schedule (parked slots are +inf in
    # every RSU row), re-admissions run at trace level after the reconcile
    # whose boundary re-scored the fleet, and only the eps-bandit carries
    # f32 reward accumulators through the scan (guard-checked)
    sel_active = plan.sel is not None and not plan.sel.is_noop
    with_state = sel_active and plan.sel.spec.policy == "eps-bandit"

    # faults (DESIGN.md §16): the exact same fold as the jit engine.
    # Suppressed re-schedules AND into the admission table, recovery
    # sweeps merge into the boundary re-admission map (recoveries run at
    # reconcile boundaries, which are already scan-segment splits), the
    # staleness-cap verdicts gate each pop's cohort-row update, and the
    # per-cycle epoch counts feed the masked partial trainer.  flt is
    # None on the off path, so every branch below vanishes and the
    # program is textually the legacy one (rule FLT001).
    from repro.faults import fold_admission, fold_readmits

    flt_plan = plan.flt
    flt_on = flt_plan is not None
    has_partial = flt_on and flt_plan.spec.has_partial
    has_cap = flt_on and flt_plan.spec.has_cap
    adm_active = sel_active or (flt_on and flt_plan.timeline_active)

    # telemetry fold (DESIGN.md §14): every metrics branch below is gated
    # on this *static* flag, so ``metrics=None`` traces a program textually
    # identical to the legacy one (rule TEL001 — bitwise off path)
    met_on = metrics is not None
    if met_on:
        from repro.telemetry import device as tel_dev
        met_edges = jnp.asarray(metrics.edges, jnp.float32)
    if adm_active:
        adm = (np.stack([plan.sel.mask_for_round(r) for r in range(M)])
               if sel_active else np.ones((M, K), bool))
        if flt_on and flt_plan.timeline_active:
            adm = fold_admission(adm, flt_plan, plan.veh)
        adm_tab = jnp.asarray(adm)
        readmit_at = {b: np.asarray(vs, np.int32)
                      for b, vs in fold_readmits(
                          plan.sel if sel_active else None,
                          flt_plan if flt_on else None).items() if len(vs)}
    else:
        readmit_at = {}
    if has_cap:
        keep_tab = jnp.asarray(np.asarray(flt_plan.keep, bool))
    if has_partial:
        ep_tab = jnp.asarray(np.asarray(flt_plan.epochs, np.int32))
    # fault counters (DESIGN.md §16): per-pop i32[4] increments from the
    # fault plan, accumulated in the metrics carry and conformance-checked
    # against the f64 fault replay after the run
    fct_on = met_on and metrics.fault_counters and flt_on
    if fct_on:
        fct_tab = jnp.asarray(flt_plan.counts_table(l_iters))

    if n_shards > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

    def aggregate(g, loc, t, cu, cl, dl_t):
        """One arrival's cohort update — identical math and f32 arithmetic
        to the jit engine / host aggregation paths."""
        if scheme == "mafl":
            weight = gamma ** (cu - 1.0) * zeta ** (cl - 1.0)   # Eqs. 7, 9
        else:
            weight = jnp.float32(1.0)
        if scheme == "mafl" and interpretation == "literal":
            if use_kernel:
                from repro.kernels.weighted_agg import ops as agg_ops
                return agg_ops.weighted_agg_tree(g, loc, beta, weight), weight
            new = jax.tree_util.tree_map(
                lambda a, b: (beta * a.astype(jnp.float32) +
                              (1.0 - beta) * weight *
                              b.astype(jnp.float32)).astype(a.dtype), g, loc)
            return new, weight
        if scheme == "mafl":
            alpha = jnp.clip((1.0 - beta) * weight, 0.0, 1.0)
        elif scheme == "afl":
            alpha = 1.0 - beta
        else:                                                   # fedasync
            stale = jnp.maximum(t - dl_t, 0.0)
            alpha = f_mix * (stale + 1.0) ** (-0.5)
        if use_kernel:
            from repro.kernels.weighted_agg import ops as agg_ops
            return agg_ops.weighted_agg_tree(g, loc, 1.0 - alpha,
                                             jnp.float32(1.0)), weight
        new = jax.tree_util.tree_map(
            lambda a, b: ((1.0 - alpha) * a.astype(jnp.float32) +
                          alpha * b.astype(jnp.float32)).astype(a.dtype),
            g, loc)
        return new, weight

    def stack_mean(G):
        """Mean over the (local) cohort rows, f32 accumulate."""
        return jax.tree_util.tree_map(
            lambda x: jnp.mean(x.astype(jnp.float32), axis=0), G)

    def mix_rows(G, cons):
        """EMA of every row toward ``cons`` (tau=1 → adopt outright);
        ``cons`` arrives in f32 and is cast back to the row dtype."""
        if use_kernel and float(tau) != 1.0:
            from repro.kernels.weighted_agg import ops as agg_ops
            return agg_ops.weighted_agg_tree(
                G, jax.tree_util.tree_map(
                    lambda x, c: jnp.broadcast_to(c.astype(x.dtype),
                                                  x.shape), G, cons),
                1.0 - tau, jnp.float32(1.0))
        return jax.tree_util.tree_map(
            lambda x, c: ((1.0 - tau) * x.astype(jnp.float32) +
                          tau * c[None]).astype(x.dtype), G, cons)

    def serving(x):
        j = jnp.floor((x + span / 2.0) / cell).astype(jnp.int32)
        return jnp.clip(j, 0, R - 1)

    def eq36_upload_delay(gains, x0, idx, t_up):
        """Eq. 3-6 with the corridor geometry: slot gain -> span wrap ->
        serving-cell distance -> SNR -> Shannon rate -> upload delay.
        ``idx`` is a scalar pop or a vector of re-admissions; one
        definition serves the pytree and flat bodies and both readmit
        helpers — its op order is part of the flat-vs-pytree bitwise
        pin, so it must never fork."""
        slot = jnp.clip(t_up.astype(jnp.int32), 0, n_slots - 1)
        gain = gains[slot, idx]
        dx = x0[idx] + v_c * t_up                       # Eq. 3
        x_up = jnp.mod(dx + span / 2.0, span) - span / 2.0
        j_up = serving(x_up)                 # serving cell at upload
        dist = jnp.sqrt((x_up - centers[j_up]) ** 2 + dy2H2)  # Eq. 4
        snr = pm * gain * dist ** (-alpha_pl) / sigma2
        rate = bw * jnp.log2(1.0 + snr)                 # Eq. 5
        return bits / jnp.maximum(rate, 1e-12)          # Eq. 6

    def make_seg_body(locals_buf, gains, x0, qcl, off):
        def wrap_x(i, t):
            dx = x0[i] + v_c * t                                # Eq. 3
            return jnp.mod(dx + span / 2.0, span) - span / 2.0

        # fresh body per scan segment (the lax.scan traced-body cache
        # pitfall, DESIGN.md §9) — and ``off`` is this shard's first RSU
        # row (0 when unsharded)
        def body(carry, r):
            if met_on:
                carry, mst = carry[:-1], carry[-1]
            if with_state:
                G, qt, qdl, qcu, rs, rc = carry
            else:
                G, qt, qdl, qcu = carry
            flat = jnp.argmin(qt)                               # pop
            j = flat // K
            i = flat % K
            t = qt[j, i]
            cu, cl, dl_t = qcu[i], qcl[i], qdl[i]
            if met_on:
                # per-RSU live slots at pop time, before the slot
                # migration writes (matches the f64 replay's pre-pop
                # pending count)
                occ = jnp.sum(jnp.isfinite(qt), axis=1).astype(jnp.int32)
            loc = jax.tree_util.tree_map(lambda B: B[r], locals_buf)
            owned = (j >= off) & (j < off + Rl)
            row = jnp.where(owned, j - off, 0)
            grow = jax.tree_util.tree_map(lambda Gl: Gl[row], G)
            new_row, weight = aggregate(grow, loc, t, cu, cl, dl_t)
            if has_cap:
                # a cap-discarded pop keeps the cohort row exactly (the
                # host skips the update outright); the ring contribution
                # below inherits the unchanged row
                new_row = jax.tree_util.tree_map(
                    lambda old, new: jnp.where(keep_tab[r], new, old),
                    grow, new_row)
            G = jax.tree_util.tree_map(
                lambda Gl, nr: Gl.at[row].set(
                    jnp.where(owned, nr, Gl[row])), G, new_row)
            # this shard's contribution to ring[r+1] (exactly one shard
            # owns the row; psum'd once per segment under the mesh path)
            contrib = jax.tree_util.tree_map(
                lambda nr: jnp.where(owned, nr, jnp.zeros_like(nr)),
                new_row)
            if with_state:
                # bandit reward = the paper's delay weight (Eqs. 7, 9)
                rew = gamma ** (cu - 1.0) * zeta ** (cl - 1.0)
                rs = rs.at[i].add(rew)
                rc = rc.at[i].add(1.0)
            # re-schedule vehicle i: download now, train C_l, upload C_u
            t_up = t + cl
            cu_new = eq36_upload_delay(gains, x0, i, t_up)
            t_new = t_up + cu_new
            j_new = serving(wrap_x(i, t_new))    # handover target
            if adm_active:
                # admission folded into the slot queue: a parked (or
                # dropped / blacked-out) vehicle is +inf in every RSU
                # row, invisible to the argmin
                t_new = jnp.where(adm_tab[r, i], t_new, jnp.inf)
            # slot migration: leave row j, land in row j_new
            qt = qt.at[j, i].set(jnp.inf)
            qt = qt.at[j_new, i].set(t_new)
            qdl = qdl.at[i].set(t)
            qcu = qcu.at[i].set(cu_new)
            out = ((G, qt, qdl, qcu, rs, rc) if with_state
                   else (G, qt, qdl, qcu))
            ys = (i, j, t, cu, cl, dl_t, weight, contrib)
            if met_on:
                # handover = the admitted re-schedule lands on a new RSU
                # (parked vehicles never migrate; readmits are counted by
                # neither the device nor the f64 replay)
                ho = (j_new != j)
                if adm_active:
                    ho = ho & adm_tab[r, i]
                mst, gap = tel_dev.corridor_pop(
                    mst, met_edges, t=t, dl_t=dl_t, j=j, handover=ho,
                    fault_row=fct_tab[r] if fct_on else None)
                out = out + (mst,)
                ys = ys + (occ, gap, ho)
            return out, ys
        return body

    def run_segment(st, locals_buf, gains, x0, qcl, a, b):
        """Consume pops ``a..b-1``; ``st`` is the carried queue/cohort
        state tuple; returns the updated tuple, the stacked ring rows for
        those rounds, and the scalar trace columns."""
        if n_shards == 1:
            body = make_seg_body(locals_buf, gains, x0, qcl, 0)
            with jax.named_scope(f"event_scan_{a}_{b}"):
                carry, ys = jax.lax.scan(body, st, jnp.arange(a, b))
            return carry, ys[7], ys[:7] + ys[8:]

        def seg_fn(st, locals_buf, gains, x0, qcl):
            off = jax.lax.axis_index(_RSU_AXIS) * Rl
            body = make_seg_body(locals_buf, gains, x0, qcl, off)
            with jax.named_scope(f"event_scan_{a}_{b}"):
                carry, ys = jax.lax.scan(body, st, jnp.arange(a, b))
            rows = jax.tree_util.tree_map(
                lambda x: jax.lax.psum(x, _RSU_AXIS), ys[7])
            return carry, rows, ys[:7] + ys[8:]

        # cohort stack sharded over the RSU axis; queue columns (and the
        # bandit accumulators, when carried) replicated
        st_spec = (P(_RSU_AXIS),) + (P(),) * (len(st) - 1)
        fn = shard_map(
            seg_fn, mesh=mesh,
            in_specs=(st_spec, P(), P(), P(), P()),
            out_specs=(st_spec, P(), P()),
            check_rep=False)
        return fn(st, locals_buf, gains, x0, qcl)

    def reconcile(G):
        """The cloud tier: FedAvg/EMA of the R cohorts; the only step that
        touches more than one cohort (one pmean per leaf when sharded)."""
        if n_shards == 1:
            return mix_rows(G, stack_mean(G))

        def rec_fn(G):
            cons = jax.tree_util.tree_map(
                lambda x: jax.lax.pmean(x, _RSU_AXIS), stack_mean(G))
            return mix_rows(G, cons)

        return shard_map(rec_fn, mesh=mesh, in_specs=(P(_RSU_AXIS),),
                         out_specs=P(_RSU_AXIS), check_rep=False)(G)

    def consensus(G):
        """Corridor-wide model (mean of cohorts) for eval/final params."""
        if n_shards == 1:
            return jax.tree_util.tree_map(
                lambda x, g: x.astype(g.dtype), stack_mean(G),
                jax.tree_util.tree_map(lambda g: g[0], G))

        def cons_fn(G):
            return jax.tree_util.tree_map(
                lambda x: jax.lax.pmean(x, _RSU_AXIS), stack_mean(G))

        cons = shard_map(cons_fn, mesh=mesh, in_specs=(P(_RSU_AXIS),),
                         out_specs=P(), check_rep=False)(G)
        return jax.tree_util.tree_map(
            lambda x, g: x.astype(g.dtype), cons,
            jax.tree_util.tree_map(lambda g: g[0], G))

    def cohort_row(G, j: int):
        """Row ``j`` of the (possibly sharded) cohort stack, replicated."""
        if n_shards == 1:
            return jax.tree_util.tree_map(lambda x: x[j], G)

        def pick(G):
            mine = jax.lax.axis_index(_RSU_AXIS) == j // Rl
            return jax.tree_util.tree_map(
                lambda x: jax.lax.psum(
                    jnp.where(mine, x[j % Rl], jnp.zeros_like(x[j % Rl])),
                    _RSU_AXIS), G)

        return shard_map(pick, mesh=mesh, in_specs=(P(_RSU_AXIS),),
                         out_specs=P(), check_rep=False)(G)

    def gather_cohorts(G):
        """Full [R, ...] stack on every device (cohort snapshots only)."""
        if n_shards == 1:
            return G

        def allg(G):
            return jax.tree_util.tree_map(
                lambda x: jax.lax.all_gather(x, _RSU_AXIS, tiled=True), G)

        return shard_map(allg, mesh=mesh, in_specs=(P(_RSU_AXIS),),
                         out_specs=P(), check_rep=False)(G)

    eval_set = set(eval_rounds)
    reconcile_set = {b for b in range(reconcile_every, M + 1,
                                      reconcile_every)}

    if flat_layout is not None:
        from repro.core.aggregation import chain_coeffs
        from repro.core.jit_engine import _ring_interpret
        from repro.corridor.plan import rsu_chain_groups
        from repro.kernels.weighted_agg import ops as agg_ops

        assert n_shards == 1, \
            "flat fast path is unsharded (mesh 'rsu' axis keeps pytrees)"
        layout = flat_layout
        bf16 = ring_dtype == "bf16"
        store_dtype = jnp.bfloat16 if bf16 else jnp.float32
        store = ((lambda x: x.astype(jnp.bfloat16)) if bf16
                 else (lambda x: x))
        ring_interp = _ring_interpret(use_kernel)
        fused_chain = use_kernel or jax.default_backend() != "cpu"
        # ring rows later waves read (payload rounds); evals read the
        # consensus, never the ring
        needed = set()
        for T, _s, _e in plan.waves:
            needed |= {int(d[t]) + 1 for t in T if d[t] >= 0}

        def program_flat(w0, gains, x0, qt, qdl, qcu, qcl, imgs, labs, lr):
            local_scan = (client_mod._local_scan_partial if has_partial
                          else client_mod._local_scan)
            G = jnp.broadcast_to(layout.pack(w0)[None],
                                 (R, layout.P)).astype(jnp.float32)
            locals_buf = jnp.zeros((M, layout.P), store_dtype)
            mst = ring_stats = None
            store_row = store
            if met_on:
                mst = tel_dev.corridor_state(metrics)
                if metrics.ring_guard and bf16:
                    ring_stats = tel_dev.RingStats()
                    store_row = ring_stats.wrap(store)
            ring = [store_row(layout.pack(w0))] + [None] * M
            cons_snaps, cohort_snaps, traces, met_traces = [], [], [], []
            rs = rc = None
            if with_state:
                rs = jnp.zeros(K, jnp.float32)
                rc = jnp.zeros(K, jnp.float32)

            def make_flat_body(locals_buf):
                # same pop / slot-migration / re-schedule arithmetic as
                # the pytree body; in fused mode the cohort stack leaves
                # the carry and aggregation streams per-RSU afterwards
                # (fresh body per segment — locals_buf rebinds per wave)
                def body(carry, r):
                    if met_on:
                        carry, mst = carry[:-1], carry[-1]
                    if fused_chain:
                        G = None
                        if with_state:
                            qt, qdl, qcu, rs, rc = carry
                        else:
                            qt, qdl, qcu = carry
                    elif with_state:
                        G, qt, qdl, qcu, rs, rc = carry
                    else:
                        G, qt, qdl, qcu = carry
                    flat = jnp.argmin(qt)                       # pop
                    j = flat // K
                    i = flat % K
                    t = qt[j, i]
                    cu, cl, dl_t = qcu[i], qcl[i], qdl[i]
                    if met_on:
                        occ = jnp.sum(jnp.isfinite(qt),
                                      axis=1).astype(jnp.int32)
                    if fused_chain:
                        if scheme == "mafl":
                            weight = gamma ** (cu - 1.0) * zeta ** (cl - 1.0)
                        else:
                            weight = jnp.float32(1.0)
                        new_row = None
                    else:
                        grow = G[j]
                        new_row, weight = aggregate(grow, locals_buf[r], t,
                                                    cu, cl, dl_t)
                        if has_cap:
                            # cap-discarded pop: the cohort row (and the
                            # ring row reading it) stays exactly put
                            new_row = jnp.where(keep_tab[r], new_row, grow)
                        G = G.at[j].set(new_row)
                    if with_state:
                        rew = gamma ** (cu - 1.0) * zeta ** (cl - 1.0)
                        rs = rs.at[i].add(rew)
                        rc = rc.at[i].add(1.0)
                    t_up = t + cl
                    cu_new = eq36_upload_delay(gains, x0, i, t_up)
                    t_new = t_up + cu_new
                    x_new = jnp.mod(x0[i] + v_c * t_new + span / 2.0,
                                    span) - span / 2.0
                    j_new = serving(x_new)              # handover target
                    if adm_active:
                        t_new = jnp.where(adm_tab[r, i], t_new, jnp.inf)
                    qt = qt.at[j, i].set(jnp.inf)
                    qt = qt.at[j_new, i].set(t_new)
                    qdl = qdl.at[i].set(t)
                    qcu = qcu.at[i].set(cu_new)
                    if fused_chain:
                        out = ((qt, qdl, qcu, rs, rc) if with_state
                               else (qt, qdl, qcu))
                        ys = (i, j, t, cu, cl, dl_t, weight)
                    else:
                        out = ((G, qt, qdl, qcu, rs, rc) if with_state
                               else (G, qt, qdl, qcu))
                        ys = (i, j, t, cu, cl, dl_t, weight, new_row)
                    if met_on:
                        ho = (j_new != j)
                        if adm_active:
                            ho = ho & adm_tab[r, i]
                        mst, gap = tel_dev.corridor_pop(
                            mst, met_edges, t=t, dl_t=dl_t, j=j, handover=ho,
                            fault_row=fct_tab[r] if fct_on else None)
                        out = out + (mst,)
                        ys = ys + (occ, gap, ho)
                    return out, ys
                return body

            def readmit(qt, qdl, qcu, A, t_b):
                A = jnp.asarray(A)
                t_up = t_b + qcl[A]
                cu_new = eq36_upload_delay(gains, x0, A, t_up)
                t_new = t_up + cu_new
                x_new = jnp.mod(x0[A] + v_c * t_new + span / 2.0,
                                span) - span / 2.0
                j_new = serving(x_new)
                return (qt.at[j_new, A].set(t_new), qdl.at[A].set(t_b),
                        qcu.at[A].set(cu_new))

            for T, s, e in plan.waves:
                T = np.asarray(T, np.int32)
                if len(T):
                    pay_rounds = [int(x) for x in d[T] + 1]
                    shared = all(pr == pay_rounds[0] for pr in pay_rounds)
                    if shared:
                        pay = layout.unpack(ring[pay_rounds[0]])
                    else:
                        pay = layout.unpack(jnp.stack(
                            [ring[pr] for pr in pay_rounds]))
                    train = _wave_train(local_scan, mesh, len(T), shared,
                                        partial=has_partial)
                    extra = (ep_tab[jnp.asarray(T)],) if has_partial else ()
                    with jax.named_scope(f"wave_train_{s}"):
                        loc, _ = train(pay, imgs[T], labs[T], lr, *extra)
                    locals_buf = locals_buf.at[jnp.asarray(T)].set(
                        layout.pack(loc, dtype=store_dtype))
                points = sorted({b for b in range(s + 1, e + 1)
                                 if b in eval_set or b in reconcile_set
                                 or b in readmit_at}
                                | {e})
                a = s
                for b in points:
                    if b > a:
                        if fused_chain:
                            st = ((qt, qdl, qcu, rs, rc) if with_state
                                  else (qt, qdl, qcu))
                        else:
                            st = ((G, qt, qdl, qcu, rs, rc) if with_state
                                  else (G, qt, qdl, qcu))
                        if met_on:
                            st = st + (mst,)
                        with jax.named_scope(f"event_scan_{a}_{b}"):
                            st, ys = jax.lax.scan(
                                make_flat_body(locals_buf),
                                st, jnp.arange(a, b))
                        if met_on:
                            st, mst = st[:-1], st[-1]
                            met_traces.append(ys[-3:])
                        if fused_chain:
                            if with_state:
                                qt, qdl, qcu, rs, rc = st
                            else:
                                qt, qdl, qcu = st
                        elif with_state:
                            G, qt, qdl, qcu, rs, rc = st
                        else:
                            G, qt, qdl, qcu = st
                        traces.append(ys[:7])
                        if fused_chain:
                            # per-RSU streaming chains (DESIGN.md §12):
                            # coefficients from the segment's own f32
                            # trace, one ring_agg per checkpoint chunk
                            cc, dd = chain_coeffs(
                                scheme, interpretation, p.beta, ys[6],
                                t=ys[2], dl_t=ys[5],
                                fedasync_mix=fedasync_mix)
                            if has_cap:
                                # cap-discarded pops become exact no-ops
                                keep_seg = keep_tab[a:b]
                                cc = jnp.where(keep_seg, cc, 1.0)
                                dd = jnp.where(keep_seg, dd, 0.0)
                            coeffs = jnp.stack([cc, dd], axis=1)
                            for jr, chunks in rsu_chain_groups(
                                    plan, a, b, needed):
                                g_j = G[jr]
                                for chunk in chunks:
                                    idx = np.asarray(chunk)
                                    g_j = agg_ops.ring_agg(
                                        g_j, locals_buf[jnp.asarray(idx)],
                                        coeffs[jnp.asarray(idx - a)],
                                        interpret=ring_interp)
                                    last = chunk[-1] + 1
                                    if last in needed:
                                        ring[last] = store_row(g_j)
                                G = G.at[jr].set(g_j)
                        else:
                            rows = ys[7]
                            for r in range(a, b):
                                ring[r + 1] = store_row(rows[r - a])
                    if b in reconcile_set:
                        G = mix_rows(G, stack_mean(G))
                        ring[b] = store_row(G[int(up_rsu[b - 1])])
                    if b in readmit_at:
                        qt, qdl, qcu = readmit(qt, qdl, qcu, readmit_at[b],
                                               traces[-1][2][-1])
                    if b in eval_set:
                        cons_snaps.append(layout.unpack(
                            jnp.mean(G, axis=0)))
                        if record_cohorts:
                            cohort_snaps.append(layout.unpack(G))
                    a = b

            trace = tuple(jnp.concatenate([tr[k] for tr in traces])
                          for k in range(7))
            ret = (layout.unpack(G), cons_snaps, cohort_snaps, trace)
            if with_state:
                ret = ret + ((rs, rc),)
            if met_on:
                met_out = {
                    "stale_hist": mst[0],
                    "handover_count": mst[2],
                    "occupancy": jnp.concatenate(
                        [m[0] for m in met_traces]),
                    "gap": jnp.concatenate([m[1] for m in met_traces]),
                    "handover": jnp.concatenate(
                        [m[2] for m in met_traces]),
                }
                if fct_on:
                    met_out["fault_counts"] = mst[3]
                if ring_stats is not None:
                    met_out.update(ring_stats.out())
                ret = ret + (met_out,)
            return ret

        return jax.jit(program_flat)

    def program(w0, gains, x0, qt, qdl, qcu, qcl, imgs, labs, lr):
        local_scan = (client_mod._local_scan_partial if has_partial
                      else client_mod._local_scan)
        G = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (R,) + x.shape), w0)
        if n_shards > 1:
            G = jax.lax.with_sharding_constraint(
                G, jax.sharding.NamedSharding(mesh, P(_RSU_AXIS)))
        locals_buf = jax.tree_util.tree_map(
            lambda x: jnp.zeros((M,) + x.shape, x.dtype), w0)
        ring = [w0] + [None] * M       # one model per round (see header)
        cons_snaps, cohort_snaps, traces = [], [], []
        mst = tel_dev.corridor_state(metrics) if met_on else None
        rs = rc = None
        if with_state:
            rs = jnp.zeros(K, jnp.float32)
            rc = jnp.zeros(K, jnp.float32)

        def readmit(qt, qdl, qcu, A, t_b):
            """Boundary re-admission (post-reconcile): schedule vehicles
            ``A`` (static) at the traced boundary timestamp — the same
            Eq. 3-6 pipeline as the in-scan re-schedule, with the slot
            landing in the row of the RSU serving each vehicle at its new
            arrival time."""
            A = jnp.asarray(A)
            t_up = t_b + qcl[A]
            cu_new = eq36_upload_delay(gains, x0, A, t_up)
            t_new = t_up + cu_new
            x_new = jnp.mod(x0[A] + v_c * t_new + span / 2.0,
                            span) - span / 2.0
            j_new = serving(x_new)
            return (qt.at[j_new, A].set(t_new), qdl.at[A].set(t_b),
                    qcu.at[A].set(cu_new))

        for T, s, e in plan.waves:
            T = np.asarray(T, np.int32)
            if len(T):
                pay_rounds = [int(x) for x in d[T] + 1]
                shared = all(pr == pay_rounds[0] for pr in pay_rounds)
                if shared:
                    pay = ring[pay_rounds[0]]
                else:
                    pay = jax.tree_util.tree_map(
                        lambda *xs: jnp.stack(xs),
                        *[ring[pr] for pr in pay_rounds])
                train = _wave_train(local_scan, mesh, len(T), shared,
                                    partial=has_partial)
                extra = (ep_tab[jnp.asarray(T)],) if has_partial else ()
                with jax.named_scope(f"wave_train_{s}"):
                    loc, _ = train(pay, imgs[T], labs[T], lr, *extra)
                T_dev = jnp.asarray(T)
                locals_buf = jax.tree_util.tree_map(
                    lambda B, L: B.at[T_dev].set(L), locals_buf, loc)
            # sub-split [s, e) at reconcile/eval boundaries, which are
            # static — the reconcile and the consensus snapshot run at
            # trace level *between* scans (no collective under lax.cond)
            points = sorted({b for b in range(s + 1, e + 1)
                             if b in eval_set or b in reconcile_set
                             or b in readmit_at}
                            | {e})
            a = s
            for b in points:
                if b > a:
                    st = ((G, qt, qdl, qcu, rs, rc) if with_state
                          else (G, qt, qdl, qcu))
                    if met_on:
                        st = st + (mst,)
                    st, rows, ys = run_segment(
                        st, locals_buf, gains, x0, qcl, a, b)
                    if met_on:
                        st, mst = st[:-1], st[-1]
                    if with_state:
                        G, qt, qdl, qcu, rs, rc = st
                    else:
                        G, qt, qdl, qcu = st
                    traces.append(ys)
                    for r in range(a, b):
                        ring[r + 1] = jax.tree_util.tree_map(
                            lambda x, i=r - a: x[i], rows)
                if b in reconcile_set:
                    G = reconcile(G)
                    # the boundary round's re-download happens *after* the
                    # reconcile (serial reference order) — its ring row is
                    # the reconciled cohort the upload landed on
                    ring[b] = cohort_row(G, int(up_rsu[b - 1]))
                if b in readmit_at:
                    # the boundary re-scored the fleet (fedavg-only, so
                    # every re-admitted download reads the reconciled
                    # ring[b] regardless of serving RSU); t_b = the
                    # boundary pop's timestamp
                    qt, qdl, qcu = readmit(qt, qdl, qcu, readmit_at[b],
                                           traces[-1][2][-1])
                if b in eval_set:
                    cons_snaps.append(consensus(G))
                    if record_cohorts:
                        cohort_snaps.append(gather_cohorts(G))
                a = b

        trace = tuple(jnp.concatenate([tr[k] for tr in traces])
                      for k in range(7))
        ret = (gather_cohorts(G), cons_snaps, cohort_snaps, trace)
        if with_state:
            ret = ret + ((rs, rc),)
        if met_on:
            met_out = {
                "stale_hist": mst[0],
                "handover_count": mst[2],
                "occupancy": jnp.concatenate([tr[7] for tr in traces]),
                "gap": jnp.concatenate([tr[8] for tr in traces]),
                "handover": jnp.concatenate([tr[9] for tr in traces]),
            }
            if fct_on:
                met_out["fault_counts"] = mst[3]
            ret = ret + (met_out,)
        return ret

    return jax.jit(program)


# ---------------------------------------------------------------------------
# public entry point — signature mirrors corridor.reference
# ---------------------------------------------------------------------------
def run_corridor_simulation(
    sc,
    vehicles_data: Sequence[VehicleData],
    test_images: np.ndarray,
    test_labels: np.ndarray,
    p: Optional[ChannelParams] = None,
    *,
    seed: int = 0,
    eval_every: int = 10,
    interpretation: str = "mixing",
    use_kernel: bool = False,
    progress=None,
    batch_size: int = 128,
    mesh=None,
    record_cohorts: bool = False,
    init_params=None,
    selection=None,
    flat: Optional[bool] = None,
    metrics=None,
    faults=None,
):
    """Run ``sc.rounds`` corridor arrivals entirely on device; returns the
    same ``SimResult`` the serial reference produces (same record fields,
    same eval cadence, per-RSU round numbering, ``rec.rsu`` set).

    ``flat=None`` auto-selects the packed flat-parameter fast path
    (DESIGN.md §12) whenever the run is unsharded; an ``"rsu"``-sharded
    mesh keeps the pytree layout (explicitly requesting both raises).
    ``sc.ring_dtype="bf16"`` (flat only) stores ring rows and upload
    buffers in bf16 around the f32 cohort stack.

    ``result.extras`` carries the corridor-specific outputs: the per-round
    serving-RSU trace, the final cohort stack, and (``record_cohorts=True``)
    per-eval-round cohort snapshots for per-RSU accuracy curves.  As with
    the jit engine, ``progress`` fires post-hoc in round order.

    ``metrics="on"`` folds device-resident telemetry into the scan
    (DESIGN.md §14): per-RSU staleness histograms, per-RSU occupancy,
    handover counters, and pop-wait traces accumulate in fixed-shape carry
    state, surfaced on ``result.report.channels``.  Any falsy value stages
    the *exact* legacy program (same cache entry, bitwise-identical
    outputs, rule TEL001).

    ``faults`` activates the fault-injection layer (DESIGN.md §16): the
    host f64 planner samples the stochastic client-state processes into
    static per-round tables folded into the compiled program exactly like
    selection — identical decisions on every engine, conformance-checked
    against the f64 replay.  Recovery sweeps run at reconcile boundaries;
    availability faults require ``reconcile_mode='fedavg'``.  Off is the
    exact legacy program (rule FLT001)."""
    from repro.core.mafl import SimResult, evaluate
    from repro.telemetry import RunReport, memory_stats
    from repro.telemetry.report import wave_stats
    from repro.telemetry.timers import PhaseTimers

    timers = PhaseTimers()
    prog, args, plan, layout, eval_rounds, with_state, met = _stage_run(
        sc, vehicles_data, p, seed=seed, eval_every=eval_every,
        interpretation=interpretation, use_kernel=use_kernel,
        batch_size=batch_size, mesh=mesh, record_cohorts=record_cohorts,
        init_params=init_params, selection=selection, flat=flat,
        metrics=metrics, faults=faults, timers=timers)
    p = p if p is not None else sc.channel()
    scheme = sc.scheme
    R = sc.n_rsus
    M = sc.rounds
    ring_dtype = getattr(sc, "ring_dtype", "f32")
    flat = layout is not None
    with timers.phase("run"):
        out = jax.block_until_ready(prog(*args))
    met_dev = None
    if met is not None:
        out, met_dev = out[:-1], out[-1]
    if with_state:
        G, cons_snaps, cohort_snaps, trace, (dev_rs, dev_rc) = out
    else:
        G, cons_snaps, cohort_snaps, trace = out
    t_veh, t_rsu, t_time, t_cu, t_cl, t_dlt, t_w = (
        np.asarray(x) for x in trace)

    # divergence guard (mirrors the jit engine): the minibatch stacks and
    # the cohort/ring pairing were planned on the host — if the device pop
    # order or serving-cell assignment ever disagreed, fail loudly
    if not np.array_equal(t_veh, plan.veh):
        bad = int(np.argmax(t_veh != plan.veh))
        raise RuntimeError(
            "corridor engine: device pop order diverged from the host dry "
            f"run at round {bad} (device vehicle {int(t_veh[bad])}, host "
            f"{int(plan.veh[bad])}) — f32 time ties are not expected")
    if not np.array_equal(t_rsu, plan.up_rsu):
        bad = int(np.argmax(t_rsu != plan.up_rsu))
        raise RuntimeError(
            "corridor engine: device serving-RSU assignment diverged from "
            f"the host dry run at round {bad} (device RSU {int(t_rsu[bad])},"
            f" host {int(plan.up_rsu[bad])}) — an f32 boundary flip is not "
            "expected")
    if not np.allclose(t_time, plan.times, rtol=1e-4, atol=1e-3):
        bad = int(np.argmax(~np.isclose(t_time, plan.times,
                                        rtol=1e-4, atol=1e-3)))
        raise RuntimeError(
            "corridor engine: device event times diverged from the host "
            f"dry run at round {bad}: {t_time[bad]} vs {plan.times[bad]}")
    if with_state:
        # selection divergence guard (DESIGN.md §11): the carried f32
        # reward accumulators must reproduce the host f64 replay the
        # admission masks were planned from
        exp_rs, exp_rc = plan.sel_bandit
        if not np.array_equal(np.asarray(dev_rc), exp_rc):
            raise RuntimeError(
                "corridor engine: device bandit arrival counts diverged "
                "from the host selection replay")
        if not np.allclose(np.asarray(dev_rs), exp_rs,
                           rtol=1e-4, atol=1e-3):
            raise RuntimeError(
                "corridor engine: device bandit reward accumulators "
                "diverged from the host selection replay")

    if flat and ring_dtype == "bf16":
        # bf16 divergence guard (DESIGN.md §12): the trace guards above
        # keep the timeline exact; a non-finite cohort stack means the
        # quantized ring diverged — fail loudly
        if not all(bool(jnp.isfinite(x).all())
                   for x in jax.tree_util.tree_leaves(G)):
            raise RuntimeError(
                "corridor engine: non-finite cohort stack under "
                "ring_dtype='bf16' — the quantized snapshot ring diverged "
                "(rerun with ring_dtype='f32' to bisect)")
    result = SimResult(scheme=f"{scheme}+corridor", rounds=[],
                       acc_history=[], loss_history=[])
    per_rsu_round = np.zeros(R, np.int64)
    eval_idx = {rr: k for k, rr in enumerate(eval_rounds)}
    with timers.phase("eval"):
        for r in range(M):
            j = int(t_rsu[r])
            per_rsu_round[j] += 1
            rec = RoundRecord(round=int(per_rsu_round[j]),
                              time=float(t_time[r]), vehicle=int(t_veh[r]),
                              upload_delay=float(t_cu[r]),
                              train_delay=float(t_cl[r]),
                              weight=float(t_w[r]), rsu=j)
            rr = r + 1
            if rr in eval_idx:
                acc, loss = evaluate(cons_snaps[eval_idx[rr]], test_images,
                                     test_labels)
                rec.accuracy, rec.loss = acc, loss
                result.acc_history.append((rr, acc))
                result.loss_history.append((rr, loss))
                if progress:
                    progress(rr, acc)
            result.rounds.append(rec)
    result.final_params = cons_snaps[eval_idx[M]]
    result.extras = {
        "n_rsus": R,
        "up_rsu": t_rsu,
        "eval_rounds": list(eval_rounds),
        "final_cohorts": G,
    }
    if record_cohorts:
        result.extras["cohort_snapshots"] = cohort_snaps
    sel_summary = None if plan.sel is None else plan.sel.summary()
    flt_plan = plan.flt
    flt_report = None
    if flt_plan is not None:
        import dataclasses
        flt_report = {"spec": dataclasses.asdict(flt_plan.spec),
                      "counts": flt_plan.counts(sc.l_iters)}
        result.extras["faults"] = flt_plan.summary(sc.l_iters)
    channels = {}
    if met is not None:
        channels = {k: np.asarray(v) for k, v in met_dev.items()}
        if "fault_counts" in channels:
            # fault-counter divergence guard (DESIGN.md §16): the carried
            # i32[4] accumulator must reproduce the f64 fault replay the
            # counts table was planned from
            exp = flt_plan.counts_table(sc.l_iters).sum(axis=0)
            if not np.array_equal(channels["fault_counts"], exp):
                raise RuntimeError(
                    "corridor engine: device fault counters diverged from "
                    f"the host fault replay ({channels['fault_counts']} vs "
                    f"{exp})")
        # per-arrival quality signal (Eqs. 7, 9 delay weight) — the
        # bandit-style reward trace, published for every scheme
        channels["reward"] = (p.gamma ** (t_cu.astype(np.float64) - 1.0)
                              * p.zeta ** (t_cl.astype(np.float64) - 1.0))
        if with_state:
            channels["reward_sum"] = np.asarray(dev_rs)
            channels["reward_count"] = np.asarray(dev_rc)
    result.report = RunReport(
        engine="corridor", scheme=f"{scheme}+corridor", rounds=M,
        seed=seed, metrics_on=met is not None,
        spec=None if met is None else met.to_json(),
        phases=timers.snapshot(), memory=memory_stats(),
        selection=sel_summary, faults=flt_report,
        waves=wave_stats(plan.waves, p.K), channels=channels)
    return result


def _stage_run(sc, vehicles_data, p=None, *, seed, eval_every,
               interpretation, use_kernel, batch_size, mesh, record_cohorts,
               init_params, selection, flat, metrics=None, faults=None,
               timers=None):
    """Validate, plan, and stage one corridor run — everything up to (but
    not including) executing the compiled program.  Split out of
    :func:`run_corridor_simulation` so ``repro.check.dtype_flow`` can build
    the jaxpr of the exact program the engine would run.

    Returns ``(prog, args, plan, layout, eval_rounds, with_state, met)``
    where ``prog(*args)`` is the staged round loop and ``met`` is the
    resolved :class:`MetricsSpec` (None on the exact legacy off path)."""
    from repro.telemetry.spec import resolve_metrics
    from repro.telemetry.timers import PhaseTimers

    timers = timers if timers is not None else PhaseTimers()
    scheme = sc.scheme
    if scheme not in _SUPPORTED_SCHEMES:
        raise ValueError(
            f"engine='corridor' supports schemes {_SUPPORTED_SCHEMES}, not "
            f"{scheme!r} (fedbuff keeps host-side buffer state — use "
            "engine='serial')")
    mode = getattr(sc, "reconcile_mode", "fedavg")
    if mode not in ("fedavg", "ema"):
        raise ValueError(f"unknown reconcile_mode {mode!r}; "
                         "expected 'fedavg' or 'ema'")
    from repro.selection import check_reconcile_mode, scenario_spec
    spec = selection if selection is not None else scenario_spec(sc)
    check_reconcile_mode(spec, mode)
    from repro.faults import check_faults_reconcile
    check_faults_reconcile(faults, mode)
    p = p if p is not None else sc.channel()
    assert len(vehicles_data) == p.K, (len(vehicles_data), p.K)
    rounds = sc.rounds
    if rounds < 1:
        raise ValueError("rounds must be >= 1")
    R = sc.n_rsus
    entry = getattr(sc, "corridor_entry", "uniform")
    ring_dtype = getattr(sc, "ring_dtype", "f32")
    if ring_dtype not in ("f32", "bf16"):
        raise ValueError(f"unknown ring_dtype {ring_dtype!r}; "
                         "expected 'f32' or 'bf16'")
    sharded = _rsu_shards(mesh, R) > 1
    if flat is None:
        flat = not sharded
    elif flat and sharded:
        raise ValueError(
            "flat fast path does not run under an 'rsu'-sharded mesh — "
            "the sharded cohort stack keeps the pytree layout (pass "
            "flat=False or drop the mesh)")
    if ring_dtype == "bf16" and not flat:
        raise ValueError("ring_dtype='bf16' requires the flat fast path "
                         "(unsharded corridor): only the packed ring "
                         "stores bf16 snapshots around the f32 stack")

    with timers.phase("plan"):
        plan = plan_corridor(p, R, seed, rounds, entry=entry,
                             selection=spec,
                             reconcile_every=sc.reconcile_every,
                             faults=faults, l_iters=sc.l_iters)
        met = resolve_metrics(
            metrics, stale=plan.times - plan.download_time,
            times=plan.times, n_rsus=R,
            ring_guard=(ring_dtype == "bf16"),
            fault_counters=plan.flt is not None)
    _t0 = time.perf_counter()
    M = rounds
    eval_rounds = tuple(sorted({rr for rr in range(1, M + 1)
                                if rr % eval_every == 0} | {M}))

    key = jax.random.PRNGKey(seed)
    w0 = init_params if init_params is not None else init_cnn(key)

    # one minibatch stack per consumed round, drawn from the same
    # per-vehicle RNG streams in the same pop order as the serial
    # reference, so both engines train identical batches
    fleet_batch = min(batch_size, min(d.size for d in vehicles_data))
    clients = [Vehicle(d, lr=sc.lr, batch_size=fleet_batch, seed=seed)
               for d in vehicles_data]
    im_list, lab_list = [], []
    for r in range(M):
        im, lab = clients[plan.veh[r]].sample_batches(sc.l_iters)
        im_list.append(im)
        lab_list.append(lab)
    imgs = jnp.asarray(np.stack(im_list))
    labs = jnp.asarray(np.stack(lab_list))

    gains = jnp.asarray(slot_gain_table(p, seed, plan.n_slots), jnp.float32)
    x0 = jnp.asarray(CorridorMobility(p, R, entry=entry).x0, jnp.float32)
    qt0 = np.full((R, p.K), np.inf, np.float32)
    qt0[plan.row0, np.arange(p.K)] = plan.q0["time"]
    qt = jnp.asarray(qt0)
    qdl = jnp.asarray(plan.q0["download_time"], jnp.float32)
    qcu = jnp.asarray(plan.q0["upload_delay"], jnp.float32)
    qcl = jnp.asarray(plan.q0["train_delay"], jnp.float32)

    from repro.core.flat import ParamLayout
    layout = ParamLayout.from_tree(w0) if flat else None
    shapes = (imgs.shape, tuple(
        (str(path), v.shape, str(v.dtype))
        for path, v in jax.tree_util.tree_leaves_with_path(w0)))
    cache_key = (plan.waves, tuple(plan.dl_round.tolist()),
                 tuple(plan.up_rsu.tolist()), plan.n_slots, R, p, scheme,
                 interpretation, use_kernel, mode,
                 float(getattr(sc, "reconcile_tau", 0.5)),
                 sc.reconcile_every, eval_rounds, record_cohorts,
                 _mesh_key(mesh), shapes,
                 None if plan.sel is None else plan.sel.signature(),
                 client_mod._local_scan,
                 None if layout is None else layout.signature(), ring_dtype,
                 None if met is None else met.signature(),
                 None if plan.flt is None else
                 (plan.flt.signature(), sc.l_iters,
                  client_mod._local_scan_partial))
    prog = _PROGRAM_CACHE.get(cache_key)
    if prog is None:
        prog = _build_program(
            plan, p, scheme=scheme, interpretation=interpretation,
            use_kernel=use_kernel, mesh=mesh,
            reconcile_every=sc.reconcile_every, reconcile_mode=mode,
            reconcile_tau=float(getattr(sc, "reconcile_tau", 0.5)),
            eval_rounds=eval_rounds, fedasync_mix=DEFAULT_FEDASYNC_MIX,
            record_cohorts=record_cohorts, flat_layout=layout,
            ring_dtype=ring_dtype, metrics=met, l_iters=sc.l_iters)
        _PROGRAM_CACHE[cache_key] = prog
        while len(_PROGRAM_CACHE) > _PROGRAM_CACHE_SIZE:
            _PROGRAM_CACHE.popitem(last=False)
    else:
        _PROGRAM_CACHE.move_to_end(cache_key)

    with_state = (plan.sel is not None and not plan.sel.is_noop
                  and plan.sel.spec.policy == "eps-bandit")
    args = (w0, gains, x0, qt, qdl, qcu, qcl, imgs, labs,
            jnp.float32(sc.lr))
    timers.add("stage", time.perf_counter() - _t0)
    return prog, args, plan, layout, eval_rounds, with_state, met

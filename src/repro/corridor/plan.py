"""Host dry-run planner for the corridor engine (DESIGN.md §10).

The event timeline depends only on the channel/mobility/data-size processes,
never on training (DESIGN.md §3) — with the corridor's serving-cell geometry
substituted for the single-RSU distance, the same payload-free f64 dry run
that plans the mega-fleet engine also plans the corridor: pop order, each
pop's serving RSU, the wave partition, the gain-table height, and the
initial per-RSU slot placement all come out of one cheap host replay of the
serial reference's scheduling rules.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.channel import ChannelParams, CorridorMobility


@dataclass
class CorridorPlan:
    """Everything the compiled corridor program needs that training cannot
    change.  All times are host-reference f64; the device re-derives them in
    f32 and the engine cross-checks the trace (divergence guard)."""
    n_rsus: int
    veh: np.ndarray             # i32[M] vehicle popped at round r
    cycle: np.ndarray           # i32[M] that vehicle's upload cycle
    dl_round: np.ndarray        # i32[M] round after which it downloaded (-1 = initial)
    up_rsu: np.ndarray          # i32[M] serving RSU at arrival (= handover target,
                                #        = the RSU its re-download reads from)
    times: np.ndarray           # f64[M] host-reference pop times
    train_delay: np.ndarray     # f64[M]
    upload_delay: np.ndarray    # f64[M]
    download_time: np.ndarray   # f64[M]
    waves: tuple                # ((train_rounds, seg_start, seg_end), ...)
    n_slots: int                # gain-table height
    q0: dict                    # initial per-vehicle slot arrays (by vehicle)
    row0: np.ndarray            # i32[K] initial RSU row of each vehicle's slot


def plan_corridor(p: ChannelParams, n_rsus: int, seed: int, rounds: int,
                  entry: str = "uniform") -> CorridorPlan:
    """Dry-run ``rounds`` arrivals through the corridor timeline (no
    payloads, no training) and derive everything static."""
    from repro.core.mafl import _Timeline

    corridor = CorridorMobility(p, n_rsus, entry=entry)
    tl = _Timeline(p, seed, distance_fn=corridor.distance)
    for k in range(p.K):
        tl.schedule(k, 0.0)

    ev0 = tl.queue.as_struct_arrays()
    assert len(np.unique(ev0["vehicle"])) == p.K, \
        "slot queue invariant: one in-flight upload per vehicle"
    order = np.argsort(ev0["vehicle"])
    q0 = {k: v[order] for k, v in ev0.items()}
    # a slot lives in the row of the RSU serving the vehicle at *arrival*
    # time — known at schedule time because positions are pure in t
    row0 = np.asarray(corridor.serving_rsu(np.arange(p.K), q0["time"]),
                      np.int32)

    M = rounds
    veh = np.empty(M, np.int32)
    cyc = np.empty(M, np.int32)
    dlr = np.empty(M, np.int32)
    ups = np.empty(M, np.int32)
    times = np.empty(M)
    c_l = np.empty(M)
    c_u = np.empty(M)
    dlt = np.empty(M)
    last_pop = np.full(p.K, -1, np.int32)
    for r in range(M):
        ev = tl.queue.pop()
        veh[r], cyc[r] = ev.vehicle, ev.cycle
        dlr[r] = last_pop[ev.vehicle]
        ups[r] = corridor.serving_rsu(ev.vehicle, ev.time)
        times[r], c_l[r], c_u[r] = ev.time, ev.train_delay, ev.upload_delay
        dlt[r] = ev.download_time
        last_pop[ev.vehicle] = r
        tl.schedule(ev.vehicle, ev.time)
        tl.prune()

    # Wave partition — the jit engine's rule verbatim (DESIGN.md §9): a wave
    # trains every not-yet-trained consumed upload whose payload round has
    # completed, then the scan segment consumes pops up to the first event
    # scheduled *during* that segment.  Handover adds nothing here: the
    # payload of the event consumed at round r is a single ring row (the
    # cohort its re-download read, see engine), so "payload round completed"
    # remains the only readiness condition.
    waves = []
    trained = np.zeros(M, bool)
    s = 0
    while s < M:
        T = np.where(~trained & (dlr < s))[0]
        trained[T] = True
        untrained = np.where(~trained)[0]
        e = int(untrained[0]) if len(untrained) else M
        waves.append((tuple(int(x) for x in T), s, e))
        s = e

    return CorridorPlan(n_rsus=n_rsus, veh=veh, cycle=cyc, dl_round=dlr,
                        up_rsu=ups, times=times, train_delay=c_l,
                        upload_delay=c_u, download_time=dlt,
                        waves=tuple(waves), n_slots=tl.gains.last_slot + 3,
                        q0=q0, row0=row0)

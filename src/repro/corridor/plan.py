"""Host dry-run planner for the corridor engine (DESIGN.md §10).

The event timeline depends only on the channel/mobility/data-size processes,
never on training (DESIGN.md §3) — with the corridor's serving-cell geometry
substituted for the single-RSU distance, the same payload-free f64 dry run
that plans the mega-fleet engine also plans the corridor: pop order, each
pop's serving RSU, the wave partition, the gain-table height, and the
initial per-RSU slot placement all come out of one cheap host replay of the
serial reference's scheduling rules.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.channel import ChannelParams, CorridorMobility, training_delay
from repro.selection import make_selection_state


@dataclass
class CorridorPlan:
    """Everything the compiled corridor program needs that training cannot
    change.  All times are host-reference f64; the device re-derives them in
    f32 and the engine cross-checks the trace (divergence guard)."""
    n_rsus: int
    veh: np.ndarray             # i32[M] vehicle popped at round r
    cycle: np.ndarray           # i32[M] that vehicle's upload cycle
    dl_round: np.ndarray        # i32[M] round after which it downloaded (-1 = initial)
    up_rsu: np.ndarray          # i32[M] serving RSU at arrival (= handover target,
                                #        = the RSU its re-download reads from)
    times: np.ndarray           # f64[M] host-reference pop times
    train_delay: np.ndarray     # f64[M]
    upload_delay: np.ndarray    # f64[M]
    download_time: np.ndarray   # f64[M]
    waves: tuple                # ((train_rounds, seg_start, seg_end), ...)
    n_slots: int                # gain-table height
    q0: dict                    # initial per-vehicle slot arrays (by vehicle)
    row0: np.ndarray            # i32[K] initial RSU row of each vehicle's slot
    sel: object = None          # SelectionPlan (DESIGN.md §11) or None
    sel_bandit: object = None   # (rew_sum f64[K], rew_cnt f64[K]) or None
    flt: object = None          # FaultPlan (DESIGN.md §16) or None

    def tables(self) -> dict:
        """Fixed-shape padded plan tables (DESIGN.md §15) — the corridor
        dual of :meth:`repro.core.jit_engine.FleetPlan.tables`: shapes
        depend only on ``(M, K)``, never on the seed, so per-world tables
        stack along a leading world axis.  The wave partition re-encodes
        as per-round ``train_round``/``seg_end`` columns; ``n_slots``
        pads as a value (the engine zero-pads gain tables).  Duplicated
        from the fleet planner deliberately — this module stays on the
        host side of the engine-import boundary (rule PLN001)."""
        M = len(self.veh)
        train_round = np.full(M, -1, np.int32)
        seg_end = np.zeros(M, np.int32)
        for T, s, e in self.waves:
            for t in T:
                train_round[t] = s
            seg_end[s:e] = e
        return {
            "veh": np.asarray(self.veh, np.int32),
            "cycle": np.asarray(self.cycle, np.int32),
            "dl_round": np.asarray(self.dl_round, np.int32),
            "up_rsu": np.asarray(self.up_rsu, np.int32),
            "times": np.asarray(self.times, np.float64),
            "train_delay": np.asarray(self.train_delay, np.float64),
            "upload_delay": np.asarray(self.upload_delay, np.float64),
            "download_time": np.asarray(self.download_time, np.float64),
            "train_round": train_round,
            "seg_end": seg_end,
            "n_slots": np.asarray(self.n_slots, np.int32),
            "row0": np.asarray(self.row0, np.int32),
            "q0_time": np.asarray(self.q0["time"], np.float64),
            "q0_download_time": np.asarray(self.q0["download_time"],
                                           np.float64),
            "q0_upload_delay": np.asarray(self.q0["upload_delay"],
                                          np.float64),
            "q0_train_delay": np.asarray(self.q0["train_delay"],
                                         np.float64),
        }


def plan_corridor(p: ChannelParams, n_rsus: int, seed: int, rounds: int,
                  entry: str = "uniform", selection=None,
                  reconcile_every: int = 0, faults=None,
                  l_iters: int = 1) -> CorridorPlan:
    """Dry-run ``rounds`` arrivals through the corridor timeline (no
    payloads, no training) and derive everything static.  With a selection
    policy the replay drives a :class:`SelectionState` that re-scores the
    fleet at every reconcile boundary (handed-over vehicles are re-scored
    by the RSU serving them at the boundary timestamp); a fault model
    drives a :class:`FaultState` the same way (DESIGN.md §16) whose
    recovery sweeps run at the same boundaries."""
    from repro.core.mafl import _Timeline
    from repro.faults import arrival_step, initial_vehicles, make_fault_state

    corridor = CorridorMobility(p, n_rsus, entry=entry)
    # corridor worlds re-score ONLY at reconcile boundaries — the spec's
    # resel_every is never consulted here (mirrors the serial reference's
    # unconditional `resel_every=sc.reconcile_every`; 0 disables, and the
    # compiled program splits scan segments at exactly these boundaries).
    # Fault recovery sweeps follow the identical cadence.
    sel = make_selection_state(selection, p, corridor, seed, rounds,
                               resel_every=reconcile_every)
    flt = make_fault_state(faults, p, seed, rounds, l_iters,
                           recheck_every=reconcile_every)
    tl = _Timeline(p, seed, distance_fn=corridor.distance,
                   cl_scale=None if flt is None else flt.cl_scale)
    for k in initial_vehicles(sel, flt, p.K):
        tl.schedule(k, 0.0)

    ev0 = tl.queue.as_struct_arrays()
    if sel is None and flt is None:
        assert len(np.unique(ev0["vehicle"])) == p.K, \
            "slot queue invariant: one in-flight upload per vehicle"
    # full-K slot arrays; parked vehicles hold +inf until a re-admission
    # boundary writes them a live slot (train_delay from Eq. 8 directly —
    # bit-identical to the event values, defined for parked vehicles too;
    # the straggler multipliers scale it exactly as the timeline does)
    q0 = {
        "time": np.full(p.K, np.inf),
        "download_time": np.zeros(p.K),
        "upload_delay": np.zeros(p.K),
        "train_delay": np.array(
            [training_delay(p, i) for i in range(1, p.K + 1)]),
    }
    if flt is not None:
        q0["train_delay"] = q0["train_delay"] * flt.cl_scale
    q0["time"][ev0["vehicle"]] = ev0["time"]
    q0["download_time"][ev0["vehicle"]] = ev0["download_time"]
    q0["upload_delay"][ev0["vehicle"]] = ev0["upload_delay"]
    # a slot lives in the row of the RSU serving the vehicle at *arrival*
    # time — known at schedule time because positions are pure in t; a
    # parked vehicle's slot is +inf in every row, so its row is moot (0)
    live = np.isfinite(q0["time"])
    row0 = np.zeros(p.K, np.int32)
    row0[live] = np.asarray(
        corridor.serving_rsu(np.flatnonzero(live), q0["time"][live]),
        np.int32)

    M = rounds
    veh = np.empty(M, np.int32)
    cyc = np.empty(M, np.int32)
    dlr = np.empty(M, np.int32)
    ups = np.empty(M, np.int32)
    times = np.empty(M)
    c_l = np.empty(M)
    c_u = np.empty(M)
    dlt = np.empty(M)
    last_pop = np.full(p.K, -1, np.int32)
    for r in range(M):
        ev = tl.queue.pop()
        veh[r], cyc[r] = ev.vehicle, ev.cycle
        dlr[r] = last_pop[ev.vehicle]
        ups[r] = corridor.serving_rsu(ev.vehicle, ev.time)
        times[r], c_l[r], c_u[r] = ev.time, ev.train_delay, ev.upload_delay
        dlt[r] = ev.download_time
        last_pop[ev.vehicle] = r
        if sel is None and flt is None:
            tl.schedule(ev.vehicle, ev.time)
        else:
            if flt is not None:
                flt.on_pop(ev.vehicle, r)

            def _readmit(v, t=ev.time, r=r):
                # re-admitted at the (post-reconcile) boundary round — its
                # next pop's payload is ring[r+1], the reconciled model
                tl.schedule(v, t)
                last_pop[v] = r

            arrival_step(
                sel, flt, r=r, vehicle=ev.vehicle, time=ev.time,
                upload_delay=ev.upload_delay, train_delay=ev.train_delay,
                pending=len(tl.queue),
                schedule=lambda v, t=ev.time: tl.schedule(v, t),
                readmit=_readmit)
        tl.prune()

    # Wave partition — the jit engine's rule verbatim (DESIGN.md §9): a wave
    # trains every not-yet-trained consumed upload whose payload round has
    # completed, then the scan segment consumes pops up to the first event
    # scheduled *during* that segment.  Handover adds nothing here: the
    # payload of the event consumed at round r is a single ring row (the
    # cohort its re-download read, see engine), so "payload round completed"
    # remains the only readiness condition.
    waves = []
    trained = np.zeros(M, bool)
    s = 0
    while s < M:
        T = np.where(~trained & (dlr < s))[0]
        trained[T] = True
        untrained = np.where(~trained)[0]
        e = int(untrained[0]) if len(untrained) else M
        waves.append((tuple(int(x) for x in T), s, e))
        s = e

    return CorridorPlan(n_rsus=n_rsus, veh=veh, cycle=cyc, dl_round=dlr,
                        up_rsu=ups, times=times, train_delay=c_l,
                        upload_delay=c_u, download_time=dlt,
                        waves=tuple(waves), n_slots=tl.gains.last_slot + 3,
                        q0=q0, row0=row0,
                        sel=None if sel is None else sel.plan(),
                        sel_bandit=None if sel is None
                        else sel.bandit_expectation(),
                        flt=None if flt is None else flt.plan())


def rsu_chain_groups(plan: CorridorPlan, s: int, e: int,
                     needed) -> list:
    """Static per-RSU upload chains for scan segment ``[s, e)`` — the flat
    fast path's fused-aggregation plan (DESIGN.md §12).

    Within a segment the uploads landing on RSU ``j`` form one sequential
    mix chain on cohort row ``j`` (uploads to other RSUs never touch it),
    so a whole segment aggregates as one ``ring_agg`` chain per active
    RSU.  Each chain is split at the rounds in ``needed`` whose ring row a
    later wave reads (``ring[r+1]`` is the post-upload row of
    ``up_rsu[r]``).  Returns ``[(j, [chunk, ...]), ...]`` where each chunk
    is a list of round indices and every chunk boundary except possibly
    the last must materialize a snapshot."""
    groups = []
    for j in range(plan.n_rsus):
        rounds_j = [r for r in range(s, e) if int(plan.up_rsu[r]) == j]
        if not rounds_j:
            continue
        chunks, cur = [], []
        for r in rounds_j:
            cur.append(r)
            if r + 1 in needed:
                chunks.append(cur)
                cur = []
        if cur:
            chunks.append(cur)
        groups.append((j, chunks))
    return groups

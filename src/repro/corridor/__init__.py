"""Device-resident multi-RSU corridor subsystem (DESIGN.md §10).

An R-RSU highway corridor run entirely on device (``engine="corridor"``):
per-RSU slot event queues batched over a leading RSU axis, handover as a
vectorized slot-migration step, wave-hoisted local training, and a periodic
cloud tier reconciling the R cohort models (FedAvg or EMA, optionally via
the Pallas ``weighted_agg`` kernel, optionally ``shard_map``-sharded over an
``"rsu"`` mesh axis).  ``corridor.reference`` holds the retired serial
handover loop the engine is conformance-tested against.
"""
from repro.corridor.plan import CorridorPlan, plan_corridor
from repro.corridor.engine import run_corridor_simulation
from repro.corridor.reference import run_handover_simulation

__all__ = ["CorridorPlan", "plan_corridor", "run_corridor_simulation",
           "run_handover_simulation"]

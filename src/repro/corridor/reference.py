"""The serial multi-RSU handover loop — retired to a reference
implementation (DESIGN.md §10).

This is the original host-Python corridor engine: one heap pop, one local
update, one cohort aggregation per arrival, with periodic cross-RSU
reconciliation.  It pays Python dispatch per event, so it caps out around
K=40 — the device-resident engine (``corridor.engine``) is the production
path, and this loop survives as the executable specification the
conformance suite pins that engine against (identical arrival traces,
allclose models; ``tests/test_engine_conformance.py``).
"""
from __future__ import annotations

from typing import Sequence

from repro.channel import ChannelParams, CorridorMobility
from repro.core.hierarchical import ema_toward, reconcile_models
from repro.faults import (arrival_step, check_faults_reconcile,
                          initial_vehicles, make_fault_state)
from repro.selection import (check_reconcile_mode, make_selection_state,
                             scenario_spec)


def run_handover_simulation(sc, vehicles_data: Sequence,
                            test_images, test_labels, p: ChannelParams,
                            *, seed: int = 0, eval_every: int = 10,
                            interpretation: str = "mixing",
                            use_kernel: bool = False,
                            batch_size: int = 128,
                            progress=None, selection=None, metrics=None,
                            faults=None):
    """Multi-RSU MAFL with handover (beyond paper, DESIGN.md §8/§10).

    Each RSU keeps its own cohort model and applies the paper's per-arrival
    aggregation; a vehicle downloads from the RSU serving its position at
    download time and uploads to the RSU serving it at arrival time.  Every
    ``sc.reconcile_every`` arrivals the cohort models are reconciled — the
    corridor-scale version of the hierarchical cross-pod reconcile, FedAvg
    (``sc.reconcile_mode == "fedavg"``: all cohorts adopt the mean) or EMA
    (``"ema"``: each cohort moves ``sc.reconcile_tau`` toward the mean).

    ``sc`` is any object with the Scenario fields this reads (scheme,
    rounds, l_iters, lr, n_rsus, reconcile_every, reconcile_mode,
    reconcile_tau, corridor_entry)."""
    import jax
    import numpy as np

    from repro.core.client import Vehicle
    from repro.core.mafl import SimResult, _Timeline, _host_report, evaluate
    from repro.core.server import RSUServer
    from repro.models.cnn import init_cnn
    from repro.telemetry import metrics_requested
    from repro.telemetry.timers import PhaseTimers

    mode = getattr(sc, "reconcile_mode", "fedavg")
    tau = getattr(sc, "reconcile_tau", 0.5)
    entry = getattr(sc, "corridor_entry", "uniform")
    spec = selection if selection is not None else scenario_spec(sc)
    check_reconcile_mode(spec, mode)
    check_faults_reconcile(faults, mode)

    init = init_cnn(jax.random.PRNGKey(seed))
    servers = [RSUServer(init, p, scheme=sc.scheme, use_kernel=use_kernel,
                         interpretation=interpretation)
               for _ in range(sc.n_rsus)]
    corridor = CorridorMobility(p, sc.n_rsus, entry=entry)
    # same scheduling rules as the single-RSU engine — only the geometry
    # (distance to the serving RSU) differs.  Selection re-scores at every
    # reconcile boundary (handed-over vehicles by their new RSU).
    sel = make_selection_state(spec, p, corridor, seed, sc.rounds,
                               resel_every=sc.reconcile_every)
    # fault recovery sweeps follow the reconcile cadence, like selection
    flt = make_fault_state(faults, p, seed, sc.rounds, sc.l_iters,
                           recheck_every=sc.reconcile_every)
    timeline = _Timeline(p, seed, distance_fn=corridor.distance,
                         cl_scale=None if flt is None else flt.cl_scale)
    queue = timeline.queue
    fleet_batch = min(batch_size, min(d.size for d in vehicles_data))
    clients = [Vehicle(d, lr=sc.lr, batch_size=fleet_batch, seed=seed)
               for d in vehicles_data]

    def schedule(vehicle: int, t_download: float):
        rsu = int(corridor.serving_rsu(vehicle, t_download))
        return timeline.schedule(vehicle, t_download,
                                 payload=servers[rsu].global_params)

    for k in initial_vehicles(sel, flt, p.K):
        schedule(k, 0.0)

    timers = PhaseTimers()
    met_req = metrics_requested(metrics)
    ch_stale, ch_occ, ch_gap, ch_times = [], [], [], []
    ch_rsu, ch_ho = [], []

    result = SimResult(scheme=f"{sc.scheme}+handover", rounds=[],
                       acc_history=[], loss_history=[])
    total = 0
    with timers.phase("run"):
        while total < sc.rounds and len(queue):
            if met_req:
                # per-RSU live slots before the pop — a pending slot's row
                # is the RSU serving the vehicle at its *arrival* time
                # (same rule the device bakes into the slot migration)
                pend = list(queue.pending())
                vs = np.array([pe.vehicle for pe in pend], np.int64)
                ts = np.array([pe.time for pe in pend])
                ch_occ.append(np.bincount(
                    np.asarray(corridor.serving_rsu(vs, ts), np.int64),
                    minlength=sc.n_rsus))
            ev = queue.pop()
            keep = True
            if flt is not None:
                # staleness-cap verdict + this cycle's epoch count, fixed
                # before the gate below draws the *next* cycle's block
                keep, _ = flt.on_pop(ev.vehicle, total)
            local_params, _ = clients[ev.vehicle].local_update(
                ev.payload, sc.l_iters,
                n_ep=(flt.epoch_of(ev.vehicle)
                      if flt is not None and flt.spec.has_partial
                      else None))
            rsu = int(corridor.serving_rsu(ev.vehicle, ev.time))  # handover target
            if met_req:
                ch_stale.append(ev.time - ev.download_time)
                ch_gap.append(ev.time - (ch_times[-1] if ch_times else 0.0))
                ch_times.append(ev.time)
                ch_rsu.append(rsu)
            rec = servers[rsu].receive(
                local_params, time=ev.time, vehicle=ev.vehicle,
                upload_delay=ev.upload_delay, train_delay=ev.train_delay,
                download_time=ev.download_time, discard=not keep)
            rec.rsu = rsu
            total += 1
            consensus = None
            if total % sc.reconcile_every == 0:
                consensus = reconcile_models(
                    [s.global_params for s in servers])
                if mode == "ema":
                    for s in servers:
                        s.global_params = ema_toward(s.global_params,
                                                     consensus, tau)
                else:
                    for s in servers:
                        s.global_params = consensus
            if total % eval_every == 0 or total == sc.rounds:
                if consensus is None or mode == "ema":
                    consensus = reconcile_models(
                        [s.global_params for s in servers])
                with timers.phase("eval"):
                    acc, loss = evaluate(consensus, test_images,
                                         test_labels)
                rec.accuracy, rec.loss = acc, loss
                result.acc_history.append((total, acc))
                result.loss_history.append((total, loss))
                if progress:
                    progress(total, acc)
            result.rounds.append(rec)
            nev = None
            if sel is None and flt is None:
                nev = schedule(ev.vehicle, ev.time)
            else:
                # mask at schedule (post-reconcile, like the ordinary
                # re-download): park unadmitted/faulted vehicles, re-score
                # and sweep recoveries at every reconcile boundary
                res = {}
                arrival_step(
                    sel, flt, r=total - 1, vehicle=ev.vehicle, time=ev.time,
                    upload_delay=ev.upload_delay,
                    train_delay=ev.train_delay, pending=len(queue),
                    schedule=lambda v, t=ev.time: res.__setitem__(
                        "nev", schedule(v, t)),
                    readmit=lambda v, t=ev.time: schedule(v, t))
                nev = res.get("nev")
            if met_req:
                # handover = the admitted re-schedule lands on a new RSU;
                # parked vehicles (and boundary re-admissions) don't count
                ch_ho.append(nev is not None and int(
                    corridor.serving_rsu(ev.vehicle, nev.time)) != rsu)
            timeline.prune()

    result.final_params = reconcile_models(
        [s.global_params for s in servers])
    sel_summary = None if sel is None else sel.plan().summary()
    flt_plan = None if flt is None else flt.plan()
    if flt_plan is not None:
        result.extras["faults"] = flt_plan.summary(sc.l_iters)
    ho_count = (np.bincount(np.asarray(ch_rsu, np.int64)[
        np.asarray(ch_ho, bool)], minlength=sc.n_rsus)
        if met_req else None)
    result.report = _host_report(
        engine="serial", scheme=f"{sc.scheme}+handover", rounds=total,
        seed=seed, metrics=metrics, met_req=met_req, p=p, timers=timers,
        selection=sel_summary, records=result.rounds, stale=ch_stale,
        occ=ch_occ, gap=ch_gap, times=ch_times, n_rsus=sc.n_rsus,
        up_rsu=np.asarray(ch_rsu, np.int64) if met_req else None,
        handover=np.asarray(ch_ho, bool) if met_req else None,
        handover_count=ho_count, faults=flt_plan, l_iters=sc.l_iters)
    return result

"""Pure-jnp oracle: causal sliding-window attention (GQA-aware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def swa_attention(q, k, v, window: int):
    """q: [B, S, H, hd]; k, v: [B, S, Kv, hd]; H % Kv == 0.
    Causal, attends only to the last ``window`` positions (inclusive of
    self).  Returns [B, S, H, hd]."""
    B, S, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qg = q.reshape(B, S, Kv, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    pos = jnp.arange(S)
    ok = (pos[None, :] <= pos[:, None]) & \
         (pos[:, None] - pos[None, :] < window)
    scores = jnp.where(ok[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, S, H, hd)

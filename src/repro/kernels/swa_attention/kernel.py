"""Sliding-window flash-attention forward kernel (TPU-adapted).

TPU adaptation of the paper-agnostic SWA hot-spot (DESIGN.md §2): instead of
a GPU warp-tiled kernel, blocks are sized for VMEM/MXU — (block_q x hd) query
tiles stream (block_k x hd) KV tiles whose *block index is derived from the
query block*, so only ceil(W/bk)+1 KV tiles are touched per query tile: the
O(S*W) (not O(S^2)) schedule is structural, enforced by the BlockSpec index
maps.  GQA is folded into the index maps (kv head = q head // group).

Running-softmax state (m, s, acc) lives in VMEM scratch across the kv sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import resolve_interpret

NEG_INF = -1e30


def _swa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, s_ref, acc_ref, *,
                block_q: int, block_k: int, window: int, n_kv: int,
                scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute start of the kv block this step touches (see index_map)
    # highest kv block needed by this q tile is its own last column block;
    # the sweep walks the n_kv blocks ending there (negative kb => masked)
    kb = qi * (block_q // block_k) + (block_q // block_k - 1) - \
        (n_kv - 1) + ki
    q = q_ref[0].astype(jnp.float32) * scale              # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                      # [bk, hd]
    scores = q @ k.T                                      # [bq, bk]

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    kpos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    ok = (kpos <= qpos) & (qpos - kpos < window) & (kb >= 0)
    scores = jnp.where(ok, scores, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[:, None])
    s_ref[...] = s_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + \
        p @ v_ref[0].astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        denom = jnp.maximum(s_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                             "interpret"))
def swa_attention_bhsd(q, k, v, *, window: int, block_q: int = 128,
                       block_k: int = 128, interpret=None):
    """q: [BH, S, hd]; k, v: [BKv, S, hd]; BH = B*H, BKv = B*Kv.
    Requires S % block == 0 and window % block_k == 0.

    ``interpret=None`` resolves by backend from the race analyzer's verdict
    (``sequential-axis-required``: the kv sweep accumulates softmax state
    through VMEM scratch): compiled on TPU, interpreter elsewhere."""
    interpret = resolve_interpret("swa_attention.swa_attention_bhsd",
                                  interpret)
    BH, S, hd = q.shape
    BKv = k.shape[0]
    G = BH // BKv
    bq, bk = min(block_q, S), min(block_k, S)
    assert S % bq == 0 and S % bk == 0
    assert bq % bk == 0, "block_q must be a multiple of block_k"
    # blocks per q-tile sweep: the q tile spans bq/bk column blocks, plus the
    # window reaches back ceil((W-1)/bk) more (negative ids are masked out)
    n_kv = bq // bk + -(-(window - 1) // bk)
    scale = 1.0 / np.sqrt(hd)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        kb = qi * (bq // bk) + (bq // bk - 1) - (n_kv - 1) + ki
        kb = jnp.clip(kb, 0, S // bk - 1)
        return (bh // G, kb, 0)

    kernel = functools.partial(_swa_kernel, block_q=bq, block_k=bk,
                               window=window, n_kv=n_kv, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(BH, S // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), q_map),
            pl.BlockSpec((1, bk, hd), kv_map),
            pl.BlockSpec((1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), q_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

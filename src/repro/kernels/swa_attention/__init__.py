from repro.kernels.swa_attention import kernel, ops, ref  # noqa: F401

"""Public wrapper: [B, S, H, hd] GQA layout -> kernel layout and back."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.swa_attention.kernel import swa_attention_bhsd


def swa_attention(q, k, v, window: int, *, block_q: int = 128,
                  block_k: int = 128, interpret=None):
    """q: [B, S, H, hd]; k, v: [B, S, Kv, hd] -> [B, S, H, hd].
    ``interpret=None`` resolves by backend via ``repro.kernels.dispatch``."""
    B, S, H, hd = q.shape
    Kv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kv, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kv, S, hd)
    out = swa_attention_bhsd(qf, kf, vf, window=window, block_q=block_q,
                             block_k=block_k, interpret=interpret)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)

"""Pure-jnp oracle: one-token GQA attention against a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention(q, k_cache, v_cache, pos):
    """q: [B, H, hd] (one new token, already rotary-encoded);
    k_cache/v_cache: [B, S, Kv, hd] with entries > pos invalid.
    Returns [B, H, hd]."""
    B, H, hd = q.shape
    Kv = k_cache.shape[2]
    G = H // Kv
    qg = q.reshape(B, Kv, G, hd)
    scores = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache).astype(jnp.float32)
    scores = scores / np.sqrt(hd)
    ok = jnp.arange(k_cache.shape[1]) <= pos
    scores = jnp.where(ok[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", w, v_cache)
    return out.reshape(B, H, hd)

"""Public wrapper: [B, H, hd] query + [B, S, Kv, hd] cache -> kernel layout."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_bkv


def decode_attention(q, k_cache, v_cache, pos, *, block_s: int = 512,
                     interpret=None):
    """q: [B, H, hd]; caches [B, S, Kv, hd]; pos scalar int.
    ``interpret=None`` resolves by backend via ``repro.kernels.dispatch``."""
    B, H, hd = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    G = H // Kv
    qf = q.reshape(B, Kv, G, hd).reshape(B * Kv, G, hd)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * Kv, S, hd)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * Kv, S, hd)
    posb = jnp.full((1, 1), pos, jnp.int32)
    out = decode_attention_bkv(qf, kf, vf, posb, block_s=block_s,
                               interpret=interpret)
    return out.reshape(B, Kv, G, hd).reshape(B, H, hd)

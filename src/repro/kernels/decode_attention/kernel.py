"""Fused one-token GQA decode-attention kernel (the decode_32k hot-spot).

Decode is KV-cache-streaming-bound (EXPERIMENTS.md §Roofline): the kernel
streams [block_s, hd] cache tiles through VMEM once, keeping the online
softmax state (m, s, acc) for all G grouped query heads in scratch — one
HBM pass over the cache per step, no [S]-sized intermediates.

Grid: (B * Kv, S / block_s); the G query heads sharing one KV head ride in
the block's leading dim so the MXU sees [G, hd] x [hd, block_s] matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import resolve_interpret

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, s_ref,
                   acc_ref, *, block_s: int, n_blocks: int, scale: float):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0, 0]
    q = q_ref[0].astype(jnp.float32) * scale              # [G, hd]
    k = k_ref[0].astype(jnp.float32)                      # [bs, hd]
    scores = q @ k.T                                      # [G, bs]
    idx = si * block_s + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(idx <= pos, scores, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[:, None])
    s_ref[...] = s_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + \
        p @ v_ref[0].astype(jnp.float32)
    m_ref[...] = m_new

    @pl.when(si == n_blocks - 1)
    def _finish():
        denom = jnp.maximum(s_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_bkv(q, k, v, pos, *, block_s: int = 512,
                         interpret=None):
    """q: [BKv, G, hd]; k, v: [BKv, S, hd]; pos: i32[1,1] scalar block.
    Returns [BKv, G, hd].

    ``interpret=None`` resolves by backend from the race analyzer's verdict
    (``sequential-axis-required``: the cache sweep accumulates softmax state
    through VMEM scratch): compiled on TPU, interpreter elsewhere."""
    interpret = resolve_interpret("decode_attention.decode_attention_bkv",
                                  interpret)
    BKv, G, hd = q.shape
    S = k.shape[1]
    bs = min(block_s, S)
    assert S % bs == 0
    n_blocks = S // bs
    kern = functools.partial(_decode_kernel, block_s=bs, n_blocks=n_blocks,
                             scale=1.0 / np.sqrt(hd))
    return pl.pallas_call(
        kern,
        grid=(BKv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, s: (0, 0)),    # pos scalar
            pl.BlockSpec((1, G, hd), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, bs, hd), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, bs, hd), lambda b, s: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(pos, q, k, v)

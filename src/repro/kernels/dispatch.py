"""Backend dispatch derived from the grid-race analyzer (DESIGN.md §13).

Every ``kernels/*/ops.py`` wrapper used to hand-roll its backend selection —
``ring_agg`` pinned its compiled path to TPU with an inline
``jax.default_backend()`` check, the older wrappers defaulted to the
interpreter everywhere.  This module is now the single place execution modes
come from, and the legality table is *derived* from
``repro.check.pallas_race``'s per-backend verdict rather than maintained by
hand (rule PAL003 flags any reintroduction of inline backend checks under
``kernels/``).

``select_impl`` maps (race verdict, backend, caller's ``interpret`` flag) to
one of three modes:

- ``"compiled"``  — run the compiled Pallas kernel (Mosaic/Triton).
- ``"interpret"`` — run the kernel body through the Pallas interpreter
  (always legal: the interpreter executes grid cells sequentially in
  row-major order, the same order the classification assumes).
- ``"fallback"``  — use the caller's jnp reference implementation (only
  returned when the caller declares one via ``fallback="ref"``).
"""
from __future__ import annotations

from typing import Optional

import jax


def kernel_report(kernel_id: str):
    """The race analyzer's cached :class:`KernelReport` for a registered
    kernel.  Imported lazily: ``repro.check`` pulls kernel modules in to
    capture their grids, so a module-level import would cycle."""
    from repro.check.pallas_race import get_report
    return get_report(kernel_id)


def select_impl(report, backend: Optional[str] = None, *,
                interpret=None, fallback: str = "interpret",
                force_kernel: bool = False) -> str:
    """Resolve the execution mode for one kernel call.

    ``interpret`` is the caller-facing tri-state every wrapper exposes:
    an explicit bool forces Pallas in that mode (parity across modes is
    pinned by the kernel test suites); ``None`` resolves by backend from
    the race verdict.  ``fallback`` names what an illegal backend gets:
    ``"interpret"`` (default) or ``"ref"`` — callers with a cheaper jnp
    reference (``ring_agg``'s one-pass scan chain) pass ``"ref"`` and
    map the returned ``"fallback"`` onto it.  ``force_kernel=True`` keeps
    the Pallas kernel even where compiled execution is illegal (the
    engines' ``use_kernel=True`` contract): interpret mode instead of the
    reference."""
    if interpret is not None:
        return "interpret" if interpret else "compiled"
    backend = backend or jax.default_backend()
    if report.compiled_legal.get(backend, False):
        return "compiled"
    if force_kernel or fallback != "ref":
        return "interpret"
    return "fallback"


def resolve_interpret(kernel_id: str, interpret=None) -> bool:
    """The kernel-level form of :func:`select_impl`: the ``interpret`` flag
    a ``pallas_call`` wrapper should use when its caller passed ``None``.
    At this level the kernel *will* run — the only question is compiled vs
    interpreter — so illegal-compiled backends get the interpreter."""
    if interpret is not None:
        return interpret
    mode = select_impl(kernel_report(kernel_id), force_kernel=True)
    return mode != "compiled"

"""Jit'd public wrappers: the fused aggregation kernels applied to arbitrary
pytrees (``weighted_agg_tree``) and to packed flat buffers (``ring_agg``,
DESIGN.md §12) by tiling into lane-aligned (R, 128) blocks.

``interpret=None`` (default) picks the execution mode per backend — the
Pallas interpreter on CPU, a compiled VMEM-tiled streaming kernel on
TPU/GPU (the hardcoded ``interpret=True`` default used to force the
interpreter even on accelerators).  Leaves too small to tile (< 128
elements) fall through to the jnp oracle — the traffic they contribute is
negligible.  Ragged leaves are zero-padded up to the next full lane row and
run through the tiled kernel in one call (the padded slice of the output is
dropped); the old path computed the remainder with the jnp oracle and
``jnp.concatenate``d it back, which re-copied the whole leaf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dispatch import kernel_report, select_impl
from repro.kernels.weighted_agg import ref
from repro.kernels.weighted_agg.kernel import (LANE, ring_agg_2d,
                                               weighted_agg_2d)


def weighted_agg_leaf(g, l, beta: float, weight: float, interpret=None):
    if g.size < LANE:
        return ref.weighted_agg(g, l, beta, weight)
    scalars = jnp.asarray([[beta, weight]], jnp.float32)
    n = g.size
    rows = -(-n // LANE)
    pad = rows * LANE - n
    gf, lf = g.reshape(-1), l.reshape(-1)
    if pad:
        # pad the ragged tail into the last tile row; beta*0+(1-beta)*w*0
        # keeps the pad lanes finite and the slice below drops them
        gf = jnp.pad(gf, (0, pad))
        lf = jnp.pad(lf, (0, pad))
    out = weighted_agg_2d(gf.reshape(rows, LANE), lf.reshape(rows, LANE),
                          scalars, interpret=interpret).reshape(-1)
    if pad:
        out = out[:n]
    return out.reshape(g.shape)


def weighted_agg_tree(global_params, local_params, beta: float,
                      weight: float, interpret=None):
    """Drop-in for ``aggregation.mafl_update(..., use_kernel=True)``."""
    return jax.tree_util.tree_map(
        lambda g, l: weighted_agg_leaf(g, l, beta, weight, interpret),
        global_params, local_params)


def ring_agg(g, locs, coeffs, interpret=None):
    """Fused multi-upload chain over packed flat buffers (DESIGN.md §12).

    ``g``: ``[P]`` with P a multiple of 128 (a ``ParamLayout`` buffer);
    ``locs``: ``[U, P]`` f32/bf16 upload rows; ``coeffs``: ``f32[U, 2]``
    per-upload ``(c, d)`` mix pairs.  Semantics are exactly
    ``ref.ring_agg`` (U sequential mixes, f32 accumulation — bitwise equal
    to U separate ``mix_update`` passes); this wrapper is the one-pass
    streaming execution of it.

    ``interpret=None`` resolves from the race analyzer's per-backend
    verdict (``repro.kernels.dispatch.select_impl``): the kernel is
    ``sequential-axis-required`` — its upload-chunk accumulation revisits
    the output tile across grid steps, which requires the *sequential*
    grid execution TPU (and the interpreter) guarantee; GPU grid cells
    are parallel blocks, so GPU and CPU fall back to the jnp chain (same
    arithmetic, one lax.scan pass).  Pass ``interpret=True/False`` to
    force the Pallas kernel in either mode (parity is pinned by
    ``tests/test_flat.py``)."""
    U = locs.shape[0]
    if U == 0:
        return g.astype(jnp.float32)
    assert g.shape[-1] % LANE == 0, \
        f"ring_agg needs a lane-aligned buffer, got P={g.shape[-1]}"
    mode = select_impl(kernel_report("weighted_agg.ring_agg_2d"),
                       interpret=interpret, fallback="ref")
    if mode == "fallback":
        return ref.ring_agg(g, locs, coeffs)
    rows = g.shape[-1] // LANE
    out = ring_agg_2d(g.reshape(rows, LANE),
                      locs.reshape(U, rows, LANE), coeffs,
                      interpret=mode == "interpret")
    return out.reshape(-1)


def prefix_weights(coeffs) -> np.ndarray:
    """The chain's closed form: weights ``w[U+1]`` (f64) such that

        ring_agg(g, locs, coeffs) ~= w[0]*g + sum_u w[1+u]*locs[u]

    with ``w[0] = prod_u c_u`` and ``w[1+u] = d_u * prod_{v>u} c_v`` — the
    prefix-weight algebra the f64 host planner exposes (DESIGN.md §12).
    Equality is algebraic, not bitwise: evaluating this form reassociates
    the f32 arithmetic, which is why the kernels evaluate sequentially."""
    c = np.asarray(coeffs, np.float64)
    U = c.shape[0]
    w = np.empty(U + 1)
    suffix = 1.0
    for u in range(U - 1, -1, -1):
        w[1 + u] = c[u, 1] * suffix
        suffix *= c[u, 0]
    w[0] = suffix
    return w

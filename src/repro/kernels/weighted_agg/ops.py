"""Jit'd public wrapper: applies the fused aggregation kernel to arbitrary
pytrees by flattening every leaf into lane-aligned (R, 128) tiles.

``interpret=None`` (default) picks the execution mode per backend — the
Pallas interpreter on CPU, a compiled VMEM-tiled streaming kernel on
TPU/GPU (the hardcoded ``interpret=True`` default used to force the
interpreter even on accelerators).  Leaves too small to tile (< 128
elements) fall through to the jnp oracle — the traffic they contribute is
negligible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.weighted_agg import ref
from repro.kernels.weighted_agg.kernel import LANE, weighted_agg_2d


def weighted_agg_leaf(g, l, beta: float, weight: float, interpret=None):
    if g.size < LANE:
        return ref.weighted_agg(g, l, beta, weight)
    scalars = jnp.asarray([[beta, weight]], jnp.float32)
    n = g.size
    rows = n // LANE
    main = rows * LANE
    gf, lf = g.reshape(-1), l.reshape(-1)
    out_main = weighted_agg_2d(gf[:main].reshape(rows, LANE),
                               lf[:main].reshape(rows, LANE), scalars,
                               interpret=interpret).reshape(-1)
    if main == n:
        return out_main.reshape(g.shape)
    tail = ref.weighted_agg(gf[main:], lf[main:], beta, weight)
    return jnp.concatenate([out_main, tail]).reshape(g.shape)


def weighted_agg_tree(global_params, local_params, beta: float,
                      weight: float, interpret=None):
    """Drop-in for ``aggregation.mafl_update(..., use_kernel=True)``."""
    return jax.tree_util.tree_map(
        lambda g, l: weighted_agg_leaf(g, l, beta, weight, interpret),
        global_params, local_params)

"""Fused MAFL aggregation kernel (Eq. 10 + Eq. 11):

    out = beta * w_global + (1 - beta) * weight * w_local

One HBM read of each operand, one write — the minimal-traffic form of the
RSU update (it is memory-roofline-bound; arithmetic intensity ~3 flops /
6 bytes).  Arrays are processed as flat (rows, 128) lane-aligned tiles; the
two scalar coefficients ride along as a tiny replicated block so a single
compiled kernel serves every round / every leaf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 256        # 256 x 128 x 4B = 128 KiB per operand tile


def _agg_kernel(scal_ref, g_ref, l_ref, o_ref):
    beta = scal_ref[0, 0]
    weight = scal_ref[0, 1]
    g = g_ref[...].astype(jnp.float32)
    l = l_ref[...].astype(jnp.float32)
    o_ref[...] = (beta * g + (1.0 - beta) * weight * l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def weighted_agg_2d(g, l, scalars, *, block_rows=DEFAULT_BLOCK_ROWS,
                    interpret=None):
    """g, l: [R, 128] same dtype; scalars: f32[1, 2] = (beta, weight).

    ``interpret=None`` (default) selects the mode from the backend: the
    kernel body runs through the Pallas interpreter on CPU (where no Mosaic
    lowering exists) and compiles on TPU/GPU.  Pass an explicit bool to
    force a mode — parity across modes and backends is pinned by
    ``tests/test_kernels.py``."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    R = g.shape[0]
    br = min(block_rows, R)
    return pl.pallas_call(
        _agg_kernel,
        grid=(pl.cdiv(R, br),),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),      # scalars, replicated
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        interpret=interpret,
    )(scalars, g, l)

"""Fused MAFL aggregation kernel (Eq. 10 + Eq. 11):

    out = beta * w_global + (1 - beta) * weight * w_local

One HBM read of each operand, one write — the minimal-traffic form of the
RSU update (it is memory-roofline-bound; arithmetic intensity ~3 flops /
6 bytes).  Arrays are processed as flat (rows, 128) lane-aligned tiles; the
two scalar coefficients ride along as a tiny replicated block so a single
compiled kernel serves every round / every leaf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import resolve_interpret

LANE = 128
DEFAULT_BLOCK_ROWS = 256        # 256 x 128 x 4B = 128 KiB per operand tile
DEFAULT_BLOCK_U = 8             # uploads per grid step of the fused chain


def _agg_kernel(scal_ref, g_ref, l_ref, o_ref):
    beta = scal_ref[0, 0]
    weight = scal_ref[0, 1]
    g = g_ref[...].astype(jnp.float32)
    l = l_ref[...].astype(jnp.float32)
    o_ref[...] = (beta * g + (1.0 - beta) * weight * l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def weighted_agg_2d(g, l, scalars, *, block_rows=DEFAULT_BLOCK_ROWS,
                    interpret=None):
    """g, l: [R, 128] same dtype; scalars: f32[1, 2] = (beta, weight).

    ``interpret=None`` (default) resolves the mode from the race analyzer's
    per-backend verdict (``repro.kernels.dispatch``): this kernel is
    parallel-safe, so it compiles on TPU/GPU and runs through the Pallas
    interpreter on CPU (where no Mosaic lowering exists).  Pass an explicit
    bool to force a mode — parity across modes and backends is pinned by
    ``tests/test_kernels.py``."""
    interpret = resolve_interpret("weighted_agg.weighted_agg_2d", interpret)
    R = g.shape[0]
    br = min(block_rows, R)
    return pl.pallas_call(
        _agg_kernel,
        grid=(pl.cdiv(R, br),),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),      # scalars, replicated
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(g.shape, g.dtype),
        interpret=interpret,
    )(scalars, g, l)


# ---------------------------------------------------------------------------
# fused multi-upload chain: U staleness-weighted mixes in one streaming pass
# ---------------------------------------------------------------------------
def _make_ring_kernel(block_u: int, U: int):
    def _ring_kernel(coef_ref, g_ref, l_ref, o_ref):
        ub = pl.program_id(1)

        @pl.when(ub == 0)
        def _():
            # first upload chunk of this row tile: seed the accumulator
            # with the global model (f32 master)
            o_ref[...] = g_ref[...].astype(jnp.float32)

        def body(j, acc):
            c = coef_ref[j, 0]
            d = coef_ref[j, 1]
            l = l_ref[j].astype(jnp.float32)
            new = c * acc + d * l
            # ragged final chunk: steps past U are identity (masked, not
            # coeff-padded — 1*acc + 0*l would rewrite -0.0 to +0.0)
            return jnp.where(ub * block_u + j < U, new, acc)

        o_ref[...] = jax.lax.fori_loop(0, block_u, body, o_ref[...])
    return _ring_kernel


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "block_u", "interpret"))
def ring_agg_2d(g, locs, coeffs, *, block_rows=DEFAULT_BLOCK_ROWS,
                block_u=DEFAULT_BLOCK_U, interpret=None):
    """g: [R, 128]; locs: [U, R, 128] (f32 or bf16); coeffs: f32[U, 2].

    Applies the U-upload mix chain ``acc <- c_u*acc + d_u*locs[u]`` with an
    f32 accumulator that lives in the output tile across upload chunks:
    grid = (row tiles, upload chunks) with the upload axis innermost, so
    each row tile of the global model is read ONCE and each local is read
    once — ``(U+2)·P`` total traffic for the whole chain instead of the
    ``3·U·P`` of U separate two-operand passes.  The cross-chunk
    accumulation through ``o_ref`` assumes grid steps execute
    *sequentially* (TPU and the interpreter do; GPU grid cells are
    parallel blocks and would race) — the race analyzer classifies this
    kernel ``sequential-axis-required``, so dispatch only compiles it on
    TPU; ``interpret=None`` anywhere else gets the interpreter.
    Sequential evaluation order per element keeps the f32 path bitwise
    against chained ``weighted_agg`` calls (see ``ref.ring_agg``).
    Output is f32."""
    interpret = resolve_interpret("weighted_agg.ring_agg_2d", interpret)
    U, R = locs.shape[0], g.shape[0]
    assert locs.shape[1:] == g.shape and coeffs.shape == (U, 2)
    br = min(block_rows, R)
    bu = min(block_u, U)
    return pl.pallas_call(
        _make_ring_kernel(bu, U),
        grid=(pl.cdiv(R, br), pl.cdiv(U, bu)),
        in_specs=[
            pl.BlockSpec((bu, 2), lambda i, u: (u, 0)),
            pl.BlockSpec((br, LANE), lambda i, u: (i, 0)),
            pl.BlockSpec((bu, br, LANE), lambda i, u: (u, i, 0)),
        ],
        out_specs=pl.BlockSpec((br, LANE), lambda i, u: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(g.shape, jnp.float32),
        interpret=interpret,
    )(coeffs, g, locs)

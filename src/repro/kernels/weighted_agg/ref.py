"""Pure-jnp oracle for the fused aggregation (Eqs. 10-11)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_agg(g, l, beta: float, weight: float):
    """out = beta*g + (1-beta)*weight*l, computed in f32, cast back."""
    b = jnp.float32(beta)
    w = jnp.float32(weight)
    return (b * g.astype(jnp.float32) +
            (1.0 - b) * w * l.astype(jnp.float32)).astype(g.dtype)


def weighted_agg_tree(global_params, local_params, beta: float,
                      weight: float):
    return jax.tree_util.tree_map(
        lambda g, l: weighted_agg(g, l, beta, weight), global_params,
        local_params)


def ring_agg(g, locs, coeffs):
    """Fused multi-upload chain, pure-jnp form (also the CPU fast path).

    ``g``: ``[P]`` (any float dtype, accumulated in f32); ``locs``:
    ``[U, P]``; ``coeffs``: ``f32[U, 2]`` of per-upload ``(c, d)`` pairs.
    Applies the U mixes *sequentially*::

        acc <- c_u * acc + d_u * locs[u]        (f32)

    which is bitwise identical to U separate ``mix_update`` /
    ``literal_update`` passes in f32 — the property that lets the flat
    engines stay pinned by the PR-4 golden traces.  Algebraically it equals
    the prefix-weight linear combination (``aggregation.prefix_weights``),
    but evaluating *that* would reassociate the f32 arithmetic.  Returns
    f32 (the master-weight dtype)."""

    def step(acc, cl):
        c, l = cl
        return c[0] * acc + c[1] * l.astype(jnp.float32), None

    acc, _ = jax.lax.scan(step, g.astype(jnp.float32), (coeffs, locs))
    return acc

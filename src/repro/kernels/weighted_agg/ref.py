"""Pure-jnp oracle for the fused aggregation (Eqs. 10-11)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_agg(g, l, beta: float, weight: float):
    """out = beta*g + (1-beta)*weight*l, computed in f32, cast back."""
    b = jnp.float32(beta)
    w = jnp.float32(weight)
    return (b * g.astype(jnp.float32) +
            (1.0 - b) * w * l.astype(jnp.float32)).astype(g.dtype)


def weighted_agg_tree(global_params, local_params, beta: float,
                      weight: float):
    return jax.tree_util.tree_map(
        lambda g, l: weighted_agg(g, l, beta, weight), global_params,
        local_params)

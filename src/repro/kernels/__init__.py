"""Pallas TPU kernels for the compute hot-spots this system optimizes:

  weighted_agg   — the paper's fused Eq.(10)+(11) aggregation pass: the RSU
                   update is a pure HBM-streaming op over the full parameter
                   pytree (memory-roofline-bound at 12B-405B params).
  cross_entropy  — Eq.(1) loss over 100k-200k vocabularies, vocab-tiled
                   online-softmax (avoids materializing log-probs).
  swa_attention  — sliding-window flash-style attention forward for the
                   long_500k-legal dense variant (mistral-nemo SWA).

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with interpret fallback), ref.py (pure-jnp oracle).  CPU validation
runs interpret=True; compiled TPU lowering is the deployment target.
"""

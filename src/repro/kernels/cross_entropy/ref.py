"""Pure-jnp oracle for the Eq. (1) cross-entropy loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels):
    """logits [R, V], labels [R] int32 -> per-row NLL [R] (f32)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]

from repro.kernels.cross_entropy import kernel, ops, ref  # noqa: F401

"""Vocab-tiled online-softmax cross-entropy (Eq. 1) Pallas kernel.

For 100k-200k vocabularies the naive log-softmax materializes [R, V] logprobs
in HBM; this kernel streams vocab tiles through VMEM keeping only the running
(max, sumexp, label-logit) statistics per row — the flash-softmax recurrence:

    m' = max(m, max(tile));  s' = s*exp(m-m') + sum(exp(tile-m'))
    nll = log(s_final) + m_final - logit[label]

Grid: (row_blocks, vocab_blocks); vocab is the innermost (fastest) axis so
each row block's statistics live in VMEM scratch across its vocab sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import resolve_interpret

DEFAULT_BLOCK_R = 128
DEFAULT_BLOCK_V = 2048
NEG_INF = -1e30


def _ce_kernel(labels_ref, logits_ref, out_ref, m_ref, s_ref, c_ref,
               *, block_v: int, n_v_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        c_ref[...] = jnp.full_like(c_ref, NEG_INF)

    tile = logits_ref[...].astype(jnp.float32)            # [br, bv]
    m_prev = m_ref[...]                                   # [br]
    m_new = jnp.maximum(m_prev, jnp.max(tile, axis=-1))
    scale = jnp.exp(m_prev - m_new)
    s_ref[...] = s_ref[...] * scale + jnp.sum(
        jnp.exp(tile - m_new[:, None]), axis=-1)
    m_ref[...] = m_new

    # pick out the label logit if it falls inside this vocab tile
    labels = labels_ref[...]                              # [br] int32
    local = labels - j * block_v
    cols = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1)
    hit = cols == local[:, None]
    c_ref[...] = jnp.maximum(c_ref[...],
                             jnp.max(jnp.where(hit, tile, NEG_INF), axis=-1))

    @pl.when(j == n_v_blocks - 1)
    def _finish():
        out_ref[...] = jnp.log(s_ref[...]) + m_ref[...] - c_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("block_r", "block_v", "interpret"))
def cross_entropy_tiled(logits, labels, *, block_r=DEFAULT_BLOCK_R,
                        block_v=DEFAULT_BLOCK_V, interpret=None):
    """logits [R, V] (V % block_v == 0, R % block_r == 0), labels [R] int32
    -> per-row NLL [R] f32.

    ``interpret=None`` resolves by backend from the race analyzer's verdict
    (``sequential-axis-required``: the vocab sweep accumulates through VMEM
    scratch): compiled on TPU, interpreter elsewhere."""
    interpret = resolve_interpret("cross_entropy.cross_entropy_tiled",
                                  interpret)
    R, V = logits.shape
    br, bv = min(block_r, R), min(block_v, V)
    assert R % br == 0 and V % bv == 0, (R, V, br, bv)
    n_v = V // bv
    kernel = functools.partial(_ce_kernel, block_v=bv, n_v_blocks=n_v)
    return pl.pallas_call(
        kernel,
        grid=(R // br, n_v),
        in_specs=[
            pl.BlockSpec((br,), lambda i, j: (i,)),       # labels
            pl.BlockSpec((br, bv), lambda i, j: (i, j)),  # logits tile
        ],
        out_specs=pl.BlockSpec((br,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((R,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((br,), jnp.float32),   # running max  m
            pltpu.VMEM((br,), jnp.float32),   # running sumexp s
            pltpu.VMEM((br,), jnp.float32),   # label logit  c
        ],
        interpret=interpret,
    )(labels, logits)

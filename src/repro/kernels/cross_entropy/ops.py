"""Public wrapper: pads rows/vocab to tile multiples, restores shape, and
offers the mean-reduced LM loss used by the training driver."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.cross_entropy import ref
from repro.kernels.cross_entropy.kernel import (DEFAULT_BLOCK_R,
                                                DEFAULT_BLOCK_V,
                                                cross_entropy_tiled)


def cross_entropy(logits, labels, *, interpret=None):
    """logits [R, V], labels [R] -> per-row NLL [R] f32 (pads as needed).
    ``interpret=None`` resolves by backend via ``repro.kernels.dispatch``."""
    R, V = logits.shape
    br = min(DEFAULT_BLOCK_R, max(8, 1 << (R - 1).bit_length()))
    bv = min(DEFAULT_BLOCK_V, V)
    padR = (-R) % br
    padV = (-V) % bv
    if padV:
        logits = jnp.pad(logits, ((0, 0), (0, padV)),
                         constant_values=-1e30)
    if padR:
        logits = jnp.pad(logits, ((0, padR), (0, 0)))
        labels = jnp.pad(labels, (0, padR))
    out = cross_entropy_tiled(logits, labels, block_r=br,
                              block_v=bv, interpret=interpret)
    return out[:R]


def lm_loss(logits, targets, *, interpret=None, use_kernel=True):
    """Mean next-token NLL for [B, S, V] logits vs [B, S] targets."""
    B, S, V = logits.shape
    flat_l = logits.reshape(B * S, V)
    flat_t = targets.reshape(B * S)
    if use_kernel:
        nll = cross_entropy(flat_l, flat_t, interpret=interpret)
    else:
        nll = ref.cross_entropy(flat_l, flat_t)
    return jnp.mean(nll)

"""Fault injection: stochastic client-state simulation with graceful
degradation across every engine (DESIGN.md §16)."""
from repro.faults.replay import replay_corridor_faults, replay_fleet_faults
from repro.faults.runtime import (FaultPlan, FaultState, arrival_step,
                                  check_faults_reconcile, fold_admission,
                                  fold_readmits, initial_vehicles,
                                  make_fault_state)
from repro.faults.spec import (PROFILES, FaultSpec, faults_requested,
                               named_profile, resolve_faults,
                               scenario_faults)

__all__ = [
    "FaultPlan", "FaultSpec", "FaultState", "PROFILES", "arrival_step",
    "check_faults_reconcile", "faults_requested", "fold_admission",
    "fold_readmits", "initial_vehicles", "make_fault_state",
    "named_profile", "replay_corridor_faults", "replay_fleet_faults",
    "resolve_faults", "scenario_faults",
]

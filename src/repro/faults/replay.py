"""f64 host replay of the fault decisions (DESIGN.md §16).

The conformance oracle: re-drive the exact event timeline the planners
dry-run (``plan_fleet`` / ``plan_corridor`` — same ``_Timeline``, same
selection driving, same fault driving, same pop order) and return the
:class:`~repro.faults.runtime.FaultPlan` every engine must reproduce
decision-for-decision: which pops were dropped or blacked out, which
survived the staleness cap, how many local epochs each cycle ran, which
recovery sweeps re-admitted whom, and every straggler multiplier.

Planner discipline applies (rule FLT001, the faults dual of PLN002):
everything here is pure f64 numpy over the host timeline — no jax, no
device state, no engine imports.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.channel import ChannelParams, CorridorMobility, Mobility
from repro.faults.runtime import (FaultPlan, arrival_step, initial_vehicles,
                                  make_fault_state)
from repro.selection import make_selection_state


def replay_fleet_faults(p: ChannelParams, seed: int, rounds: int,
                        faults, l_iters: int = 5,
                        selection=None) -> Optional[FaultPlan]:
    """Re-drive the single-RSU fleet timeline under ``faults`` and return
    the decision residue (None when faults resolve to off)."""
    from repro.core.mafl import _Timeline

    flt = make_fault_state(faults, p, seed, rounds, l_iters)
    if flt is None:
        return None
    sel = make_selection_state(selection, p, Mobility(p), seed, rounds)
    tl = _Timeline(p, seed, cl_scale=flt.cl_scale)
    for k in initial_vehicles(sel, flt, p.K):
        tl.schedule(k, 0.0)

    for r in range(rounds):
        ev = tl.queue.pop()
        flt.on_pop(ev.vehicle, r)
        arrival_step(
            sel, flt, r=r, vehicle=ev.vehicle, time=ev.time,
            upload_delay=ev.upload_delay, train_delay=ev.train_delay,
            pending=len(tl.queue),
            schedule=lambda v, t=ev.time: tl.schedule(v, t))
        tl.prune()
    return flt.plan()


def replay_corridor_faults(p: ChannelParams, n_rsus: int, seed: int,
                           rounds: int, faults, l_iters: int = 1,
                           entry: str = "uniform", selection=None,
                           reconcile_every: int = 0
                           ) -> Optional[FaultPlan]:
    """Re-drive the corridor timeline under ``faults``.  Recovery sweeps
    run at reconcile boundaries only (``reconcile_every=0`` disables
    them — recovered vehicles stay parked), mirroring selection."""
    from repro.core.mafl import _Timeline

    flt = make_fault_state(faults, p, seed, rounds, l_iters,
                           recheck_every=reconcile_every)
    if flt is None:
        return None
    corridor = CorridorMobility(p, n_rsus, entry=entry)
    sel = make_selection_state(selection, p, corridor, seed, rounds,
                               resel_every=reconcile_every)
    tl = _Timeline(p, seed, distance_fn=corridor.distance,
                   cl_scale=flt.cl_scale)
    for k in initial_vehicles(sel, flt, p.K):
        tl.schedule(k, 0.0)

    for r in range(rounds):
        ev = tl.queue.pop()
        flt.on_pop(ev.vehicle, r)
        arrival_step(
            sel, flt, r=r, vehicle=ev.vehicle, time=ev.time,
            upload_delay=ev.upload_delay, train_delay=ev.train_delay,
            pending=len(tl.queue),
            schedule=lambda v, t=ev.time: tl.schedule(v, t))
        tl.prune()
    return flt.plan()

"""Fault-injection configuration (DESIGN.md §16).

A :class:`FaultSpec` describes the stochastic client-state processes the
host f64 planner samples into static per-round fault tables:

- **availability** — a Gilbert-Elliott on/off process evaluated at upload-
  cycle granularity: at each (re-)schedule attempt a live vehicle enters a
  blackout with probability ``p_blackout`` and stays dark for an
  exponential off-duration of mean ``blackout_mean`` seconds (the RSU's
  periodic re-admission sweep brings it back, see runtime);
- **mid-training dropout** — with probability ``p_dropout`` per cycle the
  upload never arrives: the slot is reclaimed and the vehicle is eligible
  for re-admission at the next sweep;
- **partial computation** — with probability ``p_partial`` per cycle the
  vehicle finishes only ``n_ep < l_iters`` local SGD steps inside its
  unchanged time budget (deadline semantics: the timeline is untouched,
  only the local update truncates);
- **straggler inflation** — a fixed fraction ``straggler_frac`` of the
  fleet computes ``straggler_mult`` x slower: the per-vehicle constant
  multiplier scales the Eq. 8 training delay everywhere it feeds the
  Eq. 3-6 event times;
- **staleness-cap discard** — graceful degradation at the RSU: an upload
  whose model is older than ``staleness_cap`` consumed rounds is
  discarded (the arrival still counts, the model update is skipped).

All probabilities are per upload cycle.  ``recheck_every`` is the fleet
engines' re-admission sweep cadence in consumed rounds (corridor worlds
re-admit at reconcile boundaries instead, mirroring selection).

The capability properties (``timeline_active`` / ``has_partial`` /
``has_cap``) are *spec-level* — independent of the seed — so the compiled
program structure is stable across seeds (rule FLT001's shape probe).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class FaultSpec:
    """Stochastic client-state processes, sampled per upload cycle."""
    p_dropout: float = 0.0
    p_blackout: float = 0.0
    blackout_mean: float = 0.0          # seconds (exponential off-duration)
    p_partial: float = 0.0
    straggler_frac: float = 0.0
    straggler_mult: float = 1.0
    staleness_cap: Optional[int] = None  # consumed rounds; None = keep all
    recheck_every: int = 8               # fleet re-admission sweep cadence

    def validate(self) -> "FaultSpec":
        for f in ("p_dropout", "p_blackout", "p_partial", "straggler_frac"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FaultSpec.{f}={v} must be in [0, 1]")
        if self.p_blackout and self.blackout_mean <= 0.0:
            raise ValueError("p_blackout > 0 needs blackout_mean > 0")
        if self.straggler_mult < 1.0:
            raise ValueError("straggler_mult < 1 would *deflate* compute "
                             "time; use a fresh ChannelParams instead")
        if self.staleness_cap is not None and self.staleness_cap < 1:
            raise ValueError("staleness_cap must be >= 1 round")
        if self.recheck_every < 0:
            raise ValueError("recheck_every must be >= 0 (0 disables "
                             "re-admission sweeps)")
        return self

    # -- spec-level capabilities (seed-independent, FLT001 shape probe) ----
    @property
    def is_noop(self) -> bool:
        """No fault process can ever fire — the engines must compile the
        exact legacy program (the TEL001-style contract)."""
        return (self.p_dropout == 0.0 and self.p_blackout == 0.0
                and self.p_partial == 0.0
                and (self.straggler_frac == 0.0
                     or self.straggler_mult == 1.0)
                and self.staleness_cap is None)

    @property
    def timeline_active(self) -> bool:
        """Dropout/blackout can suppress re-schedules (admission machinery
        needed in the compiled program)."""
        return self.p_dropout > 0.0 or self.p_blackout > 0.0

    @property
    def has_partial(self) -> bool:
        return self.p_partial > 0.0

    @property
    def has_cap(self) -> bool:
        return self.staleness_cap is not None


# -- named profiles (Scenario.faults) ---------------------------------------
PROFILES: dict[str, FaultSpec] = {
    # churn-heavy fleet: vehicles drop uploads and go dark sporadically,
    # stale survivors are discarded at 12 rounds
    "flaky": FaultSpec(p_dropout=0.08, p_blackout=0.04, blackout_mean=30.0,
                       staleness_cap=12),
    # coverage dead zones: long blackouts dominate (rush-hour corridor),
    # uploads themselves are reliable while covered
    "deadzone": FaultSpec(p_blackout=0.10, blackout_mean=60.0,
                          staleness_cap=16),
    # compute-constrained fleet: a third of the vehicles are 4x slower and
    # half the cycles finish only part of their local epochs
    "throttled": FaultSpec(p_partial=0.5, straggler_frac=0.3,
                           straggler_mult=4.0, staleness_cap=8),
}


def named_profile(name: str) -> FaultSpec:
    try:
        return PROFILES[name]
    except KeyError:
        known = ", ".join(sorted(PROFILES))
        raise KeyError(
            f"unknown fault profile {name!r}; known: {known}") from None


def resolve_faults(faults) -> Optional[FaultSpec]:
    """Normalize the engines' ``faults`` argument BEFORE any program-cache
    key is formed: every falsy or no-op spelling collapses to ``None`` so
    a faults-off run shares the legacy executable object bitwise (the
    TEL001-style contract, rule FLT001)."""
    if faults is None or faults is False or faults in ("off", "none", ""):
        return None
    spec = named_profile(faults) if isinstance(faults, str) else faults
    if not isinstance(spec, FaultSpec):
        raise TypeError(f"faults must be None, a profile name, or a "
                        f"FaultSpec, not {type(faults).__name__}")
    spec = spec.validate()
    return None if spec.is_noop else spec


def faults_requested(faults) -> bool:
    return resolve_faults(faults) is not None


def scenario_faults(sc) -> Optional[FaultSpec]:
    """Build the :class:`FaultSpec` from Scenario-style fields (``faults``
    profile name + ``faults_overrides`` replace-pairs) — None when the
    scenario carries no fault model."""
    name = getattr(sc, "faults", None)
    if not name:
        return None
    spec = named_profile(name) if isinstance(name, str) else name
    overrides = dict(getattr(sc, "faults_overrides", ()) or ())
    if overrides:
        spec = dataclasses.replace(spec, **overrides)
    return resolve_faults(spec)

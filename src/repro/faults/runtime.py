"""Host-side fault driver and the static plan the engines fold into their
compiled programs (DESIGN.md §16).

:class:`FaultState` is **the** definition of the fault semantics — the
serial engines drive one live, and the f64 planners (the batched engine's
consumed-set dry run, ``core.jit_engine.plan_fleet``,
``corridor.plan.plan_corridor``) replay an identical instance over the
identical timeline, so every engine makes byte-for-byte the same
drop/partial/inflation decisions.  The rules:

- **Draws advance per schedule attempt.**  Every vehicle owns one RNG
  stream (seeded from ``(seed, salt, vehicle)``); each schedule attempt —
  initial admission, post-pop re-schedule, selection re-admission, fault
  recovery — consumes exactly one fixed-size draw block, so the decision
  sequence depends only on the (engine-identical) timeline.
- **Suppression reuses the selection machinery.**  A dropped upload or a
  blackout is a suppressed re-schedule: the vehicle's slot goes +inf the
  same way a selection-parked vehicle's does, and the compiled engines
  fold ``sched`` into the admission table at ``[r, veh[r]]``.
- **Recovery is a periodic re-admission sweep.**  Every ``recheck_every``
  consumed arrivals (corridor worlds: every reconcile boundary) dark
  vehicles whose recovery time has passed re-enter at the boundary
  timestamp through the exact selection re-admission path.
- **The queue never empties.**  If refusing a schedule would leave zero
  in-flight uploads the fault is suppressed (draws are consumed first, so
  determinism is unaffected) — graceful degradation raises nothing.
- **Staleness-cap discard is a per-pop verdict.**  ``keep[r]`` compares
  the pop's model age in consumed rounds against the cap; a discarded
  arrival still counts as a round, only the model update is skipped.

:class:`FaultPlan` is the replay's static residue; its ``signature()``
feeds the program-cache keys (``faults=None`` contributes nothing, so the
off path shares the legacy executable object — rule FLT001).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.faults.spec import FaultSpec, resolve_faults

_SALT = 0xFA17


@dataclass(frozen=True)
class FaultPlan:
    """Everything static the compiled programs need about faults.

    Per-pop columns are length-``rounds`` tuples: ``sched[r]`` — was pop
    ``r``'s vehicle re-scheduled (False = dropped/blacked out),
    ``keep[r]`` — does its upload survive the staleness cap, ``epochs[r]``
    — local SGD steps its cycle actually ran, ``cause[r]`` — 0 none /
    1 dropout / 2 blackout.  ``readmits`` holds the recovery sweeps:
    ``(b, (v, ...))`` re-admits vehicles at boundary ``b`` (1-based
    consumed-arrival count, exactly the selection-boundary encoding)."""
    spec: FaultSpec
    cl_scale: tuple             # f64*K straggler train-delay multipliers
    admit0: tuple               # bool*K initially-live vehicles
    sched: tuple                # bool*rounds
    keep: tuple                 # bool*rounds
    epochs: tuple               # int*rounds
    cause: tuple                # int*rounds
    readmits: tuple             # ((b, (v, ...)), ...)

    @property
    def is_noop(self) -> bool:
        return self.spec.is_noop

    @property
    def timeline_active(self) -> bool:
        return self.spec.timeline_active

    def signature(self) -> tuple:
        """Hashable identity for program-cache keys (value-level, like
        the selection plan's — the decision columns are baked into the
        staged program as constants)."""
        return (self.spec, self.cl_scale, self.admit0, self.sched,
                self.keep, self.epochs, self.cause, self.readmits)

    def readmit_lists(self) -> dict:
        """``{boundary: [vehicle, ...]}`` for the engines' readmit fold."""
        return {b: list(vs) for b, vs in self.readmits}

    def tables(self, rounds: int) -> dict:
        """Fixed-shape padded fault tables (DESIGN.md §15 discipline):
        shapes depend only on ``(rounds, K)``, never on the seed, so
        per-world fault plans stack along a leading world axis (the
        FLT001 cross-seed shape probe pins this)."""
        K = len(self.cl_scale)
        readmit = np.zeros((rounds, K), bool)
        for b, vs in self.readmits:
            if b < rounds:
                readmit[b, list(vs)] = True
        return {
            "cl_scale": np.asarray(self.cl_scale, np.float64),
            "admit0": np.asarray(self.admit0, bool),
            "sched": np.asarray(self.sched, bool),
            "keep": np.asarray(self.keep, bool),
            "epochs": np.asarray(self.epochs, np.int32),
            "cause": np.asarray(self.cause, np.int8),
            "readmit": readmit,
        }

    def counts_table(self, l_iters: int) -> np.ndarray:
        """i32[rounds, 4] per-pop counter increments —
        (dropped, blackout, partial, discarded) — the rows the device
        metrics accumulators stream (DESIGN.md §14/§16)."""
        cause = np.asarray(self.cause)
        eps = np.asarray(self.epochs)
        keep = np.asarray(self.keep)
        return np.stack([cause == 1, cause == 2, eps < l_iters, ~keep],
                        axis=1).astype(np.int32)

    def counts(self, l_iters: int) -> dict:
        tot = self.counts_table(l_iters).sum(axis=0)
        return {"dropped_uploads": int(tot[0]),
                "blackout_rounds": int(tot[1]),
                "partial_rounds": int(tot[2]),
                "discarded_uploads": int(tot[3])}

    def summary(self, l_iters: int) -> dict:
        """The ``SimResult.extras['faults']`` payload — identical across
        engines by construction (conformance asserts it), plain
        JSON-serializable types only."""
        import dataclasses
        return {
            "spec": dataclasses.asdict(self.spec),
            "counts": self.counts(l_iters),
            "admit0": [bool(x) for x in self.admit0],
            "sched": [bool(x) for x in self.sched],
            "keep": [bool(x) for x in self.keep],
            "epochs": [int(x) for x in self.epochs],
            "cause": [int(x) for x in self.cause],
            "readmits": [(int(b), [int(v) for v in vs])
                         for b, vs in self.readmits],
            "n_stragglers": int(sum(1 for s in self.cl_scale if s != 1.0)),
        }


class FaultState:
    """Live fault driver over one simulation timeline (f64 host numpy).

    ``recheck_every`` overrides the spec's sweep cadence (the corridor
    engines pass their reconcile period, mirroring selection's
    ``resel_every`` override)."""

    def __init__(self, spec: FaultSpec, p, seed: int, rounds: int,
                 l_iters: int, recheck_every: Optional[int] = None):
        self.spec = spec.validate()
        K = p.K
        self.K = K
        self.rounds = rounds
        self.l_iters = l_iters
        self.recheck = (recheck_every if recheck_every is not None
                        else spec.recheck_every)
        rng0 = np.random.default_rng([int(seed), _SALT, 0])
        slow = rng0.random(K) < spec.straggler_frac
        self.cl_scale = np.where(slow, float(spec.straggler_mult), 1.0)
        self._rng = [np.random.default_rng([int(seed), _SALT, 1, v])
                     for v in range(K)]
        self._dark = np.zeros(K, bool)
        self._t_rec = np.zeros(K)
        self._ep = np.full(K, l_iters, np.int64)
        self._dl = np.full(K, -1, np.int64)       # last (re-)schedule round
        # per-pop decision records
        self.admit0 = np.ones(K, bool)
        self._sched = np.ones(rounds, bool)
        self._keep = np.ones(rounds, bool)
        self._eps = np.full(rounds, l_iters, np.int64)
        self._cause = np.zeros(rounds, np.int64)
        self._readmits: list = []

    # -- draws --------------------------------------------------------------
    def _assign_ep(self, v: int, u) -> None:
        n = self.l_iters
        if self.spec.p_partial and u[3] < self.spec.p_partial:
            n = 1 + int(u[4] * self.l_iters)
        self._ep[v] = min(max(n, 1), self.l_iters)

    # -- timeline hooks ------------------------------------------------------
    def gate(self, v: int, t: float, r: int, pending: int) -> bool:
        """One schedule attempt for vehicle ``v`` at time ``t`` (pop round
        ``r``; ``-1`` = initial admission).  ``pending`` is the number of
        other in-flight uploads — zero forbids suppression (force-live).
        Consumes one draw block; returns whether the schedule happens."""
        sp = self.spec
        u = self._rng[v].random(5)
        cause = 0
        if sp.p_blackout and u[0] < sp.p_blackout:
            cause = 2
            t_rec = t + sp.blackout_mean * float(-np.log1p(-u[1]))
        elif sp.p_dropout and u[2] < sp.p_dropout:
            cause, t_rec = 1, t
        if cause and pending <= 0:
            cause = 0                        # force-live: never stall
        if cause:
            self._dark[v] = True
            self._t_rec[v] = t_rec
            if r < 0:
                self.admit0[v] = False
            else:
                self._sched[r] = False
                self._cause[r] = cause
            return False
        self._assign_ep(v, u)
        self._dl[v] = r
        return True

    def on_pop(self, v: int, r: int) -> tuple:
        """Pop ``r`` consumed vehicle ``v``'s upload: the staleness-cap
        verdict and the cycle's epoch count."""
        stale = r - int(self._dl[v])
        keep = (self.spec.staleness_cap is None
                or stale <= self.spec.staleness_cap)
        self._keep[r] = keep
        self._eps[r] = self._ep[v]
        return keep, int(self._ep[v])

    def is_dark(self, v: int) -> bool:
        return bool(self._dark[v])

    def epoch_of(self, v: int) -> int:
        """Epoch count of vehicle ``v``'s in-flight cycle (assigned at its
        schedule; valid until the pop's gate draws the next cycle — one
        in-flight upload per vehicle, so this is unambiguous)."""
        return int(self._ep[v])

    def note_readmit(self, v: int, r: int) -> None:
        """A selection boundary re-admitted live vehicle ``v`` at pop
        ``r`` — a fresh cycle needs a fresh draw block."""
        u = self._rng[v].random(5)
        self._assign_ep(v, u)
        self._dl[v] = r

    def recoveries(self, total: int, t: float, sel_mask) -> list:
        """Re-admission sweep after consumed arrival ``total`` (1-based):
        dark vehicles whose recovery time has passed (and whom selection
        currently admits) re-enter at ``t``."""
        if (not self.recheck or total % self.recheck != 0
                or total >= self.rounds):
            return []
        out = [int(v) for v in np.flatnonzero(self._dark)
               if self._t_rec[v] <= t
               and (sel_mask is None or sel_mask[v])]
        for v in out:
            self._dark[v] = False
            u = self._rng[v].random(5)
            self._assign_ep(v, u)
            self._dl[v] = total - 1
        if out:
            self._readmits.append((total, tuple(out)))
        return out

    def force_initial(self, v: int) -> None:
        """Initial admission left zero vehicles live: force ``v`` in
        (its draws were already consumed, determinism unaffected)."""
        self._dark[v] = False
        self.admit0[v] = True

    # -- residue -------------------------------------------------------------
    def plan(self) -> FaultPlan:
        return FaultPlan(
            spec=self.spec,
            cl_scale=tuple(float(x) for x in self.cl_scale),
            admit0=tuple(bool(x) for x in self.admit0),
            sched=tuple(bool(x) for x in self._sched),
            keep=tuple(bool(x) for x in self._keep),
            epochs=tuple(int(x) for x in self._eps),
            cause=tuple(int(x) for x in self._cause),
            readmits=tuple(self._readmits))


# ---------------------------------------------------------------------------
# composition with selection — one shared arrival step for every driver
# ---------------------------------------------------------------------------
def initial_vehicles(sel, flt, K: int) -> list:
    """Vehicles to schedule at t=0 under both admission layers: the
    selection mask first, then the availability gate (index-ascending,
    exactly the per-engine legacy order).  Never returns an empty list."""
    base = (list(range(K)) if sel is None else sel.initial_vehicles())
    if flt is None:
        return base
    out = []
    for v in base:
        if flt.gate(v, 0.0, -1, pending=K):
            out.append(v)
        elif sel is not None:
            sel.in_flight[v] = False
    if not out and base:
        v = base[0]
        flt.force_initial(v)
        if sel is not None:
            sel.in_flight[v] = True
        out = [v]
    return out


def arrival_step(sel, flt, *, r: int, vehicle: int, time: float,
                 upload_delay: float, train_delay: float, pending: int,
                 schedule, readmit=None) -> None:
    """The selection+fault re-scheduling composition for one consumed
    arrival.  The caller pops, calls ``flt.on_pop(vehicle, r)`` for the
    staleness verdict, aggregates, then calls this.

    ``schedule(v)`` re-enters vehicle ``v``'s next cycle at ``time``;
    ``readmit(v)`` (default ``schedule``) additionally does the caller's
    boundary bookkeeping (the planners' ``last_pop[v] = r``).  ``pending``
    is the in-flight upload count *after* this pop."""
    if readmit is None:
        readmit = schedule
    resched = True if sel is None else sel.on_arrival(
        vehicle, upload_delay, train_delay)
    if resched and flt is not None:
        resched = flt.gate(vehicle, time, r, pending)
        if not resched and sel is not None:
            sel.in_flight[vehicle] = False
    if resched:
        schedule(vehicle)
    if sel is not None:
        for v in sel.maybe_reselect(r + 1, time):
            if flt is not None and flt.is_dark(v):
                # still dark: stays parked until a recovery sweep
                sel.in_flight[v] = False
                continue
            if flt is not None:
                flt.note_readmit(v, r)
            readmit(v)
    if flt is not None:
        for v in flt.recoveries(r + 1, time,
                                None if sel is None else sel.mask):
            if sel is not None:
                sel.in_flight[v] = True
            readmit(v)


# ---------------------------------------------------------------------------
# engine folds (static, host-side — consumed before staging)
# ---------------------------------------------------------------------------
def fold_admission(adm_tab, flt_plan, veh) -> np.ndarray:
    """AND the fault plan's per-pop suppression column into the [M, K]
    admission table at ``[r, veh[r]]`` (``veh[r]`` is static, so only the
    popped vehicle's entry ever matters)."""
    adm = np.array(adm_tab, bool, copy=True)
    sched = np.asarray(flt_plan.sched, bool)
    rs = np.flatnonzero(~sched)
    adm[rs, np.asarray(veh)[rs]] = False
    return adm


def fold_readmits(sel_plan, flt_plan) -> dict:
    """Merge selection re-admissions and fault recovery sweeps into one
    ``{boundary: [vehicle, ...]}`` map for the engines' readmit fold."""
    out: dict = {}
    if sel_plan is not None:
        for b, newly, _ in sel_plan.boundaries:
            if newly:
                out[b] = list(newly)
    if flt_plan is not None:
        for b, vs in flt_plan.readmits:
            out.setdefault(b, [])
            out[b] = sorted(set(out[b]) | set(vs))
    return out


def check_faults_reconcile(spec, mode: str) -> None:
    """Shared corridor-engine guard (the faults dual of
    ``check_reconcile_mode``): availability faults + EMA reconcile cannot
    coexist — a recovery re-admission download must be RSU-independent,
    which only the fedavg reconcile provides (DESIGN.md §16)."""
    spec = resolve_faults(spec)
    if spec is not None and spec.timeline_active and mode == "ema":
        raise ValueError(
            "fault injection with reconcile_mode='ema' is unsupported: "
            "EMA keeps distinct post-reconcile cohorts, so a recovery "
            "re-admission download is RSU-dependent and the one-row-per-"
            "round snapshot ring cannot represent it (DESIGN.md §16) — "
            "use 'fedavg'")


def make_fault_state(faults, p, seed: int, rounds: int, l_iters: int,
                     recheck_every: Optional[int] = None
                     ) -> Optional[FaultState]:
    """Normalize the engines' ``faults`` argument: every falsy/no-op
    spelling stays ``None`` (legacy path, zero fault machinery), a profile
    name or :class:`FaultSpec` becomes a live driver."""
    spec = resolve_faults(faults)
    if spec is None:
        return None
    return FaultState(spec, p, seed, rounds, l_iters,
                      recheck_every=recheck_every)

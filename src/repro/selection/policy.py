"""Vehicle-selection policies (DESIGN.md §11).

The source paper admits every covered vehicle; its sequels show that
*selecting* participants improves both accuracy and wall-clock — by
mobility/compute/data score (arXiv:2304.02832) or under per-RSU resource
budgets (arXiv:2210.15496).  This module defines the policy layer every
engine consumes:

- ``admit-all``     — the paper baseline; provably a no-op (golden traces).
- ``weighted-topk`` — score = normalized data amount x compute capability x
                      predicted residence time (boundary crossings), top-k
                      per RSU.
- ``budget``        — admit cheapest-estimated-upload-cost first until the
                      per-RSU upload-slot budget (seconds of airtime per
                      cycle) is exhausted.
- ``eps-bandit``    — epsilon-greedy over per-vehicle historical marginal
                      contribution, re-drawn every selection epoch.

Every scoring input is **timeline-pure** (DESIGN.md §3): data volumes and
CPU frequencies are Table-I constants, residence times and distances are
pure functions of time, and the bandit reward is the paper's own delay
weight ``gamma^(C_u-1) * zeta^(C_l-1)`` — the timeline-measurable surrogate
of an upload's marginal model impact.  A reward derived from measured
accuracy would make the event timeline depend on training, destroying the
host-plans/device-executes architecture all four engines rest on; the
deviation is recorded in DESIGN.md §11.

Decisions therefore replay identically on the host f64 planner and are
folded into the compiled programs as static admission masks; the device
engines re-derive only the bandit *state* (in f32, cross-checked by the
divergence guard).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

POLICIES = ("admit-all", "weighted-topk", "budget", "eps-bandit")


@dataclass(frozen=True)
class SelectionSpec:
    """Hashable policy selector + parameters (rides in program-cache keys).

    ``k`` is the per-RSU admission cap (weighted-topk / eps-bandit);
    ``budget`` the per-RSU upload-airtime budget in seconds (budget policy);
    ``eps`` the bandit exploration probability; ``resel_every`` the
    re-selection epoch in rounds (single-RSU worlds; corridor worlds
    re-score at every reconcile boundary instead)."""
    policy: str = "admit-all"
    k: Optional[int] = None
    budget: Optional[float] = None
    eps: float = 0.1
    resel_every: Optional[int] = None

    @property
    def is_noop(self) -> bool:
        """True when admission can never differ from the paper baseline —
        the engines then compile the exact legacy program (bitwise golden
        guarantee)."""
        return self.policy == "admit-all"

    def validate(self) -> "SelectionSpec":
        if self.policy not in POLICIES:
            raise ValueError(f"unknown selection policy {self.policy!r}; "
                             f"expected one of {POLICIES}")
        if self.policy in ("weighted-topk", "eps-bandit") and \
                (self.k is None or self.k < 1):
            raise ValueError(f"policy {self.policy!r} needs k >= 1")
        if self.policy == "budget" and \
                (self.budget is None or self.budget <= 0):
            raise ValueError("policy 'budget' needs a positive upload-slot "
                             "budget (seconds of airtime per cycle)")
        if self.policy == "eps-bandit" and not (0.0 <= self.eps <= 1.0):
            raise ValueError("eps must be in [0, 1]")
        return self


@dataclass
class SelectionContext:
    """Per-vehicle features at one decision instant — everything a policy
    may read.  All arrays are length K; ``rng`` is the decision-epoch
    generator (seeded from (seed, epoch), so decisions are deterministic
    under a fixed seed)."""
    t: float
    data: np.ndarray          # f64[K] D_i, images carried (Table I)
    compute: np.ndarray       # f64[K] delta_i, CPU cycles/s (Table I)
    residence: np.ndarray     # f64[K] predicted seconds to next boundary
    upload_cost: np.ndarray   # f64[K] estimated upload seconds (mean gain)
    in_coverage: np.ndarray   # bool[K]
    serving: np.ndarray       # i64[K] serving RSU index (0 when single-RSU)
    n_rsus: int
    rng: np.random.Generator

    @property
    def K(self) -> int:
        return len(self.data)

    def groups(self):
        """Yield ``(rsu_index, member_index_array)`` over in-coverage
        vehicles, RSU-ascending — the deterministic iteration order every
        per-RSU policy uses."""
        cov = np.flatnonzero(self.in_coverage)
        for j in range(self.n_rsus):
            yield j, cov[self.serving[cov] == j]


def _norm(x: np.ndarray) -> np.ndarray:
    m = float(np.max(x)) if len(x) else 0.0
    return x / m if m > 0 else np.ones_like(x)


@dataclass
class BanditState:
    """Per-vehicle reward accumulators, carried through the device scan
    (f32 there; f64 here on the host — the divergence guard compares)."""
    rew_sum: np.ndarray       # f64[K]
    rew_cnt: np.ndarray       # f64[K]

    @classmethod
    def zeros(cls, K: int) -> "BanditState":
        return cls(np.zeros(K), np.zeros(K))


class SelectionPolicy:
    """Pure decision rule: features -> admission mask.  Stateless except
    for the bandit, whose accumulators the engines carry."""

    name = "?"

    def init_state(self, K: int):
        return None

    def observe(self, state, vehicle: int, reward: float):
        """Fold one consumed arrival's reward (bandit only)."""
        return state

    def mask(self, ctx: SelectionContext, state) -> np.ndarray:
        raise NotImplementedError


class AdmitAll(SelectionPolicy):
    name = "admit-all"

    def mask(self, ctx, state):
        return ctx.in_coverage.copy()


class WeightedTopK(SelectionPolicy):
    """arXiv:2304.02832's ingredients: score each vehicle by normalized
    data amount x compute capability x predicted residence time, admit the
    top ``k`` per RSU."""

    name = "weighted-topk"

    def __init__(self, k: int):
        self.k = k

    def scores(self, ctx) -> np.ndarray:
        return (_norm(ctx.data) * _norm(ctx.compute)
                * _norm(ctx.residence))

    def mask(self, ctx, state):
        score = self.scores(ctx)
        out = np.zeros(ctx.K, bool)
        for _, g in ctx.groups():
            if len(g):
                # descending score, index-ascending tie-break
                order = g[np.lexsort((g, -score[g]))]
                out[order[:self.k]] = True
        return out


class BudgetPolicy(SelectionPolicy):
    """arXiv:2210.15496's binding constraint: admission under a per-RSU
    resource budget.  Each vehicle's cost is its estimated upload airtime
    at the decision instant (mean channel gain); vehicles are admitted
    cheapest-first until the budget is exhausted."""

    name = "budget"

    def __init__(self, budget: float):
        self.budget = budget

    def mask(self, ctx, state):
        cost = ctx.upload_cost
        out = np.zeros(ctx.K, bool)
        for _, g in ctx.groups():
            order = g[np.lexsort((g, cost[g]))]
            spent = 0.0
            for v in order:
                if spent + cost[v] > self.budget:
                    break
                out[v] = True
                spent += cost[v]
        return out


class EpsBandit(SelectionPolicy):
    """Epsilon-greedy over per-vehicle historical mean contribution:
    with probability ``eps`` the epoch explores (uniform k-subset per RSU),
    otherwise it exploits the top ``k`` by mean reward, with never-tried
    vehicles optimistically preferred."""

    name = "eps-bandit"

    def __init__(self, k: int, eps: float):
        self.k = k
        self.eps = eps

    def init_state(self, K: int):
        return BanditState.zeros(K)

    def observe(self, state: BanditState, vehicle: int, reward: float):
        state.rew_sum[vehicle] += reward
        state.rew_cnt[vehicle] += 1.0
        return state

    def mask(self, ctx, state: BanditState):
        out = np.zeros(ctx.K, bool)
        explore = bool(ctx.rng.random() < self.eps)
        mean = np.where(state.rew_cnt > 0,
                        state.rew_sum / np.maximum(state.rew_cnt, 1.0),
                        np.inf)                         # optimistic init
        for _, g in ctx.groups():
            if not len(g):
                continue
            if explore:
                out[ctx.rng.permutation(g)[:self.k]] = True
            else:
                order = g[np.lexsort((g, -mean[g]))]
                out[order[:self.k]] = True
        return out


def make_policy(spec: SelectionSpec) -> SelectionPolicy:
    spec.validate()
    if spec.policy == "admit-all":
        return AdmitAll()
    if spec.policy == "weighted-topk":
        return WeightedTopK(spec.k)
    if spec.policy == "budget":
        return BudgetPolicy(spec.budget)
    return EpsBandit(spec.k, spec.eps)

"""Host-side selection driver and the static plan the device engines fold
into their compiled programs (DESIGN.md §11).

:class:`SelectionState` is **the** definition of the selection semantics —
the serial engines drive one live, and the f64 planners (the batched
engine's consumed-set dry run, ``core.jit_engine.plan_fleet``,
``corridor.plan.plan_corridor``) replay an identical instance over the
identical timeline, so every engine makes byte-for-byte the same admission
decisions.  The rules:

- **Mask applies at (re-)schedule time.**  A vehicle not admitted when its
  upload is consumed is *parked* — aggregated one last time (in-flight
  uploads drain; they were admitted when they downloaded) and then simply
  never re-scheduled, so it occupies no queue slot, no wave, and no
  minibatch stack.
- **Epoch boundaries re-score.**  Every ``resel_every`` consumed arrivals
  (corridor worlds: every reconcile boundary) the policy re-decides at the
  boundary arrival's timestamp; the boundary arrival itself re-schedules
  under the *old* mask (its pop precedes the decision), newly admitted
  parked vehicles download the boundary round's model and re-enter the
  timeline at that instant.
- **At least one vehicle stays admitted** — an empty admission set would
  stall the event queue, so the lowest-indexed in-coverage vehicle is
  force-admitted if a policy returns none.

:class:`SelectionPlan` is the replay's static residue — initial mask,
per-boundary masks and re-admissions, and (bandit) the expected final
reward accumulators the device engines' divergence guards compare against.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.channel import ChannelParams, CorridorMobility, Mobility
from repro.channel.rate import shannon_rate
from repro.selection.policy import (BanditState, SelectionContext,
                                    SelectionSpec, make_policy)


@dataclass(frozen=True)
class SelectionPlan:
    """Everything static the compiled programs need about admission.

    ``boundaries`` holds one entry per selection epoch boundary:
    ``(b, newly, mask)`` — after consumed arrival ``b`` (1-based) the
    admission mask becomes ``mask`` and the parked vehicles in ``newly``
    are scheduled at the boundary timestamp.  ``admit0`` is the t=0 mask.
    """
    spec: SelectionSpec
    admit0: tuple               # bool*K
    boundaries: tuple           # ((b, newly tuple, mask tuple), ...)

    @property
    def is_noop(self) -> bool:
        """No admission op can ever fire: all masks all-ones, no
        re-admissions, no carried state — the engines compile the exact
        legacy program."""
        return (self.spec.policy != "eps-bandit" and all(self.admit0)
                and all(not n and all(m) for _, n, m in self.boundaries))

    def mask_for_round(self, r: int) -> np.ndarray:
        """Admission mask in effect for (0-based) pop ``r`` — the decision
        at boundary ``b`` governs re-schedules of pops ``r >= b``."""
        mask = self.admit0
        for b, _, m in self.boundaries:
            if b <= r:
                mask = m
            else:
                break
        return np.asarray(mask, bool)

    def signature(self) -> tuple:
        """Hashable identity for program-cache keys."""
        return (self.spec, self.admit0, self.boundaries)

    def tables(self, rounds: int) -> dict:
        """Fixed-shape padded admission tables (DESIGN.md §15): the ragged
        ``boundaries`` tuple re-encoded as ``[rounds, K]`` bool arrays so
        per-world selection plans stack along a leading world axis —
        ``mask[r]`` gates the re-schedule of pop ``r`` (exactly
        :meth:`mask_for_round`), ``readmit[b, v]`` marks vehicle ``v``
        re-admitted at boundary ``b``.  A policy-free world is the
        all-True/all-False table pair, so heterogeneous batches mix
        selection and no-selection worlds at stable shapes."""
        K = len(self.admit0)
        mask = np.stack([self.mask_for_round(r) for r in range(rounds)])
        readmit = np.zeros((rounds, K), bool)
        for b, newly, _ in self.boundaries:
            if b < rounds:
                readmit[b, list(newly)] = True
        return {"mask": mask, "readmit": readmit}

    def summary(self) -> dict:
        """The ``SimResult.extras['selection']`` payload — identical
        across engines by construction (conformance asserts it), plain
        JSON-serializable types only."""
        import dataclasses
        return {
            "policy": self.spec.policy,
            "spec": dataclasses.asdict(self.spec),
            "admit0": list(self.admit0),
            "decisions": [(b, list(n), list(m))
                          for b, n, m in self.boundaries],
            "n_admitted_final": int(sum(self.mask_for_round(10 ** 9))),
        }


class SelectionState:
    """Live selection driver over one simulation timeline (f64).

    ``mobility`` is the world's :class:`Mobility` or
    :class:`CorridorMobility`; ``resel_every`` overrides the spec's epoch
    (the corridor engines pass their reconcile period).  The driver is
    deliberately cheap — decisions are O(K log K) numpy at epoch
    boundaries only."""

    def __init__(self, spec: SelectionSpec, p: ChannelParams, mobility,
                 seed: int, rounds: int,
                 resel_every: Optional[int] = None):
        self.spec = spec.validate()
        self.policy = make_policy(spec)
        self.p = p
        self.mobility = mobility
        self.n_rsus = getattr(mobility, "n_rsus", 1)
        self.seed = seed
        self.rounds = rounds
        self.resel_every = (resel_every if resel_every is not None
                            else spec.resel_every)
        if spec.policy == "eps-bandit" and not self.resel_every:
            raise ValueError(
                "eps-bandit needs a re-selection epoch: set resel_every "
                "(single-RSU) or run it on a corridor scenario (re-scores "
                "at every reconcile boundary)")
        K = p.K
        self.K = K
        idx = np.arange(1, K + 1)                     # 1-based (Table I)
        self._data = np.array([p.data_count(i) for i in idx], float)
        self._compute = np.array([p.delta(i) for i in idx], float)
        self.state = self.policy.init_state(K)
        self.in_flight = np.zeros(K, bool)
        self._epoch = 0
        self._decisions: list = []
        self.mask = self._decide(0.0)
        self.admit0 = self.mask.copy()

    # -- feature extraction (timeline-pure) --------------------------------
    def _ctx(self, t: float) -> SelectionContext:
        p = self.p
        arange = np.arange(self.K)
        mob = self.mobility
        residence = np.asarray(mob.next_boundary_crossing(arange, t)) - t
        if isinstance(mob, CorridorMobility):
            serving = np.asarray(mob.serving_rsu(arange, t), np.int64)
        else:
            serving = np.zeros(self.K, np.int64)
        dist = np.asarray(mob.distances(t))
        # estimated upload airtime at mean channel gain (E|g|^2 = 1);
        # shannon_rate is Eq. 5 (vector-safe), the division is Eq. 6
        # (rate.upload_delay's scalar max() doesn't broadcast)
        rate = shannon_rate(p, 1.0, dist)
        upload_cost = p.model_bits / np.maximum(rate, 1e-12)
        return SelectionContext(
            t=t, data=self._data, compute=self._compute,
            residence=residence, upload_cost=upload_cost,
            in_coverage=np.ones(self.K, bool), serving=serving,
            n_rsus=self.n_rsus,
            rng=np.random.default_rng([self.seed, self._epoch]))

    def _decide(self, t: float) -> np.ndarray:
        ctx = self._ctx(t)
        mask = np.asarray(self.policy.mask(ctx, self.state), bool)
        if not mask.any():                      # never stall the queue:
            # force-admit the lowest-indexed in-coverage vehicle
            cov = np.flatnonzero(ctx.in_coverage)
            mask[int(cov[0]) if len(cov) else 0] = True
        self._epoch += 1
        return mask

    # -- timeline hooks ----------------------------------------------------
    def initial_vehicles(self) -> list[int]:
        """Vehicles to schedule at t=0 (index-ascending)."""
        out = [int(v) for v in np.flatnonzero(self.admit0)]
        self.in_flight[out] = True
        return out

    def on_arrival(self, vehicle: int, upload_delay: float,
                   train_delay: float) -> bool:
        """One consumed upload: fold the bandit reward and report whether
        the vehicle re-schedules (current mask) or parks."""
        if isinstance(self.state, BanditState):
            rew = (self.p.gamma ** (upload_delay - 1.0)
                   * self.p.zeta ** (train_delay - 1.0))    # Eqs. 7, 9
            self.policy.observe(self.state, vehicle, rew)
        self.in_flight[vehicle] = False
        if self.mask[vehicle]:
            self.in_flight[vehicle] = True
            return True
        return False

    def maybe_reselect(self, total: int, t: float) -> list[int]:
        """Epoch boundary after consumed arrival ``total`` (1-based):
        re-decide and return the parked vehicles to schedule at ``t``."""
        if (not self.resel_every or total % self.resel_every != 0
                or total >= self.rounds):
            return []
        self.mask = self._decide(t)
        newly = [int(v) for v in np.flatnonzero(self.mask
                                                & ~self.in_flight)]
        self.in_flight[newly] = True
        self._decisions.append(
            (total, tuple(newly), tuple(bool(x) for x in self.mask)))
        return newly

    # -- residue -----------------------------------------------------------
    def plan(self) -> SelectionPlan:
        return SelectionPlan(
            spec=self.spec,
            admit0=tuple(bool(x) for x in self.admit0),
            boundaries=tuple(self._decisions))

    def bandit_expectation(self):
        """(rew_sum, rew_cnt) f64 the device guard compares, or None."""
        if isinstance(self.state, BanditState):
            return (self.state.rew_sum.copy(), self.state.rew_cnt.copy())
        return None


def check_reconcile_mode(spec, mode: str) -> None:
    """Shared corridor-engine guard: selection + EMA reconcile cannot
    coexist (both the device engine and the serial reference call this, so
    they always accept exactly the same scenario set).  ``spec`` is the
    engines' raw ``selection`` argument — None, a policy-name string, or a
    :class:`SelectionSpec`."""
    if isinstance(spec, str):
        spec = SelectionSpec(policy=spec).validate()
    if spec is not None and not spec.is_noop and mode == "ema":
        raise ValueError(
            "vehicle selection with reconcile_mode='ema' is unsupported: "
            "EMA keeps distinct post-reconcile cohorts, so a re-admission "
            "download is RSU-dependent and the one-row-per-round snapshot "
            "ring cannot represent it (DESIGN.md §11) — use 'fedavg'")


def scenario_spec(sc) -> Optional[SelectionSpec]:
    """Build a :class:`SelectionSpec` from Scenario-style fields
    (``selection``, ``selection_k``, ``selection_budget``,
    ``selection_eps``, ``resel_every``) — None when the scenario carries no
    selection policy."""
    name = getattr(sc, "selection", None)
    if not name:
        return None
    return SelectionSpec(
        policy=name, k=getattr(sc, "selection_k", None),
        budget=getattr(sc, "selection_budget", None),
        eps=getattr(sc, "selection_eps", 0.1),
        resel_every=getattr(sc, "resel_every", None)).validate()


def make_selection_state(selection, p: ChannelParams, mobility, seed: int,
                         rounds: int,
                         resel_every: Optional[int] = None
                         ) -> Optional[SelectionState]:
    """Normalize the engines' ``selection`` argument: None stays None
    (legacy path, zero selection machinery), a policy-name string becomes a
    default spec, a :class:`SelectionSpec` is used as-is."""
    if selection is None:
        return None
    spec = (SelectionSpec(policy=selection)
            if isinstance(selection, str) else selection)
    return SelectionState(spec, p, mobility, seed, rounds,
                          resel_every=resel_every)

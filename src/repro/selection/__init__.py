"""Device-resident vehicle-selection subsystem (DESIGN.md §11): pluggable
admission policies every engine consumes as compiled masks."""
from repro.selection.policy import (POLICIES, AdmitAll, BanditState,
                                    BudgetPolicy, EpsBandit,
                                    SelectionContext, SelectionPolicy,
                                    SelectionSpec, WeightedTopK,
                                    make_policy)
from repro.selection.runtime import (SelectionPlan, SelectionState,
                                     check_reconcile_mode,
                                     make_selection_state, scenario_spec)

__all__ = ["POLICIES", "AdmitAll", "BanditState", "BudgetPolicy",
           "EpsBandit", "SelectionContext", "SelectionPolicy",
           "SelectionSpec", "WeightedTopK", "make_policy", "SelectionPlan",
           "SelectionState", "make_selection_state", "scenario_spec",
           "check_reconcile_mode"]

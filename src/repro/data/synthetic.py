"""Synthetic datasets.

The container is offline, so MNIST itself is unavailable; ``synth_mnist``
generates a deterministic drop-in: 10 classes of 28x28 grayscale images built
from smooth random class prototypes + per-sample jitter/shift/noise.  A small
CNN separates it at >95% accuracy within a few hundred SGD steps, matching the
paper's use of MNIST as an easy witness task.  The substitution is recorded in
DESIGN.md §6 and EXPERIMENTS.md — all paper claims we validate are *relative*
(MAFL vs AFL, curve shapes), not absolute MNIST numbers.
"""
from __future__ import annotations

import numpy as np


def _prototypes(rng: np.random.Generator, n_classes: int) -> np.ndarray:
    """Smooth class prototypes: low-frequency random fields, unit contrast."""
    protos = []
    for _ in range(n_classes):
        coarse = rng.normal(size=(7, 7))
        img = np.kron(coarse, np.ones((4, 4)))          # 28x28 blocky
        img = _blur(img)
        img = (img - img.min()) / (np.ptp(img) + 1e-9)
        protos.append(img)
    return np.stack(protos)


def _blur(img: np.ndarray) -> np.ndarray:
    k = np.array([0.25, 0.5, 0.25])
    for ax in (0, 1):
        img = (np.take(img, np.arange(img.shape[ax]) - 1, axis=ax, mode="clip")
               * k[0]
               + img * k[1]
               + np.take(img, np.arange(img.shape[ax]) + 1, axis=ax,
                         mode="clip") * k[2])
    return img


def synth_mnist(n_train: int = 60000, n_test: int = 10000, seed: int = 0,
                n_classes: int = 10, noise: float = 0.25):
    """Returns (train_images, train_labels, test_images, test_labels);
    images are float32 [N, 28, 28, 1] in [0, 1]."""
    rng = np.random.default_rng(seed)
    protos = _prototypes(rng, n_classes)

    def make(n, rng):
        labels = rng.integers(0, n_classes, n)
        base = protos[labels]
        # per-sample random shift (+-2 px) and additive noise
        sx = rng.integers(-2, 3, n)
        sy = rng.integers(-2, 3, n)
        imgs = np.empty((n, 28, 28), np.float32)
        for shift_x in range(-2, 3):
            for shift_y in range(-2, 3):
                m = (sx == shift_x) & (sy == shift_y)
                if not m.any():
                    continue
                imgs[m] = np.roll(np.roll(base[m], shift_x, axis=1),
                                  shift_y, axis=2)
        imgs += rng.normal(scale=noise, size=imgs.shape).astype(np.float32)
        return np.clip(imgs, 0, 1)[..., None], labels.astype(np.int32)

    tr_i, tr_l = make(n_train, rng)
    te_i, te_l = make(n_test, np.random.default_rng(seed + 1))
    return tr_i, tr_l, te_i, te_l


def synth_tokens(n_seqs: int, seq_len: int, vocab: int, seed: int = 0):
    """Markov-ish synthetic token streams for transformer FL examples:
    each sequence follows a random sparse bigram table so there is real
    next-token signal to learn."""
    rng = np.random.default_rng(seed)
    n_next = min(8, vocab)
    table = rng.integers(0, vocab, size=(vocab, n_next))
    toks = np.empty((n_seqs, seq_len), np.int32)
    toks[:, 0] = rng.integers(0, vocab, n_seqs)
    for t in range(1, seq_len):
        choice = rng.integers(0, n_next, n_seqs)
        explore = rng.random(n_seqs) < 0.1
        nxt = table[toks[:, t - 1], choice]
        toks[:, t] = np.where(explore, rng.integers(0, vocab, n_seqs), nxt)
    return toks

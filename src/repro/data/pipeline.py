"""Token-stream pipeline for the transformer training driver: deterministic
shard-per-host batching with prefetch, emitting global batches that the
launcher shards over the ``data`` mesh axis."""
from __future__ import annotations

import numpy as np


class TokenPipeline:
    """Infinite iterator of (tokens [B, S+1]) next-token-prediction batches."""

    def __init__(self, corpus: np.ndarray, batch: int, seq_len: int,
                 seed: int = 0):
        assert corpus.ndim == 2 and corpus.shape[1] >= seq_len + 1
        self.corpus = corpus
        self.batch = batch
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)

    def __iter__(self):
        return self

    def __next__(self) -> np.ndarray:
        rows = self.rng.integers(0, len(self.corpus), self.batch)
        starts = self.rng.integers(
            0, self.corpus.shape[1] - self.seq_len, self.batch)
        return np.stack([self.corpus[r, s:s + self.seq_len + 1]
                         for r, s in zip(rows, starts)])

"""Per-vehicle data partitioning (Section V-A): vehicle i carries
D_i = 2250 + 3750*i images "randomly selected" from the training pool.
Optionally a Dirichlet non-IID split (beyond paper) for heterogeneity studies.
"""
from __future__ import annotations

import numpy as np

from repro.channel.params import ChannelParams
from repro.core.client import VehicleData


def partition_vehicles(images: np.ndarray, labels: np.ndarray,
                       params: ChannelParams, seed: int = 0,
                       scale: float = 1.0,
                       dirichlet_alpha: float | None = None,
                       max_per_vehicle: int | None = None
                       ) -> list[VehicleData]:
    """``scale`` shrinks every D_i proportionally (CPU-budget knob; relative
    data imbalance between vehicles — the thing the paper's Eq. 8 feeds on —
    is preserved exactly).  ``max_per_vehicle`` caps each shard's *storage*
    for K=100+ fleets (delays still use the uncapped Table-I D_i)."""
    rng = np.random.default_rng(seed)
    out = []
    for i1 in range(1, params.K + 1):
        d_i = max(int(params.data_count(i1) * scale), 8)
        if max_per_vehicle is not None:
            d_i = min(d_i, max_per_vehicle)
        if dirichlet_alpha is None:
            sel = rng.choice(len(labels), size=min(d_i, len(labels)),
                             replace=False)
        else:
            # class-skewed shard: sample class mix ~ Dirichlet(alpha)
            probs = rng.dirichlet([dirichlet_alpha] * 10)
            weights = probs[labels]
            weights = weights / weights.sum()
            sel = rng.choice(len(labels), size=min(d_i, len(labels)),
                             replace=False, p=weights)
        out.append(VehicleData(index=i1, images=images[sel],
                               labels=labels[sel]))
    return out

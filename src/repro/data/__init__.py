from repro.data.synthetic import synth_mnist, synth_tokens
from repro.data.partition import partition_vehicles
from repro.data.pipeline import TokenPipeline

__all__ = ["synth_mnist", "synth_tokens", "partition_vehicles",
           "TokenPipeline"]

"""repro.check — static invariant analyzers (DESIGN.md §13).

Three analyzer families guard the contracts the engines rely on but the
type system cannot see:

- ``pallas_race``: enumerates each registered kernel's grid against its
  output BlockSpec index maps and classifies it ``parallel-safe`` /
  ``sequential-axis-required`` / ``racy``; the per-backend legality
  verdict is what ``repro.kernels.dispatch.select_impl`` consults — there
  is no hand-maintained backend allowlist.
- ``boundary``: AST taint pass over the engine modules for host/device
  boundary leaks (host sync pulls, Python control flow on tracers, np.*
  on tracers, f64 in traced code, donated-buffer reuse) and the planner
  duals (no engine imports, no mid-plan precision drops).
- ``dtype_flow`` / ``plan_shapes``: staged-program probes — jaxpr-level
  bf16 storage-role verification and cross-seed plan-layout stability.

CLI: ``python -m repro.check src/ [--strict] [--format=json]
[--list-rules] [--no-probes]``.  Findings can be waived in place with
``# repro-check: waive[RULE] reason``.
"""
from repro.check.findings import RULES, Finding  # noqa: F401

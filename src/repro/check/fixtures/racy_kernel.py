"""A deliberately racy Pallas kernel: the output block is addressed by the
*inner* grid axis only, so the outer axis's cells collide on the same
block at non-consecutive row-major ranks — illegal on every compiled
backend (classification ``racy``, PAL001)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _racy_sum_kernel(x_ref, o_ref):
    # both (i, u) cells with the same u write block u — a read-modify-write
    # with no sequentialisable revisit order
    o_ref[...] = o_ref[...] + jnp.sum(x_ref[...])


def racy_sum(x, *, block_rows: int = 2, interpret: bool = True):
    """x: [R, U] -> [U'] partial sums; grid (R//br, U//bu) with the output
    indexed by u alone."""
    R, U = x.shape
    br, bu = block_rows, 1
    return pl.pallas_call(
        _racy_sum_kernel,
        grid=(R // br, U // bu),
        in_specs=[pl.BlockSpec((br, bu), lambda i, u: (i, u))],
        out_specs=pl.BlockSpec((bu,), lambda i, u: (u,)),
        out_shape=jax.ShapeDtypeStruct((U,), x.dtype),
        interpret=interpret,
    )(x)


def invoke():
    """Analyzer case: grid (4, 2) — output block u is visited at row-major
    ranks {u, u+2, u+4, u+6}: revisits, and not consecutive."""
    x = jnp.ones((8, 2), jnp.float32)
    return racy_sum(x, block_rows=2)

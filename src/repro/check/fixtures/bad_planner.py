"""A deliberately bad planner module: imports engine internals (PLN001)
and drops the f64 host timeline to f32 mid-plan (PLN002).  The test feeds
this source to the boundary checker under a planner path."""
from __future__ import annotations

import numpy as np

from repro.core.jit_engine import _get_program      # PLN001: engine import
import jax.numpy as jnp                             # PLN001: jax in planner


def plan_badly(times):
    t32 = times.astype(np.float32)                  # PLN002: precision drop
    order = np.argsort(t32)
    return order, np.asarray(t32, dtype="float32")  # PLN002 again

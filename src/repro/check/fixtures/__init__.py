"""Known-positive fixture corpus for the analyzer suite.

Each module here violates exactly the invariants its name says it does —
the test suite asserts the analyzers flag them (and nothing else).  The
directory is excluded from default ``repro.check`` scans (see
``repro.check.config.EXCLUDE_PARTS``): these are test subjects, not
product code.
"""

"""A deliberately leaky traced function: every class of host/device
boundary violation the BND rules cover, each on its own line so the test
can pin rule ids to line numbers."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("n",))
def leaky_step(qt, w, n):
    if qt.sum() > 0:                     # BND002: Python branch on tracer
        w = w * 0.5
    i = int(jnp.argmin(qt))              # BND003: host scalar pull
    t = qt[0].item()                     # BND003: host scalar pull
    mean = np.mean(w)                    # BND001: np.* on a tracer
    w64 = w.astype(jnp.float64)          # BND004: f64 in traced code
    for row in w:                        # BND002: Python for over tracer
        t = t + float(row.sum())         # BND003 (inside the loop)
    return w64 * mean + i + t + n


def donating_caller(w, upload):
    from repro.core.aggregation import mix_update_donated

    mixed = mix_update_donated(w, upload, 0.5)
    stale = upload + 1.0                 # BND005: read after donation
    return mixed, stale

import sys

from repro.check.runner import main

sys.exit(main())

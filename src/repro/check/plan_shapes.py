"""PLN003 probe: plan tables must be shape- and dtype-stable across seeds.

The compiled engines key their program caches on plan *layout* (shapes), not
plan *values*.  If a planner ever emitted a seed-dependent shape — a ragged
wave table, a pruned slot array — every seed would trigger a silent
recompile and the golden-digest fixtures would stop pinning one program.
This probe runs each planner twice with different seeds on a small fleet and
diffs the ndarray fields' ``(shape, dtype)`` signatures.

Exempt by design (documented in DESIGN.md §13): ``waves`` is a host-side
tuple consumed before staging (its length legitimately varies by seed — the
engines re-derive scan segments from it at trace time and cache per-layout),
and ``n_slots`` is a Python int folded into the layout key itself.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.check.findings import Finding

_EXEMPT = ("waves", "n_slots", "sel", "sel_bandit", "q0", "flt")
_PROBE_SEEDS = (0, 1)


def _signature(plan) -> dict:
    sig = {}
    for f in dataclasses.fields(plan):
        if f.name in _EXEMPT:
            continue
        v = getattr(plan, f.name)
        if isinstance(v, np.ndarray):
            sig[f.name] = (v.shape, str(v.dtype))
        else:
            sig[f.name] = (type(v).__name__,)
    # q0 is a dict of per-vehicle arrays; check its members individually
    for k, v in plan.q0.items():
        sig[f"q0[{k}]"] = (v.shape, str(v.dtype))
    return sig


def _diff(name: str, sigs: dict, findings: list, path: str,
          rule: str = "PLN003") -> None:
    base_seed = _PROBE_SEEDS[0]
    base = sigs[base_seed]
    for seed, sig in sigs.items():
        if seed == base_seed:
            continue
        for field in sorted(set(base) | set(sig)):
            a, b = base.get(field), sig.get(field)
            if a != b:
                findings.append(Finding(
                    rule, path, 0,
                    f"{name}: field {field!r} unstable across seeds "
                    f"(seed {base_seed}: {a}, seed {seed}: {b})"))


def _tables_signature(tabs: dict) -> dict:
    return {f"tables[{k}]": (np.asarray(v).shape, str(np.asarray(v).dtype))
            for k, v in tabs.items()}


def probe_plan_shapes() -> list[Finding]:
    """Run both planners across probe seeds; findings on any layout drift."""
    from repro.channel.params import ChannelParams
    from repro.core.jit_engine import plan_fleet
    from repro.core.sweep import stack_plan_tables
    from repro.corridor.plan import plan_corridor
    from repro.selection.policy import SelectionSpec

    findings: list[Finding] = []
    p = dataclasses.replace(ChannelParams(), K=5)

    sigs = {s: _signature(plan_fleet(p, seed=s, rounds=12))
            for s in _PROBE_SEEDS}
    _diff("plan_fleet", sigs, findings, "<probe:plan_fleet>")

    sigs = {s: _signature(plan_corridor(p, n_rsus=2, seed=s, rounds=12))
            for s in _PROBE_SEEDS}
    _diff("plan_corridor", sigs, findings, "<probe:plan_corridor>")

    # padded plan-table emissions (DESIGN.md §15): the sweep tier stacks
    # ``tables()`` across worlds, so the padded encodings must be
    # seed-stable too — including the selection tables, whose ragged
    # ``boundaries`` source is exactly the kind of data that drifts
    sigs = {s: _tables_signature(plan_fleet(p, seed=s, rounds=12).tables())
            for s in _PROBE_SEEDS}
    _diff("FleetPlan.tables", sigs, findings, "<probe:plan_fleet>")

    sigs = {s: _tables_signature(
        plan_corridor(p, n_rsus=2, seed=s, rounds=12).tables())
        for s in _PROBE_SEEDS}
    _diff("CorridorPlan.tables", sigs, findings, "<probe:plan_corridor>")

    spec = SelectionSpec(policy="weighted-topk", k=3, resel_every=4)
    plans = [plan_fleet(p, seed=s, rounds=12, selection=spec)
             for s in _PROBE_SEEDS]
    sigs = {s: _tables_signature(plan.sel.tables(12))
            for s, plan in zip(_PROBE_SEEDS, plans)}
    _diff("SelectionPlan.tables", sigs, findings, "<probe:selection>")

    # and the stacked batch itself: stack_plan_tables re-validates every
    # key's (shape, dtype) — a rejection of seed-stable plans means the
    # sweep tier could never mix these seeds in one world batch
    try:
        stack_plan_tables([plan.tables() for plan in plans])
    except ValueError as e:
        findings.append(Finding(
            "PLN003", "<probe:stack_plan_tables>", 0,
            f"stack_plan_tables rejected seed-stable plans: {e}"))

    # fault-table shape stability (rule FLT001, DESIGN.md §16): the padded
    # fault tables and the i32[rounds, 4] counter rows must depend only on
    # (rounds, K, l_iters), never on the seed, so the vmap tier can stack
    # per-world fault plans later
    from repro.faults import named_profile
    fspec = named_profile("flaky")

    def _fault_sig(flt_plan, rounds, l_iters):
        ct = flt_plan.counts_table(l_iters)
        return {**_tables_signature(flt_plan.tables(rounds)),
                "counts_table": (ct.shape, str(ct.dtype))}

    sigs = {s: _fault_sig(
        plan_fleet(p, seed=s, rounds=12, faults=fspec, l_iters=2).flt,
        12, 2) for s in _PROBE_SEEDS}
    _diff("FaultPlan.tables (fleet)", sigs, findings,
          "<probe:fault_tables>", rule="FLT001")

    sigs = {s: _fault_sig(
        plan_corridor(p, n_rsus=2, seed=s, rounds=12, faults=fspec,
                      reconcile_every=4).flt, 12, 1)
        for s in _PROBE_SEEDS}
    _diff("FaultPlan.tables (corridor)", sigs, findings,
          "<probe:fault_tables>", rule="FLT001")
    return findings

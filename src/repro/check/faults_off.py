"""FLT001 probe: ``faults=None`` must stage the exact legacy program.

The fault subsystem's hard contract (DESIGN.md §16) mirrors TEL001: any
falsy or no-op ``faults`` spelling is a *bitwise no-op* — the engines
normalize it to ``None`` through :func:`repro.faults.resolve_faults`
before the program-cache key is formed, so a faults-off run and a
pre-faults run share one executable object — not merely equivalent
programs, the same program.  This probe stages the real jit and corridor
quick worlds three ways (no faults, ``"off"``, the ``"flaky"`` profile)
and verifies

- ``resolve_faults`` collapses every falsy and no-op spelling (including
  an all-zero :class:`~repro.faults.spec.FaultSpec`) to ``None``,
- the off staging returns the *identical* compiled-program object the
  no-faults staging produced (cache identity — the strongest possible
  "same program" statement), and
- a live fault profile does NOT reuse that entry (a shared key would bake
  fault folds into clean runs or vice versa).

Like the telemetry-off probe, this exercises the engines' own
``_stage_run`` helpers on tiny synthetic worlds, so it checks the program
that would actually run, not a reconstruction of it.
"""
from __future__ import annotations

import dataclasses

from repro.check.findings import Finding

_PATH_JIT = "<probe:faults-off-jit>"
_PATH_COR = "<probe:faults-off-corridor>"


def _resolve_findings() -> list[Finding]:
    from repro.faults import FaultSpec, resolve_faults

    out = []
    for falsy in (None, False, "off", "none", "", FaultSpec()):
        if resolve_faults(falsy) is not None:
            out.append(Finding(
                "FLT001", "<probe:faults-off-resolve>", 0,
                f"resolve_faults({falsy!r}) did not return None — the "
                "falsy/no-op path must carry zero fault state"))
    return out


def _jit_findings() -> list[Finding]:
    from repro.check.dtype_flow import _small_fleet
    from repro.core.jit_engine import _stage_run

    veh, p = _small_fleet()
    kw = dict(scheme="mafl", rounds=6, l_iters=1, lr=0.05, params=p,
              seed=0, eval_every=3, use_kernel=False, init_params=None,
              interpretation="mixing", batch_size=32, mesh=None,
              selection=None, flat=True, ring_dtype="f32")
    base, *_ = _stage_run(veh, faults=None, **kw)
    off, *_ = _stage_run(veh, faults="off", **kw)
    on, *_ = _stage_run(veh, faults="flaky", **kw)
    out = []
    if off is not base:
        out.append(Finding(
            "FLT001", _PATH_JIT, 0,
            "jit engine: faults='off' staged a new program instead of "
            "reusing the legacy cache entry"))
    if on is base:
        out.append(Finding(
            "FLT001", _PATH_JIT, 0,
            "jit engine: faults='flaky' reused the legacy cache entry — "
            "the fault plan is missing from the program-cache key"))
    return out


def _corridor_findings() -> list[Finding]:
    from repro.core.scenarios import build_world, get_scenario
    from repro.corridor.engine import _stage_run

    sc = dataclasses.replace(get_scenario("corridor-quick-r2-k8"),
                             rounds=6, l_iters=1)
    veh, _, _, p = build_world(sc, seed=0)
    kw = dict(seed=0, eval_every=3, interpretation="mixing",
              use_kernel=False, batch_size=32, mesh=None,
              record_cohorts=False, init_params=None, selection=None,
              flat=True)
    base, *_ = _stage_run(sc, veh, p, faults=None, **kw)
    off, *_ = _stage_run(sc, veh, p, faults="off", **kw)
    on, *_ = _stage_run(sc, veh, p, faults="flaky", **kw)
    out = []
    if off is not base:
        out.append(Finding(
            "FLT001", _PATH_COR, 0,
            "corridor engine: faults='off' staged a new program instead "
            "of reusing the legacy cache entry"))
    if on is base:
        out.append(Finding(
            "FLT001", _PATH_COR, 0,
            "corridor engine: faults='flaky' reused the legacy cache "
            "entry — the fault plan is missing from the program-cache "
            "key"))
    return out


def probe_faults_off() -> list[Finding]:
    return (_resolve_findings() + _jit_findings() + _corridor_findings())

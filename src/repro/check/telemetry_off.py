"""TEL001 probe: ``metrics=off`` must stage the exact legacy program.

The telemetry subsystem's hard contract (DESIGN.md §14) is that any falsy
``metrics`` setting is a *bitwise no-op*: the engines map it to ``None``
before the program-cache key is formed, so an off run and a no-metrics run
share one executable object — not merely equivalent programs, the same
program.  This probe stages the real jit and corridor quick worlds three
ways (no metrics, ``"off"``, ``"on"``) and verifies

- ``resolve_metrics`` collapses every falsy spelling to ``None``,
- the off staging returns the *identical* compiled-program object the
  no-metrics staging produced (cache identity — the strongest possible
  "same program" statement), and
- the on staging does NOT reuse that entry (a shared key would leak
  telemetry ops into off runs or vice versa).

Like the dtype-flow probes, this exercises the engines' own ``_stage_run``
helpers on tiny synthetic worlds, so it checks the program that would
actually run, not a reconstruction of it.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.check.findings import Finding

_PATH_JIT = "<probe:telemetry-off-jit>"
_PATH_COR = "<probe:telemetry-off-corridor>"


def _resolve_findings() -> list[Finding]:
    from repro.telemetry.spec import resolve_metrics

    out = []
    stale = np.array([0.5, 1.0, 2.0])
    times = np.array([1.0, 2.0, 3.0])
    for falsy in (None, False, "off"):
        if resolve_metrics(falsy, stale=stale, times=times) is not None:
            out.append(Finding(
                "TEL001", "<probe:telemetry-off-resolve>", 0,
                f"resolve_metrics({falsy!r}) did not return None — the "
                "falsy path must carry zero telemetry state"))
    return out


def _jit_findings() -> list[Finding]:
    from repro.check.dtype_flow import _small_fleet
    from repro.core.jit_engine import _stage_run

    veh, p = _small_fleet()
    kw = dict(scheme="mafl", rounds=6, l_iters=1, lr=0.05, params=p,
              seed=0, eval_every=3, use_kernel=False, init_params=None,
              interpretation="mixing", batch_size=32, mesh=None,
              selection=None, flat=True, ring_dtype="f32")
    base, *_ = _stage_run(veh, metrics=None, **kw)
    off, *_ = _stage_run(veh, metrics="off", **kw)
    on, *_ = _stage_run(veh, metrics="on", **kw)
    out = []
    if off is not base:
        out.append(Finding(
            "TEL001", _PATH_JIT, 0,
            "jit engine: metrics='off' staged a new program instead of "
            "reusing the legacy cache entry"))
    if on is base:
        out.append(Finding(
            "TEL001", _PATH_JIT, 0,
            "jit engine: metrics='on' reused the legacy cache entry — "
            "the metrics spec is missing from the program-cache key"))
    return out


def _corridor_findings() -> list[Finding]:
    from repro.core.scenarios import build_world, get_scenario
    from repro.corridor.engine import _stage_run

    sc = dataclasses.replace(get_scenario("corridor-quick-r2-k8"),
                             rounds=6, l_iters=1)
    veh, _, _, p = build_world(sc, seed=0)
    kw = dict(seed=0, eval_every=3, interpretation="mixing",
              use_kernel=False, batch_size=32, mesh=None,
              record_cohorts=False, init_params=None, selection=None,
              flat=True)
    base, *_ = _stage_run(sc, veh, p, metrics=None, **kw)
    off, *_ = _stage_run(sc, veh, p, metrics="off", **kw)
    on, *_ = _stage_run(sc, veh, p, metrics="on", **kw)
    out = []
    if off is not base:
        out.append(Finding(
            "TEL001", _PATH_COR, 0,
            "corridor engine: metrics='off' staged a new program instead "
            "of reusing the legacy cache entry"))
    if on is base:
        out.append(Finding(
            "TEL001", _PATH_COR, 0,
            "corridor engine: metrics='on' reused the legacy cache entry "
            "— the metrics spec is missing from the program-cache key"))
    return out


def probe_telemetry_off() -> list[Finding]:
    return (_resolve_findings() + _jit_findings() + _corridor_findings())

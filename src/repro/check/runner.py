"""Orchestration for ``python -m repro.check``: collect files, run the
three analyzer families, apply waivers, render text or JSON."""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.check import config
from repro.check.findings import RULES, Finding, apply_waivers


def collect_files(paths: list[str]) -> list[Path]:
    files: list[Path] = []
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            files.extend(f for f in sorted(p.rglob("*.py"))
                         if not config.is_excluded(f.as_posix()))
        elif p.suffix == ".py" and not config.is_excluded(p.as_posix()):
            files.append(p)
    return files


def run_checks(paths: list[str], *, probes: bool = True):
    """Returns ``(findings, reports, timings)``: waiver-applied findings,
    the kernel race reports, and per-analyzer wall times."""
    from repro.check import boundary, pallas_race

    files = collect_files(paths)
    findings: list[Finding] = []
    timings: dict[str, float] = {}

    t0 = time.perf_counter()
    for f in files:
        findings.extend(boundary.check_file(f))
    timings["boundary"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    reports, race_findings = pallas_race.scan(Path("."), files)
    findings.extend(race_findings)
    timings["pallas_race"] = time.perf_counter() - t0

    if probes:
        from repro.check import (dtype_flow, faults_off, plan_shapes,
                                 telemetry_off)

        t0 = time.perf_counter()
        findings.extend(plan_shapes.probe_plan_shapes())
        timings["plan_shapes"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        findings.extend(dtype_flow.probe_dtype_flow())
        timings["dtype_flow"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        findings.extend(telemetry_off.probe_telemetry_off())
        timings["telemetry_off"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        findings.extend(faults_off.probe_faults_off())
        timings["faults_off"] = time.perf_counter() - t0

    sources = {}
    for f in files:
        try:
            sources[f.as_posix()] = f.read_text()
        except OSError:
            pass
    findings = apply_waivers(findings, sources)
    findings.sort(key=lambda x: (x.path, x.line, x.rule))
    return findings, reports, timings


def render_text(findings, reports, timings, *, strict: bool) -> str:
    lines = []
    for rep in reports:
        legal = ",".join(b for b, ok in sorted(rep.compiled_legal.items())
                         if ok) or "none"
        lines.append(f"kernel {rep.kernel_id}: {rep.classification} "
                     f"(grid {rep.grid}, compiled on: {legal})")
    for f in findings:
        lines.append(f.format())
    live = sum(1 for f in findings if not f.waived)
    waived = sum(1 for f in findings if f.waived)
    t = " ".join(f"{k}={v:.2f}s" for k, v in timings.items())
    lines.append(f"{live} finding(s), {waived} waived  [{t}]")
    if strict and live:
        lines.append("FAIL (strict): unwaived findings")
    return "\n".join(lines)


def render_json(findings, reports, timings) -> str:
    return json.dumps({
        "kernels": [r.to_json() for r in reports],
        "findings": [f.to_json() for f in findings],
        "timings_s": {k: round(v, 3) for k, v in timings.items()},
    }, indent=2)


def list_rules() -> str:
    lines = []
    for rule in RULES.values():
        lines.append(f"{rule.id}  {rule.slug}  [{rule.analyzer}]")
        lines.append(f"      {rule.rationale}")
    return "\n".join(lines)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="Static invariant analyzers: Pallas grid races, "
                    "host/device boundary lint, dtype flow, plan shapes.")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to scan (default: src)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any unwaived finding remains")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the staged-program probes (dtype flow, "
                         "plan shapes) — AST/race analysis only")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(list_rules())
        return 0

    findings, reports, timings = run_checks(
        args.paths or ["src"], probes=not args.no_probes)
    if args.format == "json":
        print(render_json(findings, reports, timings))
    else:
        print(render_text(findings, reports, timings, strict=args.strict))
    live = sum(1 for f in findings if not f.waived)
    return 1 if (args.strict and live) else 0

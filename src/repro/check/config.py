"""What the analyzers scan and where the module-role boundaries sit.

Paths are matched by suffix against posix-style repo-relative paths, so the
CLI works from the repo root (``python -m repro.check src/``) or any parent.
"""
from __future__ import annotations

# Engine modules: traced scan/vmap/jit bodies live here; the boundary lint's
# tracer rules (BND001-BND004) apply to every scanned file, but these are the
# modules the invariant catalog names explicitly (DESIGN.md §13).
ENGINE_MODULES = (
    "repro/core/jit_engine.py",
    "repro/corridor/engine.py",
    "repro/core/flat.py",
    "repro/selection/runtime.py",
    "repro/telemetry/device.py",
)

# Planner modules: pure f64 host numpy, no engine/kernel imports, no jnp
# (PLN001/PLN002).  selection/runtime.py is both an engine-facing module and
# a planner (the f64 replay driver) — it gets both rule sets.
PLANNER_MODULES = (
    "repro/corridor/plan.py",
    "repro/selection/runtime.py",
    "repro/telemetry/spec.py",
    "repro/telemetry/replay.py",
)

# Planner functions living inside engine modules: the f64 dry runs.  The
# PLN rules apply to these function bodies only, not their whole module.
PLANNER_FUNCTIONS = {
    "repro/core/jit_engine.py": ("plan_fleet",),
}

# Fault planner modules (FLT001, DESIGN.md §16): the stochastic
# client-state sampler is the fault dual of the PLN planners — pure host
# f64 numpy, no engine/kernel/jax imports, no f32 drop.  Same lint as
# PLN001/PLN002, reported under the FLT001 rule id.
FAULT_PLANNER_MODULES = (
    "repro/faults/__init__.py",
    "repro/faults/spec.py",
    "repro/faults/runtime.py",
    "repro/faults/replay.py",
)

# Imports a planner may take from repro.* — everything else under repro (and
# jax) is engine internals from the planner's point of view.
PLANNER_ALLOWED_REPRO_IMPORTS = (
    "repro.channel",
    "repro.selection",
    "repro.core.mafl",       # _Timeline: the shared f64 event-queue replay
    "repro.telemetry",       # MetricsSpec is plan data (DESIGN.md §14)
    "repro.faults",          # fault tables are plan data (DESIGN.md §16)
)

# Functions with donated buffers: name -> donated positional-argument index
# (BND005 flags reads of that argument after the call).
DONATING_FUNCTIONS = {
    "mix_update_donated": 1,
    "literal_update_donated": 1,
}

# The known-positive fixture corpus is deliberately broken; default scans
# skip it (tests point the analyzers at it explicitly).
EXCLUDE_PARTS = ("repro/check/fixtures/",)


def is_excluded(path: str) -> bool:
    p = path.replace("\\", "/")
    return any(part in p for part in EXCLUDE_PARTS)


def matches(path: str, suffixes) -> bool:
    p = path.replace("\\", "/")
    return any(p.endswith(s) for s in suffixes)

"""Jaxpr-level dtype-flow checker (DTF rules, DESIGN.md §13).

The bf16 snapshot-ring contract (DESIGN.md §12) is: bf16 is a *storage*
dtype only — ring rows and upload buffers may hold bf16, but every
arithmetic consumer (the mix/aggregation chain, the trainer, evaluation
heads) must first widen to f32.  The engines uphold this by construction
today; this checker re-derives it from the staged programs themselves, so
a future edit that, say, dots a bf16 upload against f32 weights (silently
truncating the accumulation on some backends) is caught at check time, not
in a golden-digest bisect.

The probes stage the *real* engine programs via the engines' ``_stage_run``
helpers and walk ``jax.make_jaxpr``'s output: bf16 may flow through data
*movement* primitives only; any arithmetic primitive touching bf16 is
DTF001 (dot/conv — an MXU contraction in reduced precision) or DTF002
(everything else); in an f32-ring program any bf16 anywhere is DTF003.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.check.findings import Finding

# primitives that relocate or reinterpret values without doing arithmetic
# on them — the only places a storage dtype is allowed to appear
MOVEMENT_PRIMS = frozenset({
    "broadcast_in_dim", "reshape", "squeeze", "transpose", "slice",
    "dynamic_slice", "dynamic_update_slice", "gather", "scatter",
    "concatenate", "pad", "select_n", "convert_element_type", "copy",
    "stop_gradient", "optimization_barrier", "rev", "device_put",
    "copy_p",
})
CONTRACTION_PRIMS = frozenset({"dot_general", "conv_general_dilated"})
# structured control flow / call primitives: their bodies are walked
# separately, so the wrapper eqn itself is not an arithmetic consumer
_WRAPPER_PRIMS = frozenset({
    "pjit", "closed_call", "core_call", "xla_call", "scan", "while",
    "cond", "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
    "remat", "remat2", "checkpoint", "custom_lin", "pallas_call",
})


def _sub_jaxprs(params):
    for v in params.values():
        vs = v if isinstance(v, (tuple, list)) else (v,)
        for x in vs:
            if isinstance(x, jax.core.ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, jax.core.Jaxpr):
                yield x


def _has_bf16(var) -> bool:
    dt = getattr(getattr(var, "aval", None), "dtype", None)
    return dt == jnp.bfloat16


def walk_jaxpr(jaxpr, visit) -> None:
    """Depth-first over every eqn, recursing into sub-jaxpr params
    (pjit bodies, scan/while carries, cond branches, custom-vjp calls)."""
    for eqn in jaxpr.eqns:
        visit(eqn)
        for sub in _sub_jaxprs(eqn.params):
            walk_jaxpr(sub, visit)


def check_jaxpr(jaxpr, *, allow_bf16: bool, path: str) -> list[Finding]:
    """DTF findings for one (closed or open) jaxpr.  One finding per
    (rule, primitive) with an occurrence count — a single bad chain shows
    up in hundreds of eqns and a per-eqn flood would bury the report."""
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    counts: dict = {}

    def visit(eqn):
        prim = eqn.primitive.name
        touches = (any(_has_bf16(v) for v in eqn.invars)
                   or any(_has_bf16(v) for v in eqn.outvars))
        if not touches or prim in _WRAPPER_PRIMS:
            return
        if not allow_bf16:
            rule = "DTF003"
        elif prim in CONTRACTION_PRIMS:
            rule = "DTF001"
        elif prim in MOVEMENT_PRIMS:
            return
        else:
            rule = "DTF002"
        counts[(rule, prim)] = counts.get((rule, prim), 0) + 1

    walk_jaxpr(jaxpr, visit)
    out = []
    for (rule, prim), n in sorted(counts.items()):
        what = {"DTF001": "contraction consumes bf16 operands",
                "DTF002": "arithmetic on bf16 (storage dtype escaped "
                          "into compute)",
                "DTF003": "bf16 present in an f32-ring program"}[rule]
        out.append(Finding(rule, path, 0,
                           f"{what}: primitive {prim!r} x{n}"))
    return out


# ---------------------------------------------------------------------------
# engine probes — stage the real programs and check their jaxprs
# ---------------------------------------------------------------------------
def _small_fleet(k: int = 4):
    import dataclasses

    from repro.channel.params import ChannelParams
    from repro.data import partition_vehicles, synth_mnist

    tr_i, tr_l, _, _ = synth_mnist(n_train=240, n_test=16, seed=0,
                                   noise=0.35)
    p = dataclasses.replace(ChannelParams(), K=k)
    veh = partition_vehicles(tr_i, tr_l, p, seed=0, scale=0.03)
    return veh, p


def _jit_probe(ring_dtype: str) -> list[Finding]:
    from repro.core.jit_engine import _stage_run

    veh, p = _small_fleet()
    prog, args, *_ = _stage_run(
        veh, scheme="mafl", rounds=6, l_iters=1, lr=0.05, params=p,
        seed=0, eval_every=3, use_kernel=False, init_params=None,
        interpretation="mixing", batch_size=32, mesh=None, selection=None,
        flat=True, ring_dtype=ring_dtype)
    jaxpr = jax.make_jaxpr(prog)(*args)
    return check_jaxpr(jaxpr, allow_bf16=ring_dtype == "bf16",
                       path=f"<probe:jit-flat-{ring_dtype}>")


def _corridor_probe(ring_dtype: str) -> list[Finding]:
    import dataclasses

    from repro.core.scenarios import build_world, get_scenario
    from repro.corridor.engine import _stage_run

    sc = dataclasses.replace(get_scenario("corridor-quick-r2-k8"),
                             rounds=6, l_iters=1, ring_dtype=ring_dtype)
    veh, _, _, p = build_world(sc, seed=0)
    prog, args, *_ = _stage_run(
        sc, veh, p, seed=0, eval_every=3, interpretation="mixing",
        use_kernel=False, batch_size=32, mesh=None, record_cohorts=False,
        init_params=None, selection=None, flat=True)
    jaxpr = jax.make_jaxpr(prog)(*args)
    return check_jaxpr(jaxpr, allow_bf16=ring_dtype == "bf16",
                       path=f"<probe:corridor-flat-{ring_dtype}>")


def probe_dtype_flow() -> list[Finding]:
    """Stage four engine configurations and dtype-check their jaxprs:
    jit flat f32 (must be bf16-free), jit flat bf16 and corridor flat bf16
    (bf16 in storage roles only), corridor flat f32 (bf16-free)."""
    findings: list[Finding] = []
    findings += _jit_probe("f32")
    findings += _jit_probe("bf16")
    findings += _corridor_probe("f32")
    findings += _corridor_probe("bf16")
    return findings

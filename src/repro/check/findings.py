"""Findings, the rule registry, and the waiver escape hatch (DESIGN.md §13).

Every analyzer in :mod:`repro.check` reports through this module: a
:class:`Finding` carries ``file:line``, a rule id from :data:`RULES`, and a
human message.  A finding can be *waived* in source with a comment on the
flagged line (or the line directly above it)::

    x = something_suspicious()  # repro-check: waive[BND001] trace-time np on static plan data

The reason text is mandatory — an empty reason does not waive.  Waivers are
the documented escape hatch for intentional exceptions; ``--strict`` fails on
any finding that is not waived.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field, asdict
from typing import Iterable, Optional


@dataclass(frozen=True)
class Rule:
    """One checked invariant: id, short slug, why it exists, which analyzer
    enforces it."""
    id: str
    slug: str
    rationale: str
    analyzer: str


RULES: dict[str, Rule] = {}


def _rule(id: str, slug: str, rationale: str, analyzer: str) -> None:
    RULES[id] = Rule(id, slug, rationale, analyzer)


# -- Pallas grid-race detector (check/pallas_race.py) -----------------------
_rule("PAL001", "racy-kernel-grid",
      "an output block is revisited by non-consecutive grid cells: the "
      "read-modify-write races on every compiled backend (DESIGN.md §12)",
      "pallas_race")
_rule("PAL002", "unregistered-kernel",
      "a pallas_call under src/repro/kernels/ has no registered analyzer "
      "case, so dispatch has no race verdict to derive legality from",
      "pallas_race")
_rule("PAL003", "hand-rolled-dispatch",
      "backend selection (jax.default_backend() comparison) inside "
      "src/repro/kernels/ outside dispatch.py: kernel legality must be "
      "derived from the race verdict, not hand-maintained allowlists",
      "pallas_race")
_rule("PAL004", "degenerate-probe",
      "a registered analyzer case exercises fewer than 2 blocks along some "
      "grid axis, so revisit analysis is blind to aliasing on that axis",
      "pallas_race")

# -- host/device boundary lint (check/boundary.py) --------------------------
_rule("BND001", "host-call-on-tracer",
      "np.* applied to a traced value inside traced code: the call either "
      "fails at trace time or silently freezes a tracer into a constant",
      "boundary")
_rule("BND002", "python-branch-on-tracer",
      "a Python if/while/for/assert predicate depends on a tracer: control "
      "flow concretizes at trace time and the branch bakes into the program",
      "boundary")
_rule("BND003", "host-scalar-pull",
      ".item()/.tolist()/float()/int()/bool() on a tracer forces a device "
      "sync and breaks under jit",
      "boundary")
_rule("BND004", "f64-on-device",
      "float64 literal or cast inside traced code: the device side is f32 "
      "by contract (DESIGN.md §3); with x64 disabled the cast silently "
      "downgrades, with it enabled it doubles traffic",
      "boundary")
_rule("BND005", "donated-buffer-reuse",
      "a buffer passed to a donate_argnums slot is read after the donating "
      "call: donation invalidates the buffer (DESIGN.md §12)",
      "boundary")

# -- planner dual of the boundary lint --------------------------------------
_rule("PLN001", "planner-imports-engine",
      "the f64 dry-run planner imports engine/kernel internals: planners "
      "must stay pure host numpy so they can replay without device state "
      "(DESIGN.md §3)",
      "boundary")
_rule("PLN002", "planner-precision-drop",
      "f32 cast or jnp usage inside the f64 host planner: timelines are "
      "exact only because every planner op stays f64 numpy (DESIGN.md §3)",
      "boundary")
_rule("PLN003", "plan-shape-instability",
      "planner output arrays change shape across seeds: fixed-shape plan "
      "tables are the declared prerequisite for the vmap multi-world "
      "engine (ROADMAP)",
      "plan_shapes")

# -- dtype-flow checker (check/dtype_flow.py) -------------------------------
_rule("DTF001", "bf16-dot",
      "a dot/conv consumes bf16: all matmul accumulation stays f32; bf16 "
      "is a storage format for ring/upload rows only (DESIGN.md §12)",
      "dtype_flow")
_rule("DTF002", "bf16-arithmetic",
      "a non-storage primitive touches bf16: arithmetic must convert to "
      "f32 first — bf16 may only move (slice/scatter/reshape/convert), "
      "never accumulate (DESIGN.md §12)",
      "dtype_flow")
_rule("DTF003", "unexpected-bf16",
      "bf16 appears in a program whose ring dtype is f32: the quantized "
      "storage path leaked into the exact path",
      "dtype_flow")

# -- telemetry off-path probe (check/telemetry_off.py) ----------------------
_rule("TEL001", "metrics-off-not-legacy",
      "metrics=off staged a different program than the legacy no-metrics "
      "path: 'off' must map to None before the program-cache key so both "
      "share one executable bitwise (DESIGN.md §14)",
      "telemetry_off")

# -- fault-injection discipline (boundary lint + staged probes) -------------
_rule("FLT001", "fault-planner-discipline",
      "fault tables must be sampled in the host f64 planner only — no "
      "engine/kernel/jax imports and no f32 inside repro.faults (duals of "
      "PLN001/PLN002), fault-table shapes stable across seeds (the PLN003 "
      "extension the vmap tier needs), and faults=None staging the exact "
      "legacy program (the TEL001 dual) — DESIGN.md §16",
      "boundary+faults_off+plan_shapes")


@dataclass
class Finding:
    """One analyzer hit.  ``path`` is repo-relative where possible; probe
    findings (jaxpr-level, planner-shape) use a ``<probe:name>`` pseudo-path
    with line 0."""
    rule: str
    path: str
    line: int
    message: str
    waived: bool = False
    waive_reason: Optional[str] = None

    def format(self) -> str:
        mark = f"  [waived: {self.waive_reason}]" if self.waived else ""
        return (f"{self.path}:{self.line}: {self.rule} "
                f"({RULES[self.rule].slug}) {self.message}{mark}")

    def to_json(self) -> dict:
        d = asdict(self)
        d["slug"] = RULES[self.rule].slug
        return d


# -- waivers ----------------------------------------------------------------
_WAIVE_RE = re.compile(
    r"#\s*repro-check:\s*waive\[([A-Za-z0-9_,\s]+)\]\s*(.*\S)")


def load_waivers(source: str) -> dict[int, tuple[set[str], str]]:
    """Map 1-based line number -> (rule ids, reason) for every waiver
    comment in ``source``.  A waiver with no reason text is ignored."""
    out: dict[int, tuple[set[str], str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _WAIVE_RE.search(line)
        if m:
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            out[i] = (rules, m.group(2).strip())
    return out


def apply_waivers(findings: Iterable[Finding],
                  sources: dict[str, str]) -> list[Finding]:
    """Mark findings waived when the flagged line (or the line above it)
    carries a matching waiver comment.  ``sources`` maps path -> text."""
    cache: dict[str, dict[int, tuple[set[str], str]]] = {}
    out = []
    for f in findings:
        src = sources.get(f.path)
        if src is not None:
            if f.path not in cache:
                cache[f.path] = load_waivers(src)
            waivers = cache[f.path]
            for ln in (f.line, f.line - 1):
                hit = waivers.get(ln)
                if hit and f.rule in hit[0]:
                    f.waived = True
                    f.waive_reason = hit[1]
                    break
        out.append(f)
    return out

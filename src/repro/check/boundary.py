"""Host/device boundary lint (DESIGN.md §13, rules BND001-BND005 and
PLN001-PLN002).

One AST pass per file, in three parts:

**Traced-code rules (BND001-BND004).**  A function is *directly traced* when
it is structurally handed to a tracer: passed to ``jax.jit`` /
``jax.lax.scan`` / ``jax.vmap`` / ``shard_map`` / ``pl.pallas_call`` /
``fori_loop`` / ``while_loop`` / ``cond`` / ``tree_map`` (directly, through
``functools.partial``, or via a factory call like
``lax.scan(make_body(...), ...)`` — every def nested in the factory is
traced), decorated with ``jax.jit`` (bare or through ``partial``), or
lexically nested in a traced def.  Inside traced defs a light forward taint
pass tracks which names are tracers — parameters seed the set, ``jnp.*`` /
``jax.*`` call results and anything derived from tainted values propagate
it, ``.shape``/``.dtype``/``.ndim``/``.size`` reads drop it — so the rules
fire on tracers without false-positiving on the engines' trace-time host
work over static plan tables (``np.asarray(T)`` on closure numpy data,
``if fused_chain:`` on closure config booleans).

Functions merely *called from* traced code (trace-time helpers like
``ParamLayout.pack``) get the weak rule set: only BND004 (f64 literal or
cast), which is wrong at trace level and run level alike, is checked there
— their parameters may legitimately be static host data, so taint seeding
would guess wrong.

**Planner rules (PLN001-PLN002).**  The dual contract for the f64 dry-run
planners (``corridor/plan.py``, ``selection/runtime.py``, ``plan_fleet``):
no engine/kernel imports, no jnp, no f32 drop mid-plan.

**Donation rule (BND005).**  Call sites of the registered donating updates
(``mix_update_donated`` etc.) must not read the donated argument afterwards.
"Afterwards" is structural: later statements of the same block or of any
enclosing block, plus anywhere in a shared enclosing loop — sibling branches
of the same ``if`` don't count.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

from repro.check import config
from repro.check.findings import Finding

NP_ROOTS = {"np", "numpy"}
TRACER_ROOTS = {"jnp", "jax", "lax", "pl", "pltpu"}
SHAPE_ATTRS = {"shape", "dtype", "ndim", "size"}
SCALAR_PULLS = {"float", "int", "bool", "complex"}
F64_STRINGS = {"float64", "f8", ">f8", "<f8"}
F32_STRINGS = {"float32", "f4", ">f4", "<f4"}

# callables whose function-valued argument positions mark traced defs
_TRACE_ENTRY_ARGS = {
    "jit": (0,), "scan": (0,), "vmap": (0,), "pmap": (0,),
    "shard_map": (0,), "pallas_call": (0,), "tree_map": (0,),
    "fori_loop": (2,), "while_loop": (0, 1), "cond": (1, 2),
    "checkpoint": (0,), "remat": (0,), "grad": (0,),
    "value_and_grad": (0,),
}


def _root_name(node) -> Optional[str]:
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _callee_name(func) -> Optional[str]:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _is_partial(func) -> bool:
    return _callee_name(func) == "partial"


HOST_ITER_FUNCS = {"zip", "enumerate", "range", "reversed", "sorted",
                   "list", "tuple", "items", "keys", "values"}


def _const_strs(node) -> tuple:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(el.value for el in node.elts
                     if isinstance(el, ast.Constant)
                     and isinstance(el.value, str))
    return ()


def _const_ints(node) -> tuple:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(el.value for el in node.elts
                     if isinstance(el, ast.Constant)
                     and isinstance(el.value, int))
    return ()


def _static_spec(call: ast.Call) -> tuple[tuple, tuple]:
    """(static_argnames, static_argnums) declared on a jit/checkpoint-style
    call — those parameters are Python values at trace time, not tracers."""
    names, nums = (), ()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            names = _const_strs(kw.value)
        elif kw.arg == "static_argnums":
            nums = _const_ints(kw.value)
    return names, nums


def _param_names(fn) -> list:
    a = fn.args
    return [arg.arg for arg in [*a.posonlyargs, *a.args, *a.kwonlyargs]]


# ---------------------------------------------------------------------------
# module indexing: parents, defs, scopes
# ---------------------------------------------------------------------------
class _Module:
    def __init__(self, path: str, tree: ast.Module):
        self.path = path
        self.tree = tree
        self.parent: dict = {}
        self.defs: list = []
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self.parent[child] = node
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs.append(node)

    def enclosing_def(self, node):
        n = self.parent.get(node)
        while n is not None:
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return n
            n = self.parent.get(n)
        return None

    def resolve_def(self, name: str, at):
        """The def a Name refers to: nearest enclosing scope first, then
        module level, then a unique global match."""
        scope = self.enclosing_def(at)
        while scope is not None:
            for d in self.defs:
                if d.name == name and self.enclosing_def(d) is scope:
                    return d
            scope = self.enclosing_def(scope)
        mod_level = [d for d in self.defs
                     if d.name == name and self.enclosing_def(d) is None]
        if mod_level:
            return mod_level[0]
        named = [d for d in self.defs if d.name == name]
        return named[0] if len(named) == 1 else None


# ---------------------------------------------------------------------------
# marking: directly traced, factory-traced, weakly reachable
# ---------------------------------------------------------------------------
def _mark(mod: _Module) -> tuple[dict, set]:
    """({traced def node: static param names}, weak def nodes).  Lambdas
    passed to tracers are handled inline by the taint pass (they cannot
    contain statements).  Parameters declared ``static_argnums`` /
    ``static_argnames`` at the trace entry are Python values, not tracers,
    so they are excluded from taint seeding."""
    traced: dict = {}

    def add(d, statics=()):
        traced.setdefault(d, set()).update(statics)

    def resolve_statics(d, names, nums):
        params = _param_names(d)
        out = set(names)
        out.update(params[i] for i in nums if i < len(params))
        return out

    def mark_fn_expr(expr, at, names=(), nums=()):
        if isinstance(expr, ast.Name):
            d = mod.resolve_def(expr.id, at)
            if d is not None:
                add(d, resolve_statics(d, names, nums))
        elif isinstance(expr, ast.Call):
            if _is_partial(expr.func) and expr.args:
                n2, i2 = _static_spec(expr)
                mark_fn_expr(expr.args[0], at, (*names, *n2), (*nums, *i2))
            else:
                # factory call: every def nested in the factory is traced
                name = _callee_name(expr.func)
                d = mod.resolve_def(name, at) if name else None
                if d is not None:
                    for sub in ast.walk(d):
                        if isinstance(sub, ast.FunctionDef) and sub is not d:
                            add(sub)

    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            callee = _callee_name(node.func)
            spots = _TRACE_ENTRY_ARGS.get(callee)
            if spots:
                names, nums = _static_spec(node)
                for i in spots:
                    if i < len(node.args):
                        mark_fn_expr(node.args[i], node, names, nums)
        elif isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                if _callee_name(dec) == "jit":
                    if isinstance(dec, ast.Call):
                        names, nums = _static_spec(dec)
                        add(node, resolve_statics(node, names, nums))
                    else:
                        add(node)
                elif (isinstance(dec, ast.Call) and _is_partial(dec.func)
                        and dec.args
                        and _callee_name(dec.args[0]) == "jit"):
                    names, nums = _static_spec(dec)
                    add(node, resolve_statics(node, names, nums))

    # nesting closure: defs inside traced defs are traced
    changed = True
    while changed:
        changed = False
        for d in mod.defs:
            if d in traced:
                continue
            enc = mod.enclosing_def(d)
            if enc is not None and enc in traced:
                add(d)
                changed = True

    # weak reachability: defs called from traced (or weak) defs
    weak: set = set()
    frontier = list(traced)
    while frontier:
        src = frontier.pop()
        for node in ast.walk(src):
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                d = mod.resolve_def(node.func.id, node)
                if d is not None and d not in traced and d not in weak:
                    weak.add(d)
                    frontier.append(d)
    return traced, weak


# ---------------------------------------------------------------------------
# taint lint over one traced def
# ---------------------------------------------------------------------------
class _TaintLint:
    def __init__(self, mod: _Module, findings: list, traced: set):
        self.mod = mod
        self.findings = findings
        self.traced = traced
        self.done: set = set()

    def hit(self, rule, node, msg):
        self.findings.append(Finding(rule, self.mod.path, node.lineno, msg))

    def run_def(self, fn, inherited=()):
        if fn in self.done:
            return
        self.done.add(fn)
        tainted = set(inherited)
        statics = self.traced.get(fn) or ()
        a = fn.args
        for arg in [*a.posonlyargs, *a.args, *a.kwonlyargs,
                    *( [a.vararg] if a.vararg else []),
                    *( [a.kwarg] if a.kwarg else [])]:
            if arg.arg not in statics:
                tainted.add(arg.arg)
        self.block(fn.body, tainted)

    # -- statements --------------------------------------------------------
    def block(self, stmts, tainted):
        for s in stmts:
            self.stmt(s, tainted)

    def assign_target(self, target, t: bool, tainted):
        if isinstance(target, ast.Name):
            (tainted.add if t else tainted.discard)(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self.assign_target(el, t, tainted)
        elif isinstance(target, ast.Starred):
            self.assign_target(target.value, t, tainted)
        # subscript/attribute targets mutate containers; no name to track

    def stmt(self, s, tainted):
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if s in self.traced:
                self.run_def(s, inherited=frozenset(tainted))
            return
        if isinstance(s, ast.Assign):
            t = self.taint(s.value, tainted)
            if (isinstance(s.value, ast.Tuple)
                    and len(s.targets) == 1
                    and isinstance(s.targets[0], ast.Tuple)
                    and len(s.targets[0].elts) == len(s.value.elts)):
                for tgt, val in zip(s.targets[0].elts, s.value.elts):
                    self.assign_target(tgt, self.taint(val, tainted),
                                       tainted)
            else:
                for tgt in s.targets:
                    self.assign_target(tgt, t, tainted)
        elif isinstance(s, ast.AnnAssign):
            if s.value is not None:
                self.assign_target(s.target, self.taint(s.value, tainted),
                                   tainted)
        elif isinstance(s, ast.AugAssign):
            t = self.taint(s.value, tainted)
            if isinstance(s.target, ast.Name):
                if t:
                    tainted.add(s.target.id)
        elif isinstance(s, ast.If):
            if self.taint(s.test, tainted):
                self.hit("BND002", s.test,
                         "Python `if` on a tracer-derived predicate")
            self.block(s.body, tainted)
            self.block(s.orelse, tainted)
        elif isinstance(s, ast.While):
            if self.taint(s.test, tainted):
                self.hit("BND002", s.test,
                         "Python `while` on a tracer-derived predicate")
            self.block(s.body, tainted)
            self.block(s.body, tainted)
        elif isinstance(s, ast.For):
            t = self.taint(s.iter, tainted)
            host_iter = (isinstance(s.iter, ast.Call)
                         and _callee_name(s.iter.func) in HOST_ITER_FUNCS)
            if t and not host_iter:
                # zip/enumerate/... over tracers is trace-time unrolling of a
                # static-length container, not a branch on traced values
                self.hit("BND002", s.iter,
                         "Python `for` over a tracer-derived iterable")
            self.assign_target(s.target, t, tainted)
            self.block(s.body, tainted)
            self.block(s.body, tainted)
            self.block(s.orelse, tainted)
        elif isinstance(s, ast.Assert):
            if self.taint(s.test, tainted):
                self.hit("BND002", s.test,
                         "`assert` on a tracer-derived predicate")
        elif isinstance(s, (ast.Return, ast.Expr)):
            if s.value is not None:
                self.taint(s.value, tainted)
        elif isinstance(s, ast.With):
            for item in s.items:
                self.taint(item.context_expr, tainted)
            self.block(s.body, tainted)
        elif isinstance(s, ast.Try):
            self.block(s.body, tainted)
            for h in s.handlers:
                self.block(h.body, tainted)
            self.block(s.orelse, tainted)
            self.block(s.finalbody, tainted)
        elif isinstance(s, (ast.Delete, ast.Pass, ast.Break, ast.Continue,
                            ast.Import, ast.ImportFrom, ast.Global,
                            ast.Nonlocal, ast.Raise)):
            return
        else:
            for child in ast.iter_child_nodes(s):
                if isinstance(child, ast.expr):
                    self.taint(child, tainted)

    # -- expressions -------------------------------------------------------
    def taint(self, e, tainted) -> bool:
        if e is None:
            return False
        if isinstance(e, ast.Constant):
            if isinstance(e.value, str) and e.value in F64_STRINGS:
                self.hit("BND004", e, "'float64' dtype string in traced "
                         "code (device contract is f32)")
            return False
        if isinstance(e, ast.Name):
            return e.id in tainted
        if isinstance(e, ast.Attribute):
            if e.attr == "float64":
                self.hit("BND004", e, "float64 dtype in traced code "
                         "(device contract is f32)")
                return False
            if e.attr in SHAPE_ATTRS:
                self.taint(e.value, tainted)
                return False
            return self.taint(e.value, tainted)
        if isinstance(e, ast.Subscript):
            return (self.taint(e.value, tainted)
                    | self.taint(e.slice, tainted))
        if isinstance(e, ast.Call):
            return self.call(e, tainted)
        if isinstance(e, (ast.BinOp,)):
            return (self.taint(e.left, tainted)
                    | self.taint(e.right, tainted))
        if isinstance(e, ast.UnaryOp):
            return self.taint(e.operand, tainted)
        if isinstance(e, ast.BoolOp):
            return any([self.taint(v, tainted) for v in e.values])
        if isinstance(e, ast.Compare):
            res = self.taint(e.left, tainted)
            for c in e.comparators:
                res |= self.taint(c, tainted)
            return res
        if isinstance(e, ast.IfExp):
            if self.taint(e.test, tainted):
                self.hit("BND002", e.test,
                         "conditional expression on a tracer-derived "
                         "predicate")
            return (self.taint(e.body, tainted)
                    | self.taint(e.orelse, tainted))
        if isinstance(e, (ast.Tuple, ast.List, ast.Set)):
            return any([self.taint(el, tainted) for el in e.elts])
        if isinstance(e, ast.Dict):
            return any([self.taint(v, tainted)
                        for v in [*e.keys, *e.values] if v is not None])
        if isinstance(e, ast.Lambda):
            inner = set(tainted)
            for arg in [*e.args.posonlyargs, *e.args.args,
                        *e.args.kwonlyargs]:
                inner.add(arg.arg)
            return self.taint(e.body, inner)
        if isinstance(e, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                          ast.DictComp)):
            inner = set(tainted)
            for gen in e.generators:
                self.assign_target(gen.target,
                                   self.taint(gen.iter, inner), inner)
                for cond in gen.ifs:
                    self.taint(cond, inner)
            if isinstance(e, ast.DictComp):
                return (self.taint(e.key, inner)
                        | self.taint(e.value, inner))
            return self.taint(e.elt, inner)
        if isinstance(e, ast.Starred):
            return self.taint(e.value, tainted)
        if isinstance(e, ast.JoinedStr):
            for v in e.values:
                if isinstance(v, ast.FormattedValue):
                    self.taint(v.value, tainted)
            return False
        if isinstance(e, ast.Slice):
            return any([self.taint(x, tainted)
                        for x in (e.lower, e.upper, e.step)
                        if x is not None])
        return any([self.taint(c, tainted)
                    for c in ast.iter_child_nodes(e)
                    if isinstance(c, ast.expr)])

    def call(self, e, tainted) -> bool:
        arg_taints = [self.taint(a, tainted) for a in e.args]
        arg_taints += [self.taint(kw.value, tainted) for kw in e.keywords]
        any_arg = any(arg_taints)
        func = e.func

        if isinstance(func, ast.Name) and func.id in SCALAR_PULLS:
            if any_arg:
                self.hit("BND003", e,
                         f"{func.id}() on a tracer forces a host sync")
            return False
        if isinstance(func, ast.Attribute):
            if func.attr in ("item", "tolist"):
                if self.taint(func.value, tainted):
                    self.hit("BND003", e,
                             f".{func.attr}() on a tracer forces a host "
                             "sync")
                return False
            if func.attr == "astype":
                # the dtype argument was already evaluated above: an
                # Attribute float64 / 'float64' constant hit BND004 there
                return self.taint(func.value, tainted)

        root = _root_name(func)
        if root in NP_ROOTS:
            if any_arg:
                self.hit("BND001", e,
                         "np.* applied to a tracer inside traced code")
            return False
        if root in TRACER_ROOTS:
            return True
        func_taint = self.taint(func, tainted) \
            if isinstance(func, (ast.Attribute, ast.Subscript, ast.Call)) \
            else (isinstance(func, ast.Name) and func.id in tainted)
        return any_arg or bool(func_taint)


def _weak_lint(mod: _Module, fn, findings: list):
    """BND004 only: an f64 literal/cast is wrong at trace level and run
    level alike; everything else needs taint context we don't have here."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and node.attr == "float64":
            findings.append(Finding(
                "BND004", mod.path, node.lineno,
                "float64 dtype in trace-time helper (device contract "
                "is f32)"))
        elif (isinstance(node, ast.Constant)
                and isinstance(node.value, str)
                and node.value in F64_STRINGS):
            findings.append(Finding(
                "BND004", mod.path, node.lineno,
                "'float64' dtype string in trace-time helper (device "
                "contract is f32)"))


# ---------------------------------------------------------------------------
# planner rules
# ---------------------------------------------------------------------------
def _planner_import_ok(module_name: str) -> bool:
    if not module_name.startswith("repro"):
        return True
    return any(module_name == p or module_name.startswith(p + ".")
               for p in config.PLANNER_ALLOWED_REPRO_IMPORTS)


def _planner_lint(mod: _Module, scope, findings: list,
                  check_imports: bool = True,
                  import_rule: str = "PLN001",
                  purity_rule: str = "PLN002"):
    """PLN001/PLN002 over ``scope`` (a module or one function body).  The
    fault planner modules run the identical lint under the FLT001 rule id
    (the faults dual, DESIGN.md §16)."""
    for node in ast.walk(scope):
        if check_imports and isinstance(node, ast.Import):
            for alias in node.names:
                if (not _planner_import_ok(alias.name)
                        or alias.name.split(".")[0] == "jax"):
                    findings.append(Finding(
                        import_rule, mod.path, node.lineno,
                        f"planner imports {alias.name!r}: planners stay "
                        "pure host numpy (f64)"))
        elif check_imports and isinstance(node, ast.ImportFrom):
            name = node.module or ""
            if (not _planner_import_ok(name)
                    or name.split(".")[0] == "jax"):
                findings.append(Finding(
                    import_rule, mod.path, node.lineno,
                    f"planner imports from {name!r}: planners stay pure "
                    "host numpy (f64)"))
        elif isinstance(node, ast.Attribute):
            if node.attr == "float32":
                findings.append(Finding(
                    purity_rule, mod.path, node.lineno,
                    "f32 drop inside the f64 planner (timelines are "
                    "exact only in f64)"))
        elif isinstance(node, ast.Name) and node.id == "jnp":
            findings.append(Finding(
                purity_rule, mod.path, node.lineno,
                "jnp usage inside the f64 planner (device types leak "
                "into the timeline)"))
        elif (isinstance(node, ast.Constant)
                and isinstance(node.value, str)
                and node.value in F32_STRINGS):
            findings.append(Finding(
                purity_rule, mod.path, node.lineno,
                "'float32' dtype string inside the f64 planner"))


# ---------------------------------------------------------------------------
# donation rule
# ---------------------------------------------------------------------------
def _stmt_path(mod: _Module, node):
    """[(body_list, index), ...] from the outermost block down to the
    statement containing ``node``."""
    stmt = node
    while stmt is not None and not isinstance(stmt, ast.stmt):
        stmt = mod.parent.get(stmt)
    path = []
    while isinstance(stmt, ast.stmt):
        parent = mod.parent.get(stmt)
        blk = None
        for attr in ("body", "orelse", "finalbody"):
            b = getattr(parent, attr, None)
            if isinstance(b, list) and stmt in b:
                blk = b
                break
        if blk is None:
            break
        path.append((id(blk), blk.index(stmt), parent))
        stmt = parent if isinstance(parent, ast.stmt) else None
    return list(reversed(path))


def _happens_after(mod: _Module, call_node, use_node) -> bool:
    cp = _stmt_path(mod, call_node)
    up = _stmt_path(mod, use_node)
    for (cb, ci, cparent), (ub, ui, _uparent) in zip(cp, up):
        if cb != ub:
            return False
        if ci != ui:
            return ui > ci
        if isinstance(cparent, (ast.For, ast.While)):
            return True          # next loop iteration re-reads
    return False


def _donation_lint(mod: _Module, findings: list):
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        callee = _callee_name(node.func)
        idx = config.DONATING_FUNCTIONS.get(callee)
        if idx is None or idx >= len(node.args):
            continue
        donated = node.args[idx]
        if not isinstance(donated, ast.Name):
            continue
        fn = mod.enclosing_def(node)
        scope = fn if fn is not None else mod.tree
        for use in ast.walk(scope):
            if (isinstance(use, ast.Name) and use.id == donated.id
                    and use is not donated
                    and isinstance(use.ctx, ast.Load)
                    and _happens_after(mod, node, use)):
                killed = any(
                    isinstance(k, ast.Name) and k.id == donated.id
                    and isinstance(k.ctx, ast.Store)
                    and _happens_after(mod, node, k)
                    and k.lineno <= use.lineno
                    for k in ast.walk(scope))
                if not killed:
                    findings.append(Finding(
                        "BND005", mod.path, use.lineno,
                        f"{donated.id!r} read after being donated to "
                        f"{callee} (line {node.lineno})"))


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def check_source(path: str, source: str) -> list[Finding]:
    """All boundary findings for one file."""
    findings: list[Finding] = []
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("BND001", path, e.lineno or 0,
                        f"unparseable file: {e.msg}")]
    mod = _Module(path, tree)

    traced, weak = _mark(mod)
    lint = _TaintLint(mod, findings, traced)
    for fn in sorted(traced, key=lambda d: d.lineno):
        enc = mod.enclosing_def(fn)
        if enc is not None and enc in traced:
            continue             # analyzed from its enclosing traced def
        lint.run_def(fn)
    for fn in sorted(weak, key=lambda d: d.lineno):
        _weak_lint(mod, fn, findings)

    if config.matches(path, config.PLANNER_MODULES):
        _planner_lint(mod, mod.tree, findings)
    if config.matches(path, config.FAULT_PLANNER_MODULES):
        _planner_lint(mod, mod.tree, findings,
                      import_rule="FLT001", purity_rule="FLT001")
    for suffix, fns in config.PLANNER_FUNCTIONS.items():
        if config.matches(path, (suffix,)):
            for d in mod.defs:
                if d.name in fns:
                    _planner_lint(mod, d, findings, check_imports=True)

    _donation_lint(mod, findings)
    return findings


def check_file(path: Path) -> list[Finding]:
    return check_source(path.as_posix(), path.read_text())

"""Pallas grid-race detector (DESIGN.md §13, rules PAL001-PAL004).

For every kernel under ``src/repro/kernels/`` a registered *case* invokes the
kernel wrapper on tiny representative inputs with ``pallas_call`` swapped for
a recorder, capturing the real ``grid`` and ``BlockSpec`` objects the wrapper
builds.  The detector then enumerates the grid cells exactly the way Pallas
iterates them (row-major, last axis fastest), evaluates each *output* index
map at every cell, and inspects which cells address each output block:

- ``parallel-safe``       — every output block is written by exactly one grid
  cell; legal compiled on any backend.
- ``sequential-axis-required`` — some output block is revisited, but each
  block's writing cells form one consecutive run in row-major order (the
  Pallas cross-step accumulation idiom, e.g. ``ring_agg``'s upload axis or
  the flash-softmax vocab/kv sweeps).  Correct only where grid steps execute
  sequentially and the block stays resident between them: TPU and the
  interpreter.  GPU grid cells are parallel blocks — illegal there.
- ``racy``                — revisits are non-consecutive; no compiled backend
  executes this correctly.

The per-backend legality verdict is what ``repro.kernels.dispatch`` consumes
— the hand-maintained "compiled on TPU only" allowlist that used to live in
``weighted_agg/ops.py`` is now derived fact.

Representative shapes must populate at least two blocks per grid axis or the
analysis is blind on that axis; PAL004 flags degenerate cases.
"""
from __future__ import annotations

import ast
import functools
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Optional

import numpy as np

from repro.check.findings import Finding

BACKENDS = ("cpu", "gpu", "tpu")

CLASSIFICATIONS = ("parallel-safe", "sequential-axis-required", "racy")


@dataclass(frozen=True)
class KernelReport:
    """Race verdict for one kernel: the captured grid geometry, the
    classification, and per-backend compiled legality.

    ``compiled_legal[backend]`` answers "may dispatch run the *compiled*
    Pallas kernel here?" — CPU is always False (no Mosaic/Triton lowering;
    the interpreter is the CPU execution mode, and it is always legal
    because it runs grid cells sequentially in row-major order)."""
    kernel_id: str
    fn_name: str
    grid: tuple
    n_outputs: int
    classification: str
    revisit_axes: tuple
    compiled_legal: dict = field(hash=False)

    def to_json(self) -> dict:
        return {
            "kernel_id": self.kernel_id,
            "fn_name": self.fn_name,
            "grid": list(self.grid),
            "classification": self.classification,
            "revisit_axes": list(self.revisit_axes),
            "compiled_legal": dict(self.compiled_legal),
        }


# ---------------------------------------------------------------------------
# capture: run the wrapper with pallas_call swapped for a recorder
# ---------------------------------------------------------------------------
@dataclass
class _Captured:
    grid: tuple
    in_specs: list
    out_specs: list
    n_outputs: int


def _capture_pallas_calls(invoke: Callable[[], object]) -> list[_Captured]:
    """Invoke ``invoke()`` under ``jax.disable_jit()`` with
    ``pallas.pallas_call`` replaced by a recorder that returns zeros of
    ``out_shape`` — the wrapper's surrounding jnp code runs eagerly, the
    kernel body never executes, and the recorder sees the exact grid and
    BlockSpecs the wrapper built."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas

    records: list[_Captured] = []
    real = pallas.pallas_call

    def fake_pallas_call(kernel, out_shape=None, *, grid=None,
                         grid_spec=None, in_specs=None, out_specs=None,
                         **kw):
        if out_shape is None:
            out_shape = kw.pop("out_shape", None)
        multi = isinstance(out_shape, (tuple, list))
        shapes = tuple(out_shape) if multi else (out_shape,)
        specs = (list(out_specs) if isinstance(out_specs, (tuple, list))
                 else [out_specs])
        g = tuple(grid) if grid is not None else ()
        records.append(_Captured(
            grid=g,
            in_specs=(list(in_specs) if in_specs is not None else []),
            out_specs=specs, n_outputs=len(shapes)))

        def run(*args):
            outs = tuple(jnp.zeros(s.shape, s.dtype) for s in shapes)
            return outs if multi else outs[0]
        return run

    pallas.pallas_call = fake_pallas_call
    try:
        with jax.disable_jit():
            invoke()
    finally:
        pallas.pallas_call = real
    if not records:
        raise RuntimeError("registered case invoked no pallas_call")
    return records


# ---------------------------------------------------------------------------
# classification
# ---------------------------------------------------------------------------
def _index_tuple(spec, cell) -> tuple:
    idx = spec.index_map(*cell)
    if not isinstance(idx, tuple):
        idx = (idx,)
    return tuple(int(x) for x in idx)


def classify_capture(cap: _Captured) -> tuple[str, tuple]:
    """(classification, revisit_axes) for one captured pallas_call."""
    if not cap.grid:
        return "parallel-safe", ()
    cells = list(np.ndindex(*cap.grid))      # row-major: Pallas's iteration
    rank = {c: i for i, c in enumerate(cells)}
    worst = "parallel-safe"
    axes: set = set()
    for spec in cap.out_specs:
        blocks: dict[tuple, list] = {}
        for c in cells:
            blocks.setdefault(_index_tuple(spec, c), []).append(c)
        for cs in blocks.values():
            if len(cs) == 1:
                continue
            for ax in range(len(cap.grid)):
                if len({c[ax] for c in cs}) > 1:
                    axes.add(ax)
            rs = sorted(rank[c] for c in cs)
            if rs != list(range(rs[0], rs[0] + len(rs))):
                worst = "racy"
            elif worst != "racy":
                worst = "sequential-axis-required"
    return worst, tuple(sorted(axes))


def _legality(classification: str) -> dict:
    return {
        "cpu": False,                                     # interpreter only
        "gpu": classification == "parallel-safe",
        "tpu": classification != "racy",
    }


def analyze_callable(kernel_id: str, fn_name: str,
                     invoke: Callable[[], object]) -> KernelReport:
    """Capture + classify one kernel invocation.  Multiple pallas_calls in
    one invocation are folded to the worst classification (none of ours do
    that, but fixtures may)."""
    caps = _capture_pallas_calls(invoke)
    worst, axes = "parallel-safe", ()
    grid, n_out = caps[0].grid, caps[0].n_outputs
    for cap in caps:
        c, a = classify_capture(cap)
        if CLASSIFICATIONS.index(c) > CLASSIFICATIONS.index(worst):
            worst, axes = c, a
            grid, n_out = cap.grid, cap.n_outputs
    return KernelReport(kernel_id=kernel_id, fn_name=fn_name, grid=grid,
                        n_outputs=n_out, classification=worst,
                        revisit_axes=axes, compiled_legal=_legality(worst))


# ---------------------------------------------------------------------------
# the registered corpus: one case per kernel under src/repro/kernels/
# ---------------------------------------------------------------------------
# Every case invokes the kernel with explicit interpret=True (the recorder
# ignores it) and shapes giving >= 2 blocks per grid axis.

def _case_weighted_agg():
    import jax.numpy as jnp
    from repro.kernels.weighted_agg.kernel import weighted_agg_2d
    g = jnp.zeros((8, 128), jnp.float32)
    scal = jnp.zeros((1, 2), jnp.float32)
    weighted_agg_2d(g, g, scal, block_rows=4, interpret=True)


def _case_ring_agg():
    import jax.numpy as jnp
    from repro.kernels.weighted_agg.kernel import ring_agg_2d
    g = jnp.zeros((8, 128), jnp.float32)
    locs = jnp.zeros((4, 8, 128), jnp.float32)
    coeffs = jnp.zeros((4, 2), jnp.float32)
    ring_agg_2d(g, locs, coeffs, block_rows=4, block_u=2, interpret=True)


def _case_cross_entropy():
    import jax.numpy as jnp
    from repro.kernels.cross_entropy.kernel import cross_entropy_tiled
    logits = jnp.zeros((16, 64), jnp.float32)
    labels = jnp.zeros((16,), jnp.int32)
    cross_entropy_tiled(logits, labels, block_r=8, block_v=32,
                        interpret=True)


def _case_decode_attention():
    import jax.numpy as jnp
    from repro.kernels.decode_attention.kernel import decode_attention_bkv
    q = jnp.zeros((2, 2, 8), jnp.float32)
    kv = jnp.zeros((2, 64, 8), jnp.float32)
    pos = jnp.zeros((1, 1), jnp.int32)
    decode_attention_bkv(q, kv, kv, pos, block_s=32, interpret=True)


def _case_swa_attention():
    import jax.numpy as jnp
    from repro.kernels.swa_attention.kernel import swa_attention_bhsd
    q = jnp.zeros((2, 256, 8), jnp.float32)
    swa_attention_bhsd(q, q, q, window=128, block_q=128, block_k=128,
                       interpret=True)


# kernel_id -> (kernel module path suffix, wrapper fn name, case)
KERNEL_CASES: dict[str, tuple[str, str, Callable]] = {
    "weighted_agg.weighted_agg_2d": (
        "repro/kernels/weighted_agg/kernel.py", "weighted_agg_2d",
        _case_weighted_agg),
    "weighted_agg.ring_agg_2d": (
        "repro/kernels/weighted_agg/kernel.py", "ring_agg_2d",
        _case_ring_agg),
    "cross_entropy.cross_entropy_tiled": (
        "repro/kernels/cross_entropy/kernel.py", "cross_entropy_tiled",
        _case_cross_entropy),
    "decode_attention.decode_attention_bkv": (
        "repro/kernels/decode_attention/kernel.py", "decode_attention_bkv",
        _case_decode_attention),
    "swa_attention.swa_attention_bhsd": (
        "repro/kernels/swa_attention/kernel.py", "swa_attention_bhsd",
        _case_swa_attention),
}

_REPORT_CACHE: dict[str, KernelReport] = {}


def get_report(kernel_id: str) -> KernelReport:
    """The cached race verdict for a registered kernel — this is what
    ``repro.kernels.dispatch.select_impl`` reads."""
    rep = _REPORT_CACHE.get(kernel_id)
    if rep is None:
        path, fn_name, case = KERNEL_CASES[kernel_id]
        rep = analyze_callable(kernel_id, fn_name, case)
        _REPORT_CACHE[kernel_id] = rep
    return rep


def all_reports() -> list[KernelReport]:
    return [get_report(k) for k in KERNEL_CASES]


# ---------------------------------------------------------------------------
# tree scan: PAL001 on reports, PAL002-PAL004 on the kernels/ source tree
# ---------------------------------------------------------------------------
def _def_line(path: Path, fn_name: str) -> int:
    try:
        tree = ast.parse(path.read_text())
    except (OSError, SyntaxError):
        return 0
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == fn_name:
            return node.lineno
    return 0


def _registered_fn_names() -> set:
    return {fn for _, fn, _ in KERNEL_CASES.values()}


def scan(root: Path, files: list[Path]) -> tuple[list[KernelReport],
                                                 list[Finding]]:
    """Analyze the registered corpus and lint the kernels/ source tree.
    ``files`` is the full scan set; only paths under ``repro/kernels/`` are
    inspected here."""
    findings: list[Finding] = []
    kernel_files = [f for f in files
                    if "repro/kernels/" in f.as_posix()]

    reports = all_reports()
    by_suffix = {suffix: (kid, fn) for kid, (suffix, fn, _)
                 in KERNEL_CASES.items()}
    for rep in reports:
        suffix, fn_name, _ = KERNEL_CASES[rep.kernel_id]
        src = next((f for f in kernel_files
                    if f.as_posix().endswith(suffix)), None)
        line = _def_line(src, fn_name) if src else 0
        path = src.as_posix() if src else suffix
        if rep.classification == "racy":
            findings.append(Finding(
                "PAL001", path, line,
                f"kernel {rep.kernel_id} is racy on grid {rep.grid}: an "
                "output block is revisited by non-consecutive grid cells"))
        for ax, extent in enumerate(rep.grid):
            if extent < 2:
                findings.append(Finding(
                    "PAL004", path, line,
                    f"case for {rep.kernel_id} exercises only {extent} "
                    f"block(s) on grid axis {ax}; aliasing there is "
                    "invisible to the race analysis"))

    registered = _registered_fn_names()
    for f in kernel_files:
        try:
            tree = ast.parse(f.read_text())
        except SyntaxError as e:
            findings.append(Finding("PAL002", f.as_posix(), e.lineno or 0,
                                    f"unparseable kernel file: {e.msg}"))
            continue
        is_dispatch = f.name == "dispatch.py"
        for node in ast.walk(tree):
            if isinstance(node, ast.FunctionDef):
                has_pc = any(
                    isinstance(c, ast.Call)
                    and isinstance(c.func, ast.Attribute)
                    and c.func.attr == "pallas_call"
                    for c in ast.walk(node))
                if has_pc and node.name not in registered:
                    findings.append(Finding(
                        "PAL002", f.as_posix(), node.lineno,
                        f"function {node.name!r} builds a pallas_call but "
                        "has no registered case in "
                        "repro.check.pallas_race.KERNEL_CASES"))
            if (not is_dispatch and isinstance(node, ast.Attribute)
                    and node.attr == "default_backend"):
                findings.append(Finding(
                    "PAL003", f.as_posix(), node.lineno,
                    "hand-rolled backend dispatch in kernels/: derive "
                    "legality via repro.kernels.dispatch.select_impl"))
    return reports, findings

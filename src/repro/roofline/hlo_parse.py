"""Trip-count-aware HLO text analysis.

XLA's ``cost_analysis`` counts ``while``-loop bodies ONCE, so scanned-layer
models under-report FLOPs/collective-bytes by the layer count (verified
empirically — see EXPERIMENTS.md §Dry-run methodology).  This parser walks
the compiled HLO text, recovers each scan loop's static trip count from its
condition computation, and propagates multipliers through the call graph
(while bodies, fusions, to_apply reducers), yielding:

  * matmul FLOPs  — 2 * prod(result_dims) * prod(lhs_contracting_dims),
                    exact for ``dot`` (the FLOP-dominant op class);
  * collective bytes by op kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), with ring-traffic conventions.

All numbers are PER DEVICE (the SPMD module is the per-partition program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+"
                    r"([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(.*)?\{\s*$")
_CALL_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else (dt, [])


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str          # the remainder of the line after the opcode paren


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # op name -> type str


@dataclass
class HloStats:
    dot_flops: float = 0.0
    collective_bytes: dict = field(default_factory=dict)  # kind -> bytes
    while_trips: dict = field(default_factory=dict)       # body name -> trips

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _split_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            # computation headers sit at column 0 and end with '{'
            # (op lines are indented, so the anchored regex skips them)
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Computation(m.group(1))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, type_str, opcode, rest = m.groups()
            cur.ops.append(Op(name, type_str, opcode, rest))
            cur.shapes[name] = type_str
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _entry_name(text: str, comps) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    # fall back: a computation not called by any other
    called = set()
    for c in comps.values():
        for op in c.ops:
            called.update(_CALL_RE.findall(op.rest))
    for name in comps:
        if name not in called:
            return name
    return next(iter(comps))


def _trip_count(cond_name: str, comps, seen=None) -> int:
    """Max integer constant reachable from the while condition — scan loops
    compare the induction var LT a literal trip count."""
    seen = seen or set()
    if cond_name in seen or cond_name not in comps:
        return 1
    seen.add(cond_name)
    best = 1
    comp = comps[cond_name]
    for op in comp.ops:
        if op.opcode == "constant":
            m = re.match(r"\s*(\d+)\s*\)", op.rest)
            if m:
                best = max(best, int(m.group(1)))
        for c in _CONST_RE.findall(op.rest):
            best = max(best, int(c))
        for callee in _CALL_RE.findall(op.rest):
            if callee != cond_name:
                best = max(best, _trip_count(callee, comps, seen))
    return best


def _dot_flops(op: Op, comp: Computation) -> float:
    _, out_dims = _shape_dims(op.type_str)
    if out_dims is None:
        return 0.0
    lhs_dims = []
    # newer XLA prints operand types inline: dot(f32[128,256]{1,0} %lhs, …)
    mt = re.match(r"\s*(\w+)\[([\d,]*)\]", op.rest)
    if mt:
        lhs_dims = [int(d) for d in mt.group(2).split(",") if d]
    else:                    # older format: dot(%lhs, %rhs) — look up shape
        m = re.match(r"\s*%([\w\.\-]+)", op.rest)
        if m and m.group(1) in comp.shapes:
            _, lhs_dims = _shape_dims(comp.shapes[m.group(1)])
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contract = 1
    if mc and lhs_dims:
        for d in mc.group(1).split(","):
            if d:
                contract *= lhs_dims[int(d)]
    out = 1
    for d in out_dims:
        out *= d
    return 2.0 * out * contract


def _collective_bytes(op: Op, comp: Computation) -> float:
    """Ring-traffic convention per op kind (bytes crossing links/device)."""
    res = shape_bytes(op.type_str)
    if op.opcode == "all-reduce":
        return 2.0 * res                     # reduce-scatter + all-gather ring
    if op.opcode == "reduce-scatter":
        # traffic ~ input size; look up the first operand's shape
        m = re.match(r"\s*%([\w\.\-]+)", op.rest)
        if m and m.group(1) in comp.shapes:
            return float(shape_bytes(comp.shapes[m.group(1)]))
        return float(res)
    return float(res)                        # all-gather / a2a / permute


def parse_hlo_module(text: str) -> HloStats:
    comps = _split_computations(text)
    entry = _entry_name(text, comps)
    stats = HloStats(collective_bytes={k: 0.0 for k in _COLLECTIVES})

    # propagate multipliers through the call graph
    mult: dict[str, float] = {}

    def visit(name: str, m: float, stack=()):
        if name not in comps or name in stack:
            return
        mult[name] = mult.get(name, 0.0) + m
        comp = comps[name]
        for op in comp.ops:
            if op.opcode == "while":
                wm = _WHILE_RE.search(op.rest)
                if wm:
                    cond, body = wm.groups()
                    trips = _trip_count(cond, comps)
                    stats.while_trips[body] = trips
                    visit(body, m * trips, stack + (name,))
                    visit(cond, m * trips, stack + (name,))
                continue
            for callee in _CALL_RE.findall(op.rest):
                visit(callee, m, stack + (name,))

    visit(entry, 1.0)

    for name, m in mult.items():
        comp = comps[name]
        for op in comp.ops:
            if op.opcode == "dot":
                stats.dot_flops += m * _dot_flops(op, comp)
            elif op.opcode in _COLLECTIVES:
                stats.collective_bytes[op.opcode] += \
                    m * _collective_bytes(op, comp)
    return stats

from repro.roofline.hlo_parse import parse_hlo_module, HloStats
from repro.roofline.analysis import RooflineTerms, roofline_terms, V5E

__all__ = ["parse_hlo_module", "HloStats", "RooflineTerms", "roofline_terms",
           "V5E"]

"""Three-term roofline model for the dry-run artifacts (EXPERIMENTS.md §Roofline).

    compute    = FLOPs_per_device / peak_flops
    memory     = HBM_bytes_per_device / hbm_bw
    collective = collective_bytes_per_device / ici_bw

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (from the brief).  FLOPs and collective bytes come from the trip-corrected
HLO parse (``hlo_parse``); HBM bytes are estimated from the compiled buffer
assignment: every argument read once + outputs written once + temps written
and read once (2x) — the streaming lower bound for one step.
"""
from __future__ import annotations

from dataclasses import dataclass, asdict

from repro.roofline.hlo_parse import HloStats, parse_hlo_module


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per link
    hbm_bytes: float           # capacity per chip


V5E = Hardware(name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9,
               ici_bw=50e9, hbm_bytes=16 * 2 ** 30)


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float          # 6*N*D (or 6*N_active*D) global
    useful_flops_ratio: float   # model_flops / (flops_per_device * n_chips)
    memory_per_device_bytes: float  # peak HBM residency (fits check)
    fits_hbm: bool
    collective_breakdown: dict
    raw_cost_analysis_flops: float

    def to_dict(self):
        return asdict(self)


def roofline_terms(*, arch: str, shape: str, mesh_name: str, n_chips: int,
                   hlo_stats: HloStats, memory_stats, cost_flops: float,
                   model_flops: float, tokens: int,
                   hw: Hardware = V5E) -> RooflineTerms:
    flops = hlo_stats.dot_flops
    coll = hlo_stats.total_collective_bytes
    arg_b = memory_stats.argument_size_in_bytes
    out_b = memory_stats.output_size_in_bytes
    tmp_b = memory_stats.temp_size_in_bytes
    alias_b = getattr(memory_stats, "alias_size_in_bytes", 0)
    hbm_traffic = arg_b + out_b + 2.0 * tmp_b
    # donated (aliased) outputs live in their argument buffers
    resident = arg_b + (out_b - alias_b) + tmp_b

    compute_s = flops / hw.peak_flops
    memory_s = hbm_traffic / hw.hbm_bw
    collective_s = coll / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name,
        flops_per_device=flops,
        hbm_bytes_per_device=hbm_traffic,
        collective_bytes_per_device=coll,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / (flops * n_chips)
                            if flops else 0.0),
        memory_per_device_bytes=resident,
        fits_hbm=resident <= hw.hbm_bytes,
        collective_breakdown=dict(hlo_stats.collective_bytes),
        raw_cost_analysis_flops=cost_flops,
    )


def model_flops_estimate(cfg, shape) -> float:
    """6*N*D for training, 2*N*D for inference (N = active params,
    D = tokens processed).  Decode processes global_batch tokens per step."""
    from repro.models.transformer import param_count
    n_active = param_count(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # one token per sequence

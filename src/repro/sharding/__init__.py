from repro.sharding.specs import (batch_spec, cache_specs, needs_fsdp,
                                  param_specs, spec_tree_to_shardings)

__all__ = ["batch_spec", "cache_specs", "needs_fsdp", "param_specs",
           "spec_tree_to_shardings"]

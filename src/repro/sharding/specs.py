"""Per-arch PartitionSpec rules.

Strategy (DESIGN.md §5):
  * TP   — contraction/head/expert dims sharded on the ``model`` axis;
  * FSDP — additionally shard the d_model-ish dim over (``pod``,) ``data``
           when the unsharded per-device parameter bytes would blow HBM
           (``needs_fsdp``); GSPMD then emits all-gather on use +
           reduce-scatter on grads (ZeRO-3 semantics);
  * every rule checks divisibility against the actual mesh axis sizes and
    silently degrades to replication for that dim — so the same rules drive
    every arch on every mesh.

Rules are keyed on the *leaf path* of the params pytree (plain dicts), so
model code stays sharding-free.  Leaves under ``stack`` carry a leading
period axis which is never sharded.
"""
from __future__ import annotations

import functools

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import transformer as T

# param-bytes-per-device (bf16, model-axis TP only) above which FSDP turns on
_FSDP_THRESHOLD_BYTES = 2 << 30


def needs_fsdp(cfg: ArchConfig, model_par: int = 16) -> bool:
    return T.param_count(cfg) * 2 / model_par > _FSDP_THRESHOLD_BYTES


def _axes(mesh: Mesh):
    names = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    return data_axes, ("model" if "model" in names else None)


def _size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _ok(dim: int, mesh: Mesh, axes) -> bool:
    return axes is not None and dim % _size(mesh, axes) == 0


def _leaf_spec(cfg, mesh, fsdp_axes, path_names, shape) -> P:
    """The rule table.  ``shape`` excludes any leading period axis."""
    name = path_names[-1]
    parent = path_names[-2] if len(path_names) > 1 else ""
    model = "model"
    dp = fsdp_axes if fsdp_axes else None

    def pick(*dims):
        """dims: one proposed axis-assignment per tensor dim; degrade each
        to None unless divisible."""
        return P(*[a if _ok(shape[i], mesh, a) else None
                   for i, a in enumerate(dims)])

    # ---- embeddings / head ------------------------------------------------
    if name == "table":
        return pick(model, dp)
    if parent == "lm_head":
        return pick(dp, model)
    # ---- norms / scalars --------------------------------------------------
    if name in ("scale", "bias", "mu", "w0", "u", "ln_scale", "dt_bias",
                "D", "conv_b"):
        return P(*([None] * len(shape)))
    # ---- MoE ---------------------------------------------------------------
    if name == "router":
        return pick(dp, None)
    if parent != "mixer" and name in ("w_gate", "w_up") and len(shape) == 3:
        return pick(model, dp, None)            # [E, d, f] expert-parallel
    if name == "w_down" and len(shape) == 3:
        return pick(model, None, dp)            # [E, f, d]
    # ---- dense MLP -----------------------------------------------------------
    if name in ("w_gate", "w_up"):
        return pick(dp, model)                  # [d, f]
    if name == "w_down":
        return pick(model, dp)                  # [f, d]
    # ---- attention -------------------------------------------------------------
    if name == "wq" and len(shape) == 3:
        return pick(dp, model, None)            # [d, H, hd]
    if name in ("wk", "wv") and len(shape) == 3:
        return pick(dp, model, None)            # [d, Kv, hd]
    if name == "wo" and len(shape) == 3:
        return pick(model, None, dp)            # [H, hd, d]
    if name in ("bq", "bk", "bv"):
        return pick(model, None)
    # ---- MLA ----------------------------------------------------------------
    if name == "w_dkv":
        return pick(dp, model)                  # [d, lora]
    if name == "w_krope":
        return pick(dp, None)
    if name in ("w_uk", "w_uv"):
        return pick(None, model, None)          # [lora, H, *]
    # ---- mamba ------------------------------------------------------------------
    if name == "in_proj":
        return pick(dp, model)                  # [d, 2di]
    if name == "conv_w":
        return pick(None, model)                # [dc, di]
    if name == "x_proj":
        return pick(model, None)                # [di, r]
    if name == "dt_proj":
        return pick(None, model)                # [r, di]
    if name == "A_log":
        return pick(model, None)                # [di, ds]
    if name == "out_proj":
        return pick(model, dp)                  # [di, d]
    # ---- rwkv ----------------------------------------------------------------------
    if name in ("wr", "wk", "wv", "wg", "wo"):
        return pick(dp, model)                  # [d, d] / [d, ff]
    if name == "wA":
        return pick(dp, None)
    if name == "wB":
        return pick(None, model)
    # default: replicate
    return P(*([None] * len(shape)))


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return out


def param_specs(cfg: ArchConfig, mesh: Mesh, fsdp: bool | None = None):
    """PartitionSpec pytree matching ``init_params(cfg)``."""
    if fsdp is None:
        fsdp = needs_fsdp(cfg, _size(mesh, "model") if "model" in
                          mesh.axis_names else 1)
    data_axes, _ = _axes(mesh)
    fsdp_axes = data_axes if fsdp else ()
    shapes = jax.eval_shape(
        functools.partial(T.init_params, cfg, dtype=jax.numpy.bfloat16),
        jax.random.PRNGKey(0))

    def assign(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        if "stack" in names:                  # leading period axis: unsharded
            spec = _leaf_spec(cfg, mesh, fsdp_axes, names, shape[1:])
            return P(None, *spec)
        return _leaf_spec(cfg, mesh, fsdp_axes, names, shape)

    return jax.tree_util.tree_map_with_path(assign, shapes)


def cache_specs(cfg: ArchConfig, mesh: Mesh, batch: int, max_seq: int):
    """PartitionSpec pytree for ``init_cache``: batch -> data axes, and the
    long sequence axis of attention/MLA caches -> ``model`` (partial-softmax
    collectives are GSPMD-inserted); SSM states shard their channel dim."""
    data_axes, _ = _axes(mesh)
    dp = data_axes if _ok_int(batch, mesh, data_axes) else None
    shapes = jax.eval_shape(
        lambda: T.init_cache(cfg, batch, max_seq, jax.numpy.bfloat16))

    def assign(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        lead = 0
        if "stack" in names:
            lead, shape = 1, shape[1:]
        name = names[-1]
        if name in ("k", "v"):               # [B, S|W|C, Kv, hd]
            seq_ax = "model" if _ok_int(shape[1], mesh, "model") else None
            spec = P(dp, seq_ax, None, None)
        elif name == "c_kv" or name == "k_rope":   # [B, S, lora|rope]
            seq_ax = "model" if _ok_int(shape[1], mesh, "model") else None
            spec = P(dp, seq_ax, None)
        elif name == "conv":                 # [B, dc-1, di]
            di_ax = "model" if _ok_int(shape[2], mesh, "model") else None
            spec = P(dp, None, di_ax)
        elif name == "ssm":                  # [B, di, ds]
            di_ax = "model" if _ok_int(shape[1], mesh, "model") else None
            spec = P(dp, di_ax, None)
        elif name == "wkv":                  # [B, H, N, N]
            h_ax = "model" if _ok_int(shape[1], mesh, "model") else None
            spec = P(dp, h_ax, None, None)
        elif name == "shift":                # [B, d]
            d_ax = "model" if _ok_int(shape[1], mesh, "model") else None
            spec = P(dp, d_ax)
        else:
            spec = P(*([None] * len(shape)))
        return P(*([None] * lead), *spec)

    return jax.tree_util.tree_map_with_path(assign, shapes)


def _ok_int(dim: int, mesh: Mesh, axes) -> bool:
    return _ok(dim, mesh, axes)


def batch_spec(mesh: Mesh, global_batch: int) -> P:
    """Token batches shard over (pod, data) when divisible."""
    data_axes, _ = _axes(mesh)
    if data_axes and global_batch % _size(mesh, data_axes) == 0:
        return P(data_axes)
    # degrade: drop 'pod' first, then replicate
    if "data" in mesh.axis_names and global_batch % mesh.shape["data"] == 0:
        return P("data")
    return P(None)


def spec_tree_to_shardings(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))

from repro.models import transformer, cnn, frontends  # noqa: F401

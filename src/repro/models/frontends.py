"""Modality frontend STUBS — the one sanctioned carve-out (see the brief):
for [vlm]/[audio] architectures we implement the language/decoder transformer
only; the ViT / EnCodec feature extractors are stand-ins that provide
correctly-shaped embeddings (or token ids).

``input_specs``-side helpers live in ``repro.launch.dryrun``; these utilities
generate *concrete* stub embeddings for smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


class VisionFrontendStub:
    """InternViT+projector stand-in: (B, n_tokens, d_model) patch embeddings."""

    def __init__(self, cfg):
        assert cfg.frontend == "vision"
        self.n_tokens = cfg.n_frontend_tokens
        self.d_model = cfg.d_model

    def __call__(self, key, batch, dtype=jnp.float32):
        return jax.random.normal(
            key, (batch, self.n_tokens, self.d_model)).astype(dtype) * 0.02

    def spec(self, batch, dtype):
        return jax.ShapeDtypeStruct((batch, self.n_tokens, self.d_model),
                                    dtype)


class AudioFrontendStub:
    """EnCodec stand-in: MusicGen consumes codec token ids directly, so the
    stub emits integer codes in [0, vocab)."""

    def __init__(self, cfg):
        assert cfg.frontend == "audio"
        self.vocab = cfg.vocab_size

    def __call__(self, key, batch, seq_len):
        return jax.random.randint(key, (batch, seq_len), 0, self.vocab,
                                  jnp.int32)


def frontend_for(cfg):
    if cfg.frontend == "vision":
        return VisionFrontendStub(cfg)
    if cfg.frontend == "audio":
        return AudioFrontendStub(cfg)
    return None

"""Mamba (S6 selective SSM) block for the Jamba hybrid architecture.

Training/prefill runs the selective scan with ``jax.lax.scan`` over the
sequence; decode is a single recurrence step.  State:
  conv state [B, d_conv-1, d_inner]   (causal conv tail)
  ssm  state [B, d_inner, d_state]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.modules import chunked_scan, dense_init

SCAN_CHUNK = 64  # sqrt-remat chunk for the selective scan (see chunked_scan)


def _d_inner(cfg):
    return cfg.mamba_expand * cfg.d_model


def init_mamba(cfg, key, dtype):
    d, di, ds = cfg.d_model, _d_inner(cfg), cfg.mamba_d_state
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.mamba_d_conv, di)) /
                   np.sqrt(cfg.mamba_d_conv)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * ds, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, di, dtype),
        "dt_bias": jnp.full((di,), np.log(np.expm1(0.01)), dtype),
        "A_log": jnp.log(A),                        # fp32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _ssm_params(cfg, p, xc):
    """xc: [..., di] post-conv activations -> (dt, Bm, Cm) selective params."""
    ds = cfg.mamba_d_state
    dt_rank = p["dt_proj"].shape[0]
    dbc = jnp.einsum("...i,ir->...r", xc, p["x_proj"])
    dt = jax.nn.softplus(
        jnp.einsum("...r,ri->...i", dbc[..., :dt_rank], p["dt_proj"])
        .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    Bm = dbc[..., dt_rank:dt_rank + ds].astype(jnp.float32)
    Cm = dbc[..., dt_rank + ds:].astype(jnp.float32)
    return dt, Bm, Cm


def _step(cfg, p, h, xc_t, dt_t, B_t, C_t):
    """One recurrence step. h:[B,di,ds]; xc_t:[B,di]; B_t,C_t:[B,ds]."""
    A = -jnp.exp(p["A_log"])                               # [di, ds]
    dA = jnp.exp(dt_t[..., None] * A[None])                # [B,di,ds]
    dBx = (dt_t * xc_t.astype(jnp.float32))[..., None] * B_t[:, None, :]
    h = dA * h + dBx
    y = jnp.einsum("bis,bs->bi", h, C_t)
    return h, y


def mamba_fwd(cfg, p, x):
    """x: [B,S,d] -> (y, cache) running the full selective scan."""
    B, S, d = x.shape
    di, dc = _d_inner(cfg), cfg.mamba_d_conv
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv
    pad = jnp.zeros((B, dc - 1, di), xin.dtype)
    xp = jnp.concatenate([pad, xin], axis=1)
    xc = sum(xp[:, i:i + S, :] * p["conv_w"][i] for i in range(dc))
    xc = jax.nn.silu((xc + p["conv_b"]).astype(jnp.float32)).astype(x.dtype)

    dt, Bm, Cm = _ssm_params(cfg, p, xc)

    def body(h, inp):
        xc_t, dt_t, B_t, C_t = inp
        h, y = _step(cfg, p, h, xc_t, dt_t, B_t, C_t)
        return h, y

    h0 = jnp.zeros((B, di, cfg.mamba_d_state), jnp.float32)
    xs = (jnp.swapaxes(xc, 0, 1), jnp.swapaxes(dt, 0, 1),
          jnp.swapaxes(Bm, 0, 1), jnp.swapaxes(Cm, 0, 1))
    h_last, ys = chunked_scan(body, h0, xs, SCAN_CHUNK)
    y = jnp.swapaxes(ys, 0, 1)                             # [B,S,di]
    y = y + xc.astype(jnp.float32) * p["D"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    cache = {"conv": xp[:, -(dc - 1):, :], "ssm": h_last}
    return out, cache


def mamba_decode(cfg, p, x, cache):
    """x: [B,1,d]; cache: {'conv':[B,dc-1,di], 'ssm':[B,di,ds]}."""
    B = x.shape[0]
    di, dc = _d_inner(cfg), cfg.mamba_d_conv
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]
    xin, z = jnp.split(xz, 2, axis=-1)                     # [B,di]
    window = jnp.concatenate([cache["conv"], xin[:, None, :]], axis=1)
    xc = jnp.einsum("bci,ci->bi", window, p["conv_w"])
    xc = jax.nn.silu((xc + p["conv_b"]).astype(jnp.float32)).astype(x.dtype)
    dt, Bm, Cm = _ssm_params(cfg, p, xc)
    h, y = _step(cfg, p, cache["ssm"], xc, dt, Bm, Cm)
    y = y + xc.astype(jnp.float32) * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"])[:, None, :]
    return out, {"conv": window[:, 1:, :], "ssm": h}


def init_mamba_cache(cfg, batch, dtype):
    di = _d_inner(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
    }

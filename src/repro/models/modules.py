"""Minimal pure-pytree building blocks (no flax/haiku — params are dicts).

All ``init_*`` return nested dicts of jnp arrays; all ``*_fwd`` are pure.
Norm statistics are computed in float32 regardless of param dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, in_dim, out_shape, dtype, scale=None):
    """Variance-scaled init for a weight of shape (in_dim, *out_shape)."""
    if isinstance(out_shape, int):
        out_shape = (out_shape,)
    shape = (in_dim, *out_shape)
    if scale is None:
        scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rmsnorm_init(dim, dtype):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim, dtype):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def embed_init(key, vocab, dim, dtype):
    return {"table": (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)}


def embed_lookup(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: [..., S, H, hd] (hd even); positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))           # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                   # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def chunked_scan(body, carry, xs, chunk: int):
    """``lax.scan`` over the leading axis of ``xs`` with sqrt-style remat.

    The sequence is split into chunks; the inner per-chunk scan is wrapped in
    ``jax.checkpoint`` so AD saves only chunk-boundary carries instead of one
    carry per step (O(S) -> O(S/chunk + chunk) live states).  Required for the
    Mamba/RWKV recurrences at seq_len=4k+ (DESIGN.md §5).
    """
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    if length % chunk != 0 or length <= chunk:
        return jax.lax.scan(body, carry, xs)
    n = length // chunk
    xs_c = jax.tree_util.tree_map(
        lambda a: a.reshape(n, chunk, *a.shape[1:]), xs)

    @jax.checkpoint
    def chunk_body(c, x_chunk):
        return jax.lax.scan(body, c, x_chunk)

    carry, ys_c = jax.lax.scan(chunk_body, carry, xs_c)
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape(length, *a.shape[2:]), ys_c)
    return carry, ys


def swiglu_mlp_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu_mlp(params, x):
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])

"""The paper's CNN (Section III-B / V-A): the model each vehicle trains on its
private MNIST shard.  conv(32,3x3)-relu-pool / conv(64,3x3)-relu-pool /
dense(128)-relu / dense(10), cross-entropy loss (Eq. 1), plain SGD (Eq. 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def init_cnn(key, num_classes=10, dtype=jnp.float32):
    ks = jax.random.split(key, 4)

    def conv_init(k, shape):  # HWIO
        fan_in = shape[0] * shape[1] * shape[2]
        return (jax.random.normal(k, shape) / np.sqrt(fan_in)).astype(dtype)

    return {
        "conv1_w": conv_init(ks[0], (3, 3, 1, 32)),
        "conv1_b": jnp.zeros((32,), dtype),
        "conv2_w": conv_init(ks[1], (3, 3, 32, 64)),
        "conv2_b": jnp.zeros((64,), dtype),
        "fc1_w": (jax.random.normal(ks[2], (7 * 7 * 64, 128)) /
                  np.sqrt(7 * 7 * 64)).astype(dtype),
        "fc1_b": jnp.zeros((128,), dtype),
        "fc2_w": (jax.random.normal(ks[3], (128, num_classes)) /
                  np.sqrt(128)).astype(dtype),
        "fc2_b": jnp.zeros((num_classes,), dtype),
    }


def _max_pool_2x2(x):
    """2x2/stride-2 max pool via reshape+max.

    Equivalent to ``lax.reduce_window`` max pooling on even inputs, but its
    VJP is a broadcasted compare/select instead of XLA's SelectAndScatter —
    which dominated the whole train step on CPU (~0.26 s of a 0.41 s step
    at batch 128; reshape-max cuts the step to ~0.15 s)."""
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def cnn_forward(params, images):
    """images: [B, 28, 28, 1] -> logits [B, num_classes]."""
    dn = jax.lax.conv_dimension_numbers(images.shape,
                                        params["conv1_w"].shape,
                                        ("NHWC", "HWIO", "NHWC"))
    x = jax.lax.conv_general_dilated(images, params["conv1_w"], (1, 1),
                                     "SAME", dimension_numbers=dn)
    x = _max_pool_2x2(jax.nn.relu(x + params["conv1_b"]))
    dn2 = jax.lax.conv_dimension_numbers(x.shape, params["conv2_w"].shape,
                                         ("NHWC", "HWIO", "NHWC"))
    x = jax.lax.conv_general_dilated(x, params["conv2_w"], (1, 1), "SAME",
                                     dimension_numbers=dn2)
    x = _max_pool_2x2(jax.nn.relu(x + params["conv2_b"]))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1_w"] + params["fc1_b"])
    return x @ params["fc2_w"] + params["fc2_b"]


def cross_entropy_loss(logits, labels):
    """Eq. (1): -sum_a y_a log(yhat_a), mean-reduced over the batch."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def accuracy(logits, labels):
    """Eq. (12)."""
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))


@jax.jit
def sgd_train_step(params, images, labels, lr):
    """One local iteration: Eqs. (1)-(2)."""
    def loss_fn(p):
        return cross_entropy_loss(cnn_forward(p, images), labels)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params = jax.tree_util.tree_map(lambda w, g: w - lr * g, params, grads)
    return params, loss

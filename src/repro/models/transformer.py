"""Decoder-LM assembly for every assigned architecture.

One generic stack interprets an ``ArchConfig``:
  * scan-over-periods (``lax.scan`` + ``jax.checkpoint``) keeps HLO size and
    activation memory depth-independent (mandatory for llama3-405b);
  * heterogeneous layer patterns (jamba 1:7, llama4 chunked/global, deepseek
    first-k-dense) are expressed as one "period" of sublayers that repeats;
  * three entry points: ``forward`` (train), ``prefill`` (build cache),
    ``decode_step`` (one token against a cache).

Params / caches are plain nested dicts -> trivially shardable by path rules
(``repro.sharding.specs``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import (ArchConfig, MIXER_ATTN, MIXER_ATTN_GLOBAL,
                                MIXER_MAMBA, MIXER_MLA, MIXER_RWKV, MLP_DENSE,
                                MLP_MOE, MLP_RWKV, SubLayer)
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.modules import (dense_init, embed_init, embed_lookup,
                                  layernorm, layernorm_init, rmsnorm,
                                  rmsnorm_init, swiglu_mlp, swiglu_mlp_init)


def _norm_init(cfg, dtype):
    return layernorm_init(cfg.d_model, dtype) if cfg.family == "ssm" \
        else rmsnorm_init(cfg.d_model, dtype)


def _norm(cfg, p, x):
    return layernorm(p, x, cfg.norm_eps) if cfg.family == "ssm" \
        else rmsnorm(p, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_sublayer(cfg: ArchConfig, key, sub: SubLayer, dtype):
    k1, k2 = jax.random.split(key)
    p = {"ln1": _norm_init(cfg, dtype), "ln2": _norm_init(cfg, dtype)}
    if sub.mixer in (MIXER_ATTN, MIXER_ATTN_GLOBAL):
        p["mixer"] = attn.init_attention(cfg, k1, dtype)
    elif sub.mixer == MIXER_MLA:
        p["mixer"] = attn.init_mla(cfg, k1, dtype)
    elif sub.mixer == MIXER_MAMBA:
        p["mixer"] = mamba_mod.init_mamba(cfg, k1, dtype)
    elif sub.mixer == MIXER_RWKV:
        p["mixer"] = rwkv_mod.init_time_mix(cfg, k1, dtype)
    else:
        raise ValueError(sub.mixer)
    if sub.mlp == MLP_DENSE:
        p["mlp"] = swiglu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    elif sub.mlp == MLP_MOE:
        p["mlp"] = moe_mod.init_moe(cfg, k2, dtype)
    elif sub.mlp == MLP_RWKV:
        p["mlp"] = rwkv_mod.init_channel_mix(cfg, k2, dtype)
    else:
        raise ValueError(sub.mlp)
    return p


def _init_period(cfg: ArchConfig, key, dtype):
    subs = cfg.sublayers()
    keys = jax.random.split(key, len(subs))
    return {f"sub{j}": _init_sublayer(cfg, keys[j], sub, dtype)
            for j, sub in enumerate(subs)}


def init_params(cfg: ArchConfig, key, dtype=jnp.float32):
    ks = jax.random.split(key, 4 + cfg.first_k_dense)
    params = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": _norm_init(cfg, dtype),
        "stack": jax.vmap(
            lambda k: _init_period(cfg, k, dtype))(
                jax.random.split(ks[1], cfg.n_periods)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": dense_init(ks[2], cfg.d_model, cfg.vocab_size, dtype)}
    if cfg.first_k_dense:
        params["prefix"] = [
            _init_sublayer(cfg, ks[4 + i], cfg.prefix_sublayer(), dtype)
            for i in range(cfg.first_k_dense)]
    return params


# ---------------------------------------------------------------------------
# sublayer application
# ---------------------------------------------------------------------------
def _apply_sublayer(cfg, p, sub: SubLayer, h, positions):
    """Train/prefill path. Returns (h, aux_loss, cache)."""
    x = _norm(cfg, p["ln1"], h)
    cache = {}
    if sub.mixer in (MIXER_ATTN, MIXER_ATTN_GLOBAL):
        kind, width = attn.mask_spec_for(cfg, sub.mixer)
        y, c = attn.attention_fwd(cfg, p["mixer"], x, positions, kind, width)
    elif sub.mixer == MIXER_MLA:
        y, c = attn.mla_fwd(cfg, p["mixer"], x, positions)
    elif sub.mixer == MIXER_MAMBA:
        y, c = mamba_mod.mamba_fwd(cfg, p["mixer"], x)
    else:
        y, c = rwkv_mod.time_mix_fwd(cfg, p["mixer"], x)
    cache["mixer"] = c
    h = h + y
    x = _norm(cfg, p["ln2"], h)
    aux = jnp.zeros((), jnp.float32)
    if sub.mlp == MLP_DENSE:
        y = swiglu_mlp(p["mlp"], x)
    elif sub.mlp == MLP_MOE:
        y, aux = moe_mod.moe_fwd(cfg, p["mlp"], x)
    else:
        y, cm = rwkv_mod.channel_mix_fwd(cfg, p["mlp"], x)
        cache["mlp"] = cm
    h = h + y
    return h, aux, cache


def _apply_sublayer_decode(cfg, p, sub: SubLayer, h, cache, pos):
    """One-token path. Returns (h, new_cache)."""
    x = _norm(cfg, p["ln1"], h)
    new_cache = {}
    if sub.mixer in (MIXER_ATTN, MIXER_ATTN_GLOBAL):
        kind, width = attn.mask_spec_for(cfg, sub.mixer)
        y, c = attn.attention_decode(cfg, p["mixer"], x, cache["mixer"], pos,
                                     kind, width)
    elif sub.mixer == MIXER_MLA:
        y, c = attn.mla_decode(cfg, p["mixer"], x, cache["mixer"], pos)
    elif sub.mixer == MIXER_MAMBA:
        y, c = mamba_mod.mamba_decode(cfg, p["mixer"], x, cache["mixer"])
    else:
        y, c = rwkv_mod.time_mix_decode(cfg, p["mixer"], x, cache["mixer"])
    new_cache["mixer"] = c
    h = h + y
    x = _norm(cfg, p["ln2"], h)
    if sub.mlp == MLP_DENSE:
        y = swiglu_mlp(p["mlp"], x)
    elif sub.mlp == MLP_MOE:
        y, _ = moe_mod.moe_decode(cfg, p["mlp"], x)
    else:
        y, cm = rwkv_mod.channel_mix_decode(cfg, p["mlp"], x, cache["mlp"])
        new_cache["mlp"] = cm
    h = h + y
    return h, new_cache


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------
def _embed_inputs(cfg, params, tokens, frontend_embeds):
    h = embed_lookup(params["embed"], tokens)
    if cfg.frontend == "vision" and cfg.n_frontend_tokens:
        assert frontend_embeds is not None, \
            f"{cfg.name} requires frontend_embeds (B,{cfg.n_frontend_tokens},d)"
        h = jnp.concatenate([frontend_embeds.astype(h.dtype), h], axis=1)
    return h


def _lm_head(cfg, params, h):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", h, params["embed"]["table"])
    return jnp.einsum("bsd,dv->bsv", h, params["lm_head"]["w"])


# ---------------------------------------------------------------------------
# forward (train)
# ---------------------------------------------------------------------------
def _maybe_shard_h(cfg, h):
    """Optional activation-sharding constraint between layers: batch on
    ``data`` AND d_model on ``model`` (sequence-parallel analog).

    Anchoring the batch axis matters: without it GSPMD may pick
    contraction-sharded matmuls (batch replicated, d contracted over the
    data axis) whose partial sums emit an [B,S,d]-sized all-reduce per
    matmul per layer — measured 38.8 TB/device/step on llama3-405b
    (EXPERIMENTS.md §Perf iteration 4)."""
    if not cfg.shard_activations:
        return h
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(h, P("data", None, "model"))


def forward(cfg: ArchConfig, params, tokens, frontend_embeds=None):
    """Returns (logits [B,S,V], aux_loss scalar)."""
    h = _embed_inputs(cfg, params, tokens, frontend_embeds)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    subs = cfg.sublayers()
    aux_total = jnp.zeros((), jnp.float32)

    for p in params.get("prefix", []):
        h, aux, _ = _apply_sublayer(cfg, p, cfg.prefix_sublayer(), h,
                                    positions)
        aux_total = aux_total + aux
    h = _maybe_shard_h(cfg, h)

    period_fn = _make_period_fn(cfg, subs, positions)
    (h, aux_total), _ = jax.lax.scan(period_fn, (h, aux_total),
                                     params["stack"])
    h = _norm(cfg, params["final_norm"], h)
    return _lm_head(cfg, params, h), aux_total


def _make_period_fn(cfg, subs, positions):
    apply = _apply_sublayer
    if cfg.remat_sublayer:
        apply = jax.checkpoint(_apply_sublayer, static_argnums=(0, 2))

    def period_fn(carry, pparams):
        h, aux_acc = carry
        for j, sub in enumerate(subs):
            h, aux, _ = apply(cfg, pparams[f"sub{j}"], sub, h, positions)
            aux_acc = aux_acc + aux
        return (_maybe_shard_h(cfg, h), aux_acc), None

    if cfg.no_remat:
        return period_fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            period_fn, policy=jax.checkpoint_policies.dots_saveable)
    if cfg.remat_policy == "dots_nb":
        # save weight-activation matmuls; recompute attention scores
        # (batch-dim dots) and elementwise — the sweet spot measured in
        # EXPERIMENTS.md §Perf
        return jax.checkpoint(
            period_fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(period_fn)


def forward_hidden(cfg: ArchConfig, params, tokens, frontend_embeds=None):
    """Like ``forward`` but returns the final-norm hidden states instead of
    logits — the vocab-chunked loss path applies the LM head itself."""
    h = _embed_inputs(cfg, params, tokens, frontend_embeds)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    subs = cfg.sublayers()
    aux_total = jnp.zeros((), jnp.float32)
    for p in params.get("prefix", []):
        h, aux, _ = _apply_sublayer(cfg, p, cfg.prefix_sublayer(), h,
                                    positions)
        aux_total = aux_total + aux
    h = _maybe_shard_h(cfg, h)
    period_fn = _make_period_fn(cfg, subs, positions)
    (h, aux_total), _ = jax.lax.scan(period_fn, (h, aux_total),
                                     params["stack"])
    return _norm(cfg, params["final_norm"], h), aux_total


def head_weight(cfg: ArchConfig, params):
    """[d, V] LM-head weight (transposed embedding when tied)."""
    if cfg.tie_embeddings:
        return params["embed"]["table"].T
    return params["lm_head"]["w"]


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------
def prefill(cfg: ArchConfig, params, tokens, frontend_embeds=None):
    """Forward pass that also returns the per-layer cache pytree."""
    h = _embed_inputs(cfg, params, tokens, frontend_embeds)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    subs = cfg.sublayers()
    caches = {"prefix": []}

    for p in params.get("prefix", []):
        h, _, c = _apply_sublayer(cfg, p, cfg.prefix_sublayer(), h, positions)
        caches["prefix"].append(c)

    def period_fn(h, pparams):
        layer_caches = {}
        for j, sub in enumerate(subs):
            h, _, c = _apply_sublayer(cfg, pparams[f"sub{j}"], sub, h,
                                      positions)
            layer_caches[f"sub{j}"] = c
        return h, layer_caches

    h, stack_caches = jax.lax.scan(period_fn, h, params["stack"])
    caches["stack"] = stack_caches
    if not caches["prefix"]:
        del caches["prefix"]
    h = _norm(cfg, params["final_norm"], h)
    return _lm_head(cfg, params, h), caches


def decode_step(cfg: ArchConfig, params, token, cache, pos):
    """token: [B,1] int32; pos: scalar int32 absolute position.

    Returns (logits [B,1,V], new_cache)."""
    h = embed_lookup(params["embed"], token)
    subs = cfg.sublayers()

    new_prefix = []
    for p, c in zip(params.get("prefix", []), cache.get("prefix", [])):
        h, nc = _apply_sublayer_decode(cfg, p, cfg.prefix_sublayer(), h, c,
                                       pos)
        new_prefix.append(nc)

    def period_fn(h, inp):
        pparams, pcache = inp
        new_caches = {}
        for j, sub in enumerate(subs):
            h, nc = _apply_sublayer_decode(cfg, pparams[f"sub{j}"], sub, h,
                                           pcache[f"sub{j}"], pos)
            new_caches[f"sub{j}"] = nc
        return h, new_caches

    h, new_stack = jax.lax.scan(period_fn, h, (params["stack"],
                                               cache["stack"]))
    h = _norm(cfg, params["final_norm"], h)
    logits = _lm_head(cfg, params, h)
    new_cache = {"stack": new_stack}
    if new_prefix:
        new_cache["prefix"] = new_prefix
    return logits, new_cache


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------
def _sublayer_cache(cfg, sub: SubLayer, batch, max_seq, dtype):
    c = {}
    if sub.mixer in (MIXER_ATTN, MIXER_ATTN_GLOBAL):
        kind, width = attn.mask_spec_for(cfg, sub.mixer)
        c["mixer"] = attn.init_attn_cache(cfg, batch, max_seq, kind, width,
                                          dtype)
    elif sub.mixer == MIXER_MLA:
        c["mixer"] = attn.init_mla_cache(cfg, batch, max_seq, dtype)
    elif sub.mixer == MIXER_MAMBA:
        c["mixer"] = mamba_mod.init_mamba_cache(cfg, batch, dtype)
    else:
        r = rwkv_mod.init_rwkv_cache(cfg, batch, dtype)
        c["mixer"] = {"wkv": r["wkv"], "shift": r["shift_tm"]}
    if sub.mlp == MLP_RWKV:
        c["mlp"] = {"shift": jnp.zeros((batch, cfg.d_model), dtype)}
    return c


def init_cache(cfg: ArchConfig, batch, max_seq, dtype=jnp.float32):
    subs = cfg.sublayers()
    period = {f"sub{j}": _sublayer_cache(cfg, sub, batch, max_seq, dtype)
              for j, sub in enumerate(subs)}
    stack = jax.tree_util.tree_map(
        lambda a: jnp.zeros((cfg.n_periods, *a.shape), a.dtype), period)
    cache = {"stack": stack}
    if cfg.first_k_dense:
        cache["prefix"] = [
            _sublayer_cache(cfg, cfg.prefix_sublayer(), batch, max_seq, dtype)
            for _ in range(cfg.first_k_dense)]
    return cache


def grow_cache(cfg: ArchConfig, cache, batch, max_seq, dtype=jnp.float32):
    """Pad a prefill-produced cache out to ``max_seq`` decode capacity.

    Full-attention / MLA caches grow along the sequence axis (zero-padded at
    the tail — future slots); ring (swa) / chunk / SSM caches are already in
    decode layout and pass through unchanged."""
    target = jax.eval_shape(lambda: init_cache(cfg, batch, max_seq, dtype))

    def pad(t, c):
        if tuple(t.shape) == tuple(c.shape):
            return c
        padding = [(0, ts - cs) for ts, cs in zip(t.shape, c.shape)]
        return jnp.pad(c, padding)

    return jax.tree_util.tree_map(pad, target, cache)


def param_count(cfg: ArchConfig, active_only=False) -> int:
    """Analytic parameter count; active_only counts top-k routed experts."""
    import math
    shapes = jax.eval_shape(
        functools.partial(init_params, cfg, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))
    total = sum(math.prod(l.shape) for l in
                jax.tree_util.tree_leaves(shapes))
    if active_only and cfg.n_routed_experts:
        # subtract inactive routed-expert weights
        E, k = cfg.n_routed_experts, cfg.moe_top_k
        n_moe_layers = sum(1 for s in cfg.sublayers() if s.mlp == MLP_MOE) \
            * cfg.n_periods
        expert_params = 3 * cfg.d_model * cfg.moe_d_ff
        total -= n_moe_layers * (E - k) * expert_params
    return total

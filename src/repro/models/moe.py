"""Mixture-of-Experts with capacity-based sort dispatch (GShard/MaxText-style
token dropping) — lowers to all-to-all/gather under pjit with experts sharded
on the ``model`` mesh axis.

Two paths:
  * ``moe_fwd``      — train/prefill: per-batch-row sort dispatch into an
                        [B, E, C, d] buffer, expert einsum, weighted combine.
  * ``moe_decode``   — S==1: dense-mask combine (compute all experts, mask);
                        cheap in absolute FLOPs at decode batch sizes and
                        avoids gathering expert weights per token (DESIGN.md).

Returns (y, aux_loss) where aux_loss is the switch-style load-balance loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.modules import dense_init, swiglu_mlp, swiglu_mlp_init


def init_moe(cfg, key, dtype):
    E, d, f = cfg.n_routed_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),   # router kept fp32
        "w_gate": dense_init(ks[1], d, (E, f), dtype).transpose(1, 0, 2),
        "w_up": dense_init(ks[2], d, (E, f), dtype).transpose(1, 0, 2),
        "w_down": dense_init(ks[3], f, (E, d), dtype).transpose(1, 0, 2),
    }
    if cfg.n_shared_experts:
        p["shared"] = swiglu_mlp_init(
            ks[4], d, cfg.moe_d_ff * cfg.n_shared_experts, dtype)
    return p


def _router(cfg, p, x):
    """x:[..., d] -> (top-k normalized gates [..., k], expert idx [..., k],
    aux load-balance loss)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    # switch-style aux loss: E * mean(fraction_routed * mean_prob)
    E = cfg.n_routed_experts
    onehot = jax.nn.one_hot(idx[..., 0], E)               # top-1 assignment
    frac = jnp.mean(onehot.reshape(-1, E), axis=0)
    mean_prob = jnp.mean(probs.reshape(-1, E), axis=0)
    aux = E * jnp.sum(frac * mean_prob) * cfg.router_aux_coef
    return gates.astype(x.dtype), idx, aux


def moe_fwd(cfg, p, x):
    """x: [B, S, d].  Sort-based capacity dispatch per batch row."""
    B, S, d = x.shape
    E, k = cfg.n_routed_experts, cfg.moe_top_k
    C = int(np.ceil(S * k * cfg.capacity_factor / E))
    gates, idx, aux = _router(cfg, p, x)                  # [B,S,k]

    flat_e = idx.reshape(B, S * k)                        # expert of assignment
    flat_g = gates.reshape(B, S * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)     # [B, S*k]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    sorted_g = jnp.take_along_axis(flat_g, order, axis=-1)
    sorted_tok = order // k                               # source token index
    # position within expert = rank - start_offset(expert)
    onehot = jax.nn.one_hot(sorted_e, E, dtype=jnp.int32)  # [B, S*k, E]
    counts = jnp.cumsum(jnp.sum(onehot, axis=1), axis=-1)  # [B, E] inclusive
    starts = counts - jnp.sum(onehot, axis=1)              # exclusive starts
    pos_in_e = jnp.arange(S * k)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=-1)
    keep = pos_in_e < C
    slot = sorted_e * C + jnp.where(keep, pos_in_e, 0)

    xs = jnp.take_along_axis(x, sorted_tok[..., None], axis=1)  # [B,S*k,d]
    xs = xs * keep[..., None].astype(x.dtype)

    def scatter_row(buf_slot, vals):
        return jnp.zeros((E * C, d), x.dtype).at[buf_slot].add(vals)

    buf = jax.vmap(scatter_row)(slot, xs).reshape(B, E, C, d)

    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"]).reshape(B, E * C, d)

    gathered = jnp.take_along_axis(out_buf, slot[..., None], axis=1)
    gathered = gathered * (sorted_g * keep)[..., None]
    y = jnp.zeros_like(x)

    def combine_row(y0, tok, vals):
        return y0.at[tok].add(vals)

    y = jax.vmap(combine_row)(y, sorted_tok, gathered)
    if cfg.n_shared_experts:
        y = y + swiglu_mlp(p["shared"], x)
    return y, aux


def moe_decode(cfg, p, x):
    """x: [B, 1, d] — dense-mask combine over all experts."""
    B, S, d = x.shape
    E = cfg.n_routed_experts
    gates, idx, aux = _router(cfg, p, x)                  # [B,1,k]
    mask = jnp.sum(jax.nn.one_hot(idx, E, dtype=x.dtype) *
                   gates[..., None], axis=-2)             # [B,1,E]
    g = jnp.einsum("bsd,edf->bsef", x, p["w_gate"])
    u = jnp.einsum("bsd,edf->bsef", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    per_e = jnp.einsum("bsef,efd->bsed", h, p["w_down"])
    y = jnp.einsum("bsed,bse->bsd", per_e, mask)
    if cfg.n_shared_experts:
        y = y + swiglu_mlp(p["shared"], x)
    return y, aux

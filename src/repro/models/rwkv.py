"""RWKV6 ("Finch") block: time-mix with data-dependent decay + squared-ReLU
channel-mix [arXiv:2404.05892].

Recurrence (per head, head size N):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t ( S_{t-1} + diag(u) k_t v_t^T )
with data-dependent decay w_t = exp(-exp(w0 + tanh(x_t A) B)) — the Finch
hallmark.  Token-shift interpolation feeds r/k/v/w/g projections.

State: wkv [B, H, N, N] (fp32), shift [B, d] (last token), per block; the
channel-mix keeps its own shift state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.modules import chunked_scan, dense_init

SCAN_CHUNK = 64
DECAY_LORA = 64


def _heads(cfg):
    assert cfg.d_model % cfg.rwkv_head_size == 0
    return cfg.d_model // cfg.rwkv_head_size


def init_time_mix(cfg, key, dtype):
    d, H, N = cfg.d_model, _heads(cfg), cfg.rwkv_head_size
    ks = jax.random.split(key, 9)
    return {
        "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25).astype(dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "wA": dense_init(ks[1], d, DECAY_LORA, dtype, scale=0.01),
        "wB": dense_init(ks[2], DECAY_LORA, d, dtype, scale=0.01),
        "wr": dense_init(ks[3], d, d, dtype),
        "wk": dense_init(ks[4], d, d, dtype),
        "wv": dense_init(ks[5], d, d, dtype),
        "wg": dense_init(ks[6], d, d, dtype),
        "wo": dense_init(ks[7], d, d, dtype),
        "u": (jax.random.normal(ks[8], (H, N)) * 0.1).astype(jnp.float32),
        "ln_scale": jnp.ones((d,), dtype),   # per-head groupnorm on output
    }


def _tm_projections(cfg, p, x, x_prev):
    """Token-shift mix then project. x, x_prev: [..., d]."""
    mu = p["mu"].astype(jnp.float32)
    xf, xpf = x.astype(jnp.float32), x_prev.astype(jnp.float32)
    mix = lambda i: (xf + mu[i] * (xpf - xf)).astype(x.dtype)
    r = jnp.einsum("...d,de->...e", mix(0), p["wr"])
    k = jnp.einsum("...d,de->...e", mix(1), p["wk"])
    v = jnp.einsum("...d,de->...e", mix(2), p["wv"])
    wx = mix(3)
    g = jnp.einsum("...d,de->...e", mix(4), p["wg"])
    dec = jnp.einsum("...d,dl->...l", wx, p["wA"])
    dec = jnp.einsum("...l,ld->...d", jnp.tanh(dec.astype(jnp.float32)
                                               ).astype(x.dtype), p["wB"])
    logw = p["w0"] + dec.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(logw))                     # in (0,1), data-dependent
    return r, k, v, w, g


def _wkv_step(p, S, r_t, k_t, v_t, w_t):
    """S:[B,H,N,N]; r/k/v/w: [B,H,N] (fp32 recurrence)."""
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r_t, k_t, v_t, w_t))
    kv = kf[..., :, None] * vf[..., None, :]        # [B,H,N,N]
    y = jnp.einsum("bhn,bhnm->bhm", rf, S + p["u"][..., None] * kv)
    S = wf[..., None] * S + kv
    return S, y


def time_mix_fwd(cfg, p, x, x_prev_last=None):
    """x: [B,S,d] -> (y, cache {'wkv','shift'})."""
    B, S, d = x.shape
    H, N = _heads(cfg), cfg.rwkv_head_size
    prev = jnp.concatenate(
        [jnp.zeros((B, 1, d), x.dtype) if x_prev_last is None
         else x_prev_last[:, None, :], x[:, :-1, :]], axis=1)
    r, k, v, w, g = _tm_projections(cfg, p, x, prev)
    rh, kh, vh = (a.reshape(B, S, H, N) for a in (r, k, v))
    wh = w.reshape(B, S, H, N)

    def body(Sst, inp):
        r_t, k_t, v_t, w_t = inp
        Sst, y = _wkv_step(p, Sst, r_t, k_t, v_t, w_t)
        return Sst, y

    S0 = jnp.zeros((B, H, N, N), jnp.float32)
    xs = tuple(jnp.swapaxes(a, 0, 1) for a in (rh, kh, vh, wh))
    S_last, ys = chunked_scan(body, S0, xs, SCAN_CHUNK)
    y = jnp.swapaxes(ys, 0, 1).reshape(B, S, d)     # fp32
    y = _out_norm(cfg, p, y, g)
    return y, {"wkv": S_last, "shift": x[:, -1, :]}


def time_mix_decode(cfg, p, x, cache):
    """x: [B,1,d]."""
    B, _, d = x.shape
    H, N = _heads(cfg), cfg.rwkv_head_size
    r, k, v, w, g = _tm_projections(cfg, p, x[:, 0], cache["shift"])
    Sst, y = _wkv_step(p, cache["wkv"], r.reshape(B, H, N),
                       k.reshape(B, H, N), v.reshape(B, H, N),
                       w.reshape(B, H, N))
    y = _out_norm(cfg, p, y.reshape(B, 1, d), g[:, None, :])
    return y, {"wkv": Sst, "shift": x[:, 0, :]}


def _out_norm(cfg, p, y, g):
    """Per-head groupnorm then silu gate then output proj."""
    B = y.shape[0]
    H, N = _heads(cfg), cfg.rwkv_head_size
    yh = y.reshape(*y.shape[:-1], H, N)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(y.shape) * p["ln_scale"].astype(jnp.float32)
    y = y.astype(g.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(g.dtype)
    return jnp.einsum("...d,de->...e", y, p["wo"])


# ---------------------------------------------------------------------------
# channel mix
# ---------------------------------------------------------------------------
def init_channel_mix(cfg, key, dtype):
    ks = jax.random.split(key, 3)
    return {
        "mu": (jax.random.uniform(ks[0], (2, cfg.d_model)) * 0.5
               + 0.25).astype(dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
        "wv": dense_init(ks[2], cfg.d_ff, cfg.d_model, dtype),
        "wr": dense_init(ks[0], cfg.d_model, cfg.d_model, dtype),
    }


def channel_mix_fwd(cfg, p, x, x_prev_last=None):
    B, S, d = x.shape
    prev = jnp.concatenate(
        [jnp.zeros((B, 1, d), x.dtype) if x_prev_last is None
         else x_prev_last[:, None, :], x[:, :-1, :]], axis=1)
    y, _ = _cm(cfg, p, x, prev)
    return y, {"shift": x[:, -1, :]}


def channel_mix_decode(cfg, p, x, cache):
    y, _ = _cm(cfg, p, x, cache["shift"][:, None, :])
    return y, {"shift": x[:, 0, :]}


def _cm(cfg, p, x, prev):
    mu = p["mu"].astype(jnp.float32)
    xf, pf = x.astype(jnp.float32), prev.astype(jnp.float32)
    xk = (xf + mu[0] * (pf - xf)).astype(x.dtype)
    xr = (xf + mu[1] * (pf - xf)).astype(x.dtype)
    k = jnp.einsum("...d,df->...f", xk, p["wk"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    v = jnp.einsum("...f,fd->...d", k, p["wv"])
    r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", xr, p["wr"])
                       .astype(jnp.float32)).astype(x.dtype)
    return r * v, None


def init_rwkv_cache(cfg, batch, dtype):
    H, N = _heads(cfg), cfg.rwkv_head_size
    return {
        "wkv": jnp.zeros((batch, H, N, N), jnp.float32),
        "shift_tm": jnp.zeros((batch, cfg.d_model), dtype),
        "shift_cm": jnp.zeros((batch, cfg.d_model), dtype),
    }

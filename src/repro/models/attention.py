"""Softmax attention family: GQA/MHA (+ RoPE, QKV bias, sliding-window,
chunked-local) and DeepSeek MLA (compressed-KV latent attention, with both
naive and absorbed decode).

Cache layouts (per layer; the transformer stacks a leading period axis):
  full/global : k,v  [B, S, Kv, hd]        (S = max context)
  swa         : k,v  [B, W, Kv, hd]        ring buffer over the window
  chunk       : k,v  [B, C, Kv, hd]        current local chunk only
  mla         : c_kv [B, S, lora], k_rope [B, S, rope_dim]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (MIXER_ATTN, MIXER_ATTN_GLOBAL)
from repro.models.modules import apply_rope, dense_init

NEG_INF = -1e30


def mask_spec_for(cfg, mixer_kind):
    """Resolve (mask_kind, width) for a sublayer's attention."""
    if mixer_kind == MIXER_ATTN_GLOBAL:
        return "full", 0
    if cfg.sliding_window:
        return "swa", cfg.sliding_window
    if cfg.attn_chunk:
        return "chunk", cfg.attn_chunk
    return "full", 0


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def init_attention(cfg, key, dtype):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, (cfg.n_heads, hd), dtype),
        "wk": dense_init(ks[1], cfg.d_model, (cfg.n_kv_heads, hd), dtype),
        "wv": dense_init(ks[2], cfg.d_model, (cfg.n_kv_heads, hd), dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype,
                         scale=1.0 / np.sqrt(cfg.n_heads * hd)).reshape(
                             cfg.n_heads, hd, cfg.d_model),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
    return p


def _qkv(cfg, p, x):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def _sdpa(q, k, v, bias):
    """q:[B,Sq,H,hd] k,v:[B,Sk,Kv,hd] bias:[B or 1, 1, Sq, Sk] additive."""
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd) + bias[:, :, None, :, :]
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, Sq, H, hd)


def _causal_bias(Sq, Sk, q_pos, k_pos, mask_kind, width):
    """Additive bias [1, 1, Sq, Sk] from absolute positions."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    ok = kp <= qp
    if mask_kind == "swa":
        ok &= (qp - kp) < width
    elif mask_kind == "chunk":
        ok &= (qp // width) == (kp // width)
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    return bias[None, None]


BLOCKED_SDPA_THRESHOLD = 1024   # S above which the q-blocked path is used
SDPA_BLOCK_Q = 128


def _sdpa_any(q, k, v, positions, mask_kind, width):
    """Dense S x S scores for short sequences; q-blocked scan (flash-style
    schedule, O(S * block_q) live scores) beyond BLOCKED_SDPA_THRESHOLD —
    without it a 4k-32k training/prefill step materializes an [H, S, S] f32
    scores tensor per layer (tens of GB/device)."""
    S = q.shape[1]
    if S <= BLOCKED_SDPA_THRESHOLD or S % SDPA_BLOCK_Q:
        bias = _causal_bias(S, S, positions, positions, mask_kind, width)
        return _sdpa(q, k, v, bias)
    bq = SDPA_BLOCK_Q

    @jax.checkpoint
    def body(_, qi):
        qs = qi * bq
        qb = jax.lax.dynamic_slice_in_dim(q, qs, bq, axis=1)
        qpos = jax.lax.dynamic_slice_in_dim(positions, qs, bq)
        bias = _causal_bias(bq, S, qpos, positions, mask_kind, width)
        return None, _sdpa(qb, k, v, bias)

    _, blocks = jax.lax.scan(body, None, jnp.arange(S // bq))
    out = jnp.swapaxes(blocks, 0, 1)            # [B, nb, bq, H, hd]
    return out.reshape(q.shape)


def attention_fwd(cfg, p, x, positions, mask_kind="full", width=0):
    """Full-sequence attention (train / prefill). Returns (y, cache_kv).

    The returned cache is already in *decode layout*: full-S for full
    attention, ring-of-W for swa, current-chunk for chunked (see
    ``to_decode_layout``)."""
    q, k, v = _qkv(cfg, p, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    pos = positions[0] if positions.ndim > 1 else positions
    out = _sdpa_any(q, k, v, pos, mask_kind, width)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": to_decode_layout(k, mask_kind, width),
               "v": to_decode_layout(v, mask_kind, width)}


def to_decode_layout(kv, mask_kind, width):
    """Convert a [B, S, Kv, hd] prefilled tensor into the decode cache layout.

    swa  : ring of the last ``width`` entries, entry for position p at p % W.
    chunk: the in-progress local chunk (positions >= S - S%C), at p % C.
    full : unchanged.
    """
    if mask_kind == "full":
        return kv
    B, S, Kv, hd = kv.shape
    W = width
    if mask_kind == "swa":
        if S < W:
            pad = jnp.zeros((B, W - S, Kv, hd), kv.dtype)
            return jnp.concatenate([kv, pad], axis=1)  # slot p%W == p
        block = kv[:, S - W:]                          # positions S-W .. S-1
        return jnp.roll(block, S % W, axis=1)          # slot (S-W+i)%W
    # chunk
    filled = S % W
    block = kv[:, S - filled:] if filled else kv[:, :0]
    pad = jnp.zeros((B, W - filled, Kv, hd), kv.dtype)
    return jnp.concatenate([block, pad], axis=1)


def attention_decode(cfg, p, x, cache, pos, mask_kind="full", width=0):
    """One-token decode. x:[B,1,d]; pos: scalar int32 OR per-sequence [B]
    vector (continuous batching — full-attention path only).

    Writes the new K/V into the cache (ring/chunk-local for swa/chunk) and
    attends with the appropriate validity mask.  Returns (y, new_cache).
    """
    B = x.shape[0]
    q, k_new, v_new = _qkv(cfg, p, x)
    pos = jnp.asarray(pos, jnp.int32)
    per_seq = pos.ndim == 1
    posv = pos[:, None] if per_seq else jnp.full((1,), pos, jnp.int32)
    q = apply_rope(q, posv, cfg.rope_theta)
    k_new = apply_rope(k_new, posv, cfg.rope_theta)

    W = cache["k"].shape[1]
    if mask_kind in ("swa", "chunk"):
        assert not per_seq, "ring caches require a scalar position"
        slot = pos % W
        k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    else:
        # one-hot masked write: unlike dynamic-update-slice at a traced
        # index, this stays elementwise under GSPMD when the cache's long
        # sequence axis is sharded over ``model`` (no gather/reshard), and
        # it supports per-sequence positions for free.
        pb = pos[:, None] if per_seq else pos
        sel = (jnp.arange(W)[None, :] == pb).astype(
            cache["k"].dtype)[..., None, None]        # [B or 1, W, 1, 1]
        k = cache["k"] * (1 - sel) + k_new * sel
        v = cache["v"] * (1 - sel) + v_new * sel

    idx = jnp.arange(W)
    if mask_kind == "swa":
        # slot i holds absolute position pos - ((slot - i) mod W); valid if >= 0
        slot_pos = pos - jnp.mod(slot - idx, W)
        ok = slot_pos >= 0
    elif mask_kind == "chunk":
        ok = idx <= slot                      # only the current chunk's prefix
    else:
        ok = idx[None, :] <= (pos[:, None] if per_seq else pos)
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    bias = bias.reshape(-1, 1, 1, W)          # [B or 1, 1, 1, W]
    out = _sdpa(q, k, v, bias)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": k, "v": v}


def init_attn_cache(cfg, batch, max_seq, mask_kind, width, dtype):
    hd = cfg.resolved_head_dim
    S = {"full": max_seq, "swa": min(width, max_seq),
         "chunk": min(width, max_seq)}[mask_kind]
    z = jnp.zeros((batch, S, cfg.n_kv_heads, hd), dtype)
    return {"k": z, "v": z}


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------
def init_mla(cfg, key, dtype):
    ks = jax.random.split(key, 6)
    qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "wq": dense_init(ks[0], cfg.d_model, (cfg.n_heads, qd), dtype),
        "w_dkv": dense_init(ks[1], cfg.d_model, cfg.kv_lora_rank, dtype),
        "w_krope": dense_init(ks[2], cfg.d_model, cfg.qk_rope_head_dim, dtype),
        "kv_norm": {"scale": jnp.ones((cfg.kv_lora_rank,), dtype)},
        "w_uk": dense_init(ks[3], cfg.kv_lora_rank,
                           (cfg.n_heads, cfg.qk_nope_head_dim), dtype),
        "w_uv": dense_init(ks[4], cfg.kv_lora_rank,
                           (cfg.n_heads, cfg.v_head_dim), dtype),
        "wo": dense_init(ks[5], cfg.n_heads * cfg.v_head_dim, cfg.d_model,
                         dtype).reshape(cfg.n_heads, cfg.v_head_dim,
                                        cfg.d_model),
    }


def _mla_compress(cfg, p, x, positions):
    from repro.models.modules import rmsnorm
    c_kv = jnp.einsum("bsd,dl->bsl", x, p["w_dkv"])
    c_kv = rmsnorm(p["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_krope"])[:, :, None, :]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def _mla_q(cfg, p, x, positions):
    q = jnp.einsum("bsd,dhq->bshq", x, p["wq"])
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_head_dim:], positions,
                        cfg.rope_theta)
    return q_nope, q_rope


def _mla_attend(cfg, p, q_nope, q_rope, k_nope, k_rope, v, qpos, kpos):
    scale = 1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    scores = (jnp.einsum("bshn,bthn->bhst", q_nope, k_nope) +
              jnp.einsum("bshr,btr->bhst", q_rope, k_rope)
              ).astype(jnp.float32)
    bias = _causal_bias(len(qpos), len(kpos), qpos, kpos, "full", 0)
    w = jax.nn.softmax(scores * scale + bias[:, 0], axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthv->bshv", w, v)


def mla_fwd(cfg, p, x, positions):
    """Full-sequence MLA (train/prefill), q-blocked beyond the dense
    threshold (same flash-style schedule as ``_sdpa_any``).
    Returns (y, cache)."""
    c_kv, k_rope = _mla_compress(cfg, p, x, positions)
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    k_nope = jnp.einsum("btl,lhn->bthn", c_kv, p["w_uk"])
    v = jnp.einsum("btl,lhv->bthv", c_kv, p["w_uv"])
    pos = positions[0] if positions.ndim > 1 else positions
    S = x.shape[1]
    if S <= BLOCKED_SDPA_THRESHOLD or S % SDPA_BLOCK_Q:
        out = _mla_attend(cfg, p, q_nope, q_rope, k_nope, k_rope, v, pos, pos)
    else:
        bq = SDPA_BLOCK_Q

        @jax.checkpoint
        def body(_, qi):
            qs = qi * bq
            qb_n = jax.lax.dynamic_slice_in_dim(q_nope, qs, bq, axis=1)
            qb_r = jax.lax.dynamic_slice_in_dim(q_rope, qs, bq, axis=1)
            qpos = jax.lax.dynamic_slice_in_dim(pos, qs, bq)
            return None, _mla_attend(cfg, p, qb_n, qb_r, k_nope, k_rope, v,
                                     qpos, pos)

        _, blocks = jax.lax.scan(body, None, jnp.arange(S // bq))
        out = jnp.swapaxes(blocks, 0, 1).reshape(
            x.shape[0], S, cfg.n_heads, cfg.v_head_dim)
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(cfg, p, x, cache, pos):
    """One-token MLA decode; naive or absorbed per cfg.mla_absorb.
    ``pos`` may be a scalar or a per-sequence [B] vector."""
    pos = jnp.asarray(pos, jnp.int32)
    per_seq = pos.ndim == 1
    posv = pos[:, None] if per_seq else jnp.full((1,), pos, jnp.int32)
    c_new, kr_new = _mla_compress(cfg, p, x, posv)
    S = cache["c_kv"].shape[1]
    pb = pos[:, None] if per_seq else pos
    sel = (jnp.arange(S)[None, :] == pb).astype(
        cache["c_kv"].dtype)[..., None]
    c_kv = cache["c_kv"] * (1 - sel) + c_new * sel
    k_rope = cache["k_rope"] * (1 - sel) + kr_new * sel
    q_nope, q_rope = _mla_q(cfg, p, x, posv)      # [B,1,H,*]
    ok = jnp.arange(S)[None, :] <= (pos[:, None] if per_seq else pos)
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    bias = bias.reshape(-1, 1, 1, S)          # [B or 1, 1, 1, S]
    scale = 1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)

    if cfg.mla_absorb:
        # Absorb W_uk into the query and W_uv into the output: attention runs
        # entirely in the compressed latent space (beyond-paper decode opt).
        q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, p["w_uk"])
        scores = (jnp.einsum("bshl,btl->bhst", q_lat, c_kv) +
                  jnp.einsum("bshr,btr->bhst", q_rope, k_rope)
                  ).astype(jnp.float32)
        w = jax.nn.softmax(scores * scale + bias,
                           axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhst,btl->bshl", w, c_kv)
        out = jnp.einsum("bshl,lhv->bshv", ctx, p["w_uv"])
    else:
        k_nope = jnp.einsum("btl,lhn->bthn", c_kv, p["w_uk"])
        v = jnp.einsum("btl,lhv->bthv", c_kv, p["w_uv"])
        scores = (jnp.einsum("bshn,bthn->bhst", q_nope, k_nope) +
                  jnp.einsum("bshr,btr->bhst", q_rope, k_rope)
                  ).astype(jnp.float32)
        w = jax.nn.softmax(scores * scale + bias,
                           axis=-1).astype(x.dtype)
        out = jnp.einsum("bhst,bthv->bshv", w, v)
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, {"c_kv": c_kv, "k_rope": k_rope}


def init_mla_cache(cfg, batch, max_seq, dtype):
    return {
        "c_kv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dtype),
    }

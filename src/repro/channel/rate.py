"""Transmission rate and delays (Eqs. 5, 6, 8)."""
from __future__ import annotations

import numpy as np

from repro.channel.params import ChannelParams


def shannon_rate(p: ChannelParams, gain: float, distance: float) -> float:
    """Eq. (5): r = B log2(1 + p_m h d^-alpha / sigma^2)."""
    snr = p.p_m * gain * distance ** (-p.alpha) / p.sigma2
    return p.B * np.log2(1.0 + snr)


def upload_delay(p: ChannelParams, rate: float) -> float:
    """Eq. (6): C_u = |w| / r."""
    return p.model_bits / max(rate, 1e-12)


def training_delay(p: ChannelParams, i: int) -> float:
    """Eq. (8): C_l = D_i C_y / delta_i   (i is 1-based)."""
    return p.data_count(i) * p.C_y / p.delta(i)

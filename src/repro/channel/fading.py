"""Rayleigh fading with an AR(1) (autoregressive, Jakes-style) evolution per
vehicle, as in the paper's simulation setup ([18]-[20]): h^i(t) is the power
gain |g|^2 of a complex Gaussian g that decorrelates with coherence rho.
"""
from __future__ import annotations

import numpy as np

from repro.channel.params import ChannelParams


class RayleighAR1:
    def __init__(self, params: ChannelParams, seed: int = 0):
        self.p = params
        self.rng = np.random.default_rng(seed)
        # complex CN(0,1) state per vehicle
        self.g = (self.rng.normal(size=params.K) +
                  1j * self.rng.normal(size=params.K)) / np.sqrt(2)

    def step(self) -> np.ndarray:
        """Advance one slot; returns power gains h^i(t) = |g|^2, shape [K]."""
        rho = self.p.fading_rho
        innov = (self.rng.normal(size=self.p.K) +
                 1j * self.rng.normal(size=self.p.K)) / np.sqrt(2)
        self.g = rho * self.g + np.sqrt(1 - rho ** 2) * innov
        return np.abs(self.g) ** 2

    def gain(self, i: int) -> float:
        return float(np.abs(self.g[i]) ** 2)

"""Rayleigh fading with an AR(1) (autoregressive, Jakes-style) evolution per
vehicle, as in the paper's simulation setup ([18]-[20]): h^i(t) is the power
gain |g|^2 of a complex Gaussian g that decorrelates with coherence rho.
"""
from __future__ import annotations

import numpy as np

from repro.channel.params import ChannelParams


class RayleighAR1:
    def __init__(self, params: ChannelParams, seed: int = 0):
        self.p = params
        self.rng = np.random.default_rng(seed)
        # complex CN(0,1) state per vehicle
        self.g = (self.rng.normal(size=params.K) +
                  1j * self.rng.normal(size=params.K)) / np.sqrt(2)

    def step(self) -> np.ndarray:
        """Advance one slot; returns power gains h^i(t) = |g|^2, shape [K]."""
        rho = self.p.fading_rho
        innov = (self.rng.normal(size=self.p.K) +
                 1j * self.rng.normal(size=self.p.K)) / np.sqrt(2)
        self.g = rho * self.g + np.sqrt(1 - rho ** 2) * innov
        return np.abs(self.g) ** 2

    def steps_block(self, n: int) -> np.ndarray:
        """Advance ``n`` slots; returns gains for each, shape [n, K].

        Bit-identical to ``n`` successive :meth:`step` calls (the (n, 2, K)
        normal draw consumes the generator's bitstream in exactly the
        real/imag per-slot order the scalar path uses) but with one RNG call
        instead of 2n — the fast path when a long-delay event forces the
        simulator to catch the channel up over many slots at once."""
        if n <= 0:
            return np.empty((0, self.p.K))
        rho = self.p.fading_rho
        innov = self.rng.normal(size=(n, 2, self.p.K))
        innov = (innov[:, 0] + 1j * innov[:, 1]) / np.sqrt(2)
        out = np.empty((n, self.p.K))
        scale = np.sqrt(1 - rho ** 2)
        g = self.g
        for t in range(n):
            g = rho * g + scale * innov[t]
            out[t] = np.abs(g) ** 2
        self.g = g
        return out

    def gain(self, i: int) -> float:
        return float(np.abs(self.g[i]) ** 2)


def slot_gain_table(params: ChannelParams, seed: int,
                    n_slots: int) -> np.ndarray:
    """Gains for slots ``0..n_slots-1`` as one ``[n_slots, K]`` table.

    The device-resident engine (DESIGN.md §9) replaces the incremental
    host-side :class:`SlotGainCache` with this precomputed table: the AR(1)
    recursion ``g_t = rho g_{t-1} + s i_t`` is a linear recurrence, so the
    whole table is produced by a *vectorized prefix scan* (log2(n) doubling
    passes of whole-array ops) instead of a per-slot Python loop.  The
    innovations are drawn in a single RNG call with exactly the bitstream
    layout of :meth:`RayleighAR1.steps_block`, so the table agrees with the
    sequential cache to f64 round-off (the summation order differs, not the
    random numbers) — pinned by ``tests/test_engine_conformance.py``."""
    K = params.K
    if n_slots <= 0:
        return np.empty((0, K))
    rng = np.random.default_rng(seed)
    g0 = (rng.normal(size=K) + 1j * rng.normal(size=K)) / np.sqrt(2)
    innov = rng.normal(size=(n_slots, 2, K))
    innov = (innov[:, 0] + 1j * innov[:, 1]) / np.sqrt(2)
    rho = params.fading_rho
    # per-slot affine map g -> A g + B; compose prefixes by doubling
    A = np.full(n_slots, rho)
    B = np.sqrt(1 - rho ** 2) * innov
    shift = 1
    while shift < n_slots:
        A_prev = np.concatenate([np.ones(shift), A[:-shift]])
        B_prev = np.vstack([np.zeros((shift, K), B.dtype), B[:-shift]])
        B = A[:, None] * B_prev + B
        A = A * A_prev
        shift *= 2
    g = A[:, None] * g0[None, :] + B
    return np.abs(g) ** 2


class SlotGainCache:
    """Windowed per-slot gain cache over a :class:`RayleighAR1` process.

    Gains are sampled once per discrete slot ``int(t)`` and kept only for
    the live window: the simulation prunes slots older than the earliest
    pending event every round (the time-ordered consumer can never revisit
    them), so memory is bounded by the event horizon rather than the
    simulation length (DESIGN.md §2)."""

    def __init__(self, fading: RayleighAR1):
        self._fading = fading
        self._cache: dict[int, np.ndarray] = {}
        self._last_slot = -1

    def at(self, t: float) -> np.ndarray:
        """Gains h^i(int(t)), advancing the AR(1) chain as needed."""
        slot = int(t)
        if slot > self._last_slot:
            block = self._fading.steps_block(slot - self._last_slot)
            for j in range(block.shape[0]):
                self._cache[self._last_slot + 1 + j] = block[j]
            self._last_slot = slot
        return self._cache[slot]

    def prune_below(self, t: float) -> None:
        """Drop every slot older than ``int(t)``."""
        keep = int(t)
        for s in [s for s in self._cache if s < keep]:
            del self._cache[s]

    @property
    def last_slot(self) -> int:
        """Highest slot the AR(1) chain has been advanced to (-1 if none).

        The jit-engine planner reads this after its dry run to size the
        precomputed :func:`slot_gain_table` (DESIGN.md §9)."""
        return self._last_slot

    def __len__(self) -> int:
        return len(self._cache)

"""Table I of the paper, as a config object (SI units).

Note on units: the paper lists sigma^2 = 1e-11 mW = 1e-14 W and B = 1e5 Hz;
|w| = 5000 bits.  delta_i = 1.5*(i+5)*1e8 cycles/s (Section V-A, i is the
1-based vehicle index); D_i = 2250 + 3750*i images.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ChannelParams:
    K: int = 10                    # vehicles
    v: float = 20.0                # m/s, eastbound
    H: float = 10.0                # RSU antenna height, m
    d_y: float = 10.0              # lateral offset, m
    C_y: float = 1e5               # CPU cycles per datum
    model_bits: float = 5000.0     # |w|
    B: float = 1e5                 # bandwidth, Hz
    p_m: float = 0.1               # transmit power, W
    alpha: float = 2.0             # path-loss exponent
    sigma2: float = 1e-14          # noise power, W (1e-11 mW)
    beta: float = 0.5              # aggregation proportion (Eq. 11)
    zeta: float = 0.9              # training-delay decay base (Eq. 9)
    gamma: float = 0.9             # uploading-delay decay base (Eq. 7)
    fading_rho: float = 0.95       # AR(1) coherence of the Rayleigh channel
    coverage: float = 400.0        # RSU coverage half-width, m (re-entry wrap)
    # platoon size (0/1 = Table-I heterogeneity per vehicle).  With
    # ``platoon = n``, vehicles travel in convoys of n that share the
    # platoon leader's compute and data volume, so every member's training
    # delay is identical and their uploads arrive in near-simultaneous
    # bursts — the bursty-arrival stress regime of the
    # ``platoon-burst-k500`` scenario (DESIGN.md §9).
    platoon: int = 0

    def _platoon_leader(self, i: int) -> int:
        if self.platoon > 1:
            return ((i - 1) // self.platoon) * self.platoon + 1
        return i

    def delta(self, i: int) -> float:
        """CPU frequency of vehicle i (1-based), cycles/s."""
        return 1.5 * (self._platoon_leader(i) + 5) * 1e8

    def data_count(self, i: int) -> int:
        """D_i: images carried by vehicle i (1-based)."""
        return 2250 + 3750 * self._platoon_leader(i)

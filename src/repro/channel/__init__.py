from repro.channel.mobility import CorridorMobility, Mobility
from repro.channel.fading import RayleighAR1, SlotGainCache, slot_gain_table
from repro.channel.rate import shannon_rate, upload_delay, training_delay
from repro.channel.params import ChannelParams

__all__ = ["Mobility", "CorridorMobility", "RayleighAR1", "SlotGainCache",
           "slot_gain_table", "shannon_rate", "upload_delay",
           "training_delay", "ChannelParams"]

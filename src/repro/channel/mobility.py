"""Vehicle mobility (Eqs. 3-4): constant eastbound velocity, RSU at origin
with antennas at height H.  Positions are a pure function of time.

Two geometries live here:

- :class:`Mobility` — the paper's world: one RSU, coverage-wrap re-entry.
- :class:`CorridorMobility` — the multi-RSU highway corridor (DESIGN.md
  §8/§10): ``n_rsus`` segments of width ``2*coverage``, RSU j at the center
  of segment j, hard handover at segment edges, wrap-around re-entry at the
  corridor ends.  Every method is vectorized over vehicles *and* times
  (positions are a pure function of time, so whole trajectories fall out of
  one broadcast expression) — the corridor engine and its host planner both
  read this geometry, so there is exactly one definition of "which RSU
  serves vehicle i at time t".
"""
from __future__ import annotations

import numpy as np

from repro.channel.params import ChannelParams


class Mobility:
    """Tracks K vehicles.  x_i(t) = x_i(0) + v t (Eq. 3), with wrap-around
    re-entry at the coverage edge (the paper keeps K vehicles under the RSU;
    re-entry keeps the population constant — documented in DESIGN.md)."""

    def __init__(self, params: ChannelParams, x0: np.ndarray | None = None):
        self.p = params
        if x0 is None:
            # spread vehicles across the western half of the coverage
            x0 = -params.coverage + (2 * params.coverage) * (
                np.arange(params.K) / params.K)
        self.x0 = np.asarray(x0, np.float64)

    def position(self, i: int, t: float) -> np.ndarray:
        """P^i(t) = (d_x, d_y, 0), Eq. (3), with coverage wrap."""
        span = 2 * self.p.coverage
        dx = self.x0[i] + self.p.v * t
        dx = ((dx + self.p.coverage) % span) - self.p.coverage
        return np.array([dx, self.p.d_y, 0.0])

    def distance(self, i: int, t: float) -> float:
        """d^i(t) = || P^i(t) - P_R ||, Eq. (4), P_R = (0, 0, H)."""
        pos = self.position(i, t)
        ref = np.array([0.0, 0.0, self.p.H])
        return float(np.linalg.norm(pos - ref))

    def distances(self, t) -> np.ndarray:
        """All K distances to the RSU at time(s) ``t`` (vectorized Eq. 4,
        same wrap as :meth:`position`).  The selection layer scores whole
        fleets at one decision instant, so it reads this instead of K
        scalar :meth:`distance` calls."""
        span = 2 * self.p.coverage
        dx = self.x0 + self.p.v * np.asarray(t)
        dx = ((dx + self.p.coverage) % span) - self.p.coverage
        return np.sqrt(dx ** 2 + self.p.d_y ** 2 + self.p.H ** 2)

    def next_boundary_crossing(self, i, t):
        """Earliest time ``> t`` at which vehicle ``i`` reaches the coverage
        edge (= its wrap-around re-entry).  Broadcasts — the single-RSU
        counterpart of :meth:`CorridorMobility.next_boundary_crossing`, so
        the selection layer's predicted-residence-time feature reads one
        interface on either geometry."""
        span = 2 * self.p.coverage
        dx = self.x0[np.asarray(i)] + self.p.v * np.asarray(t)
        into = (dx + self.p.coverage) % span
        return np.asarray(t) + (span - into) / self.p.v


class CorridorMobility:
    """Vehicle kinematics along an ``n_rsus``-segment highway corridor.

    RSU j sits at the center of segment j (width ``2*coverage``); a vehicle
    is served by the RSU whose segment contains it (hard handover at segment
    edges), wrapping at the corridor ends so the population stays constant —
    the same re-entry convention as the single-RSU :class:`Mobility`.

    ``i`` and ``t`` may be scalars or arrays and broadcast together, so
    ``serving_rsu(np.arange(K), t)`` is the whole fleet's cell assignment in
    one expression (the public, vectorized promotion of the ad-hoc
    per-vehicle ``_Corridor`` helper the serial handover loop used).

    ``entry`` picks the initial placement when ``x0`` is not given:

    - ``"uniform"`` — spread over the whole corridor (steady-state traffic).
    - ``"rush"``    — the whole fleet packed into the westmost segment, so a
      density wave of platoons enters at one end and propagates east (the
      ``corridor-rush-hour-*`` scenarios).
    """

    def __init__(self, params: ChannelParams, n_rsus: int,
                 x0: np.ndarray | None = None, entry: str = "uniform"):
        self.p = params
        self.n_rsus = n_rsus
        self.span = 2 * params.coverage * n_rsus
        self.cell = 2 * params.coverage
        self.centers = (-self.span / 2
                        + (np.arange(n_rsus) + 0.5) * self.cell)
        if x0 is None:
            frac = np.arange(params.K) / params.K
            if entry == "uniform":
                x0 = -self.span / 2 + self.span * frac
            elif entry == "rush":
                x0 = -self.span / 2 + self.cell * frac
            else:
                raise ValueError(
                    f"unknown entry profile {entry!r}; "
                    "expected 'uniform' or 'rush'")
        self.x0 = np.asarray(x0, np.float64)

    def x(self, i, t):
        """Corridor position of vehicle(s) ``i`` at time(s) ``t`` (Eq. 3
        with corridor wrap).  Broadcasts ``i`` against ``t``."""
        dx = self.x0[np.asarray(i)] + self.p.v * np.asarray(t)
        return ((dx + self.span / 2) % self.span) - self.span / 2

    def serving_rsu(self, i, t):
        """Index of the RSU whose segment contains vehicle ``i`` at ``t``
        (hard handover at segment edges).  Broadcasts; integer-valued."""
        j = ((self.x(i, t) + self.span / 2) // self.cell).astype(np.int64)
        return np.clip(j, 0, self.n_rsus - 1)

    def distance(self, i, t):
        """Distance to the *serving* RSU's antenna (Eq. 4 with the corridor
        serving-cell geometry).  Broadcasts."""
        x = self.x(i, t)
        j = self.serving_rsu(i, t)
        return np.sqrt((x - self.centers[j]) ** 2
                       + self.p.d_y ** 2 + self.p.H ** 2)

    def distances(self, t) -> np.ndarray:
        """All K distances to each vehicle's serving RSU at time(s) ``t``
        (the corridor counterpart of :meth:`Mobility.distances`)."""
        return self.distance(np.arange(self.p.K), t)

    def positions(self, t):
        """All K corridor positions at time(s) ``t``: shape ``[K]`` (or
        ``t.shape + [K]`` for an array of times)."""
        t = np.asarray(t)
        return self.x(np.arange(self.p.K), t[..., None] if t.ndim else t)

    def serving_cells(self, t):
        """All K serving-RSU indices at time(s) ``t``."""
        t = np.asarray(t)
        return self.serving_rsu(np.arange(self.p.K),
                                t[..., None] if t.ndim else t)

    def next_boundary_crossing(self, i, t):
        """Earliest time ``> t`` at which vehicle ``i`` crosses a segment
        boundary (= its next handover or corridor re-entry).  Broadcasts.

        Vehicles move east at constant ``v``, so the crossing is when the
        offset into the current segment reaches the segment width."""
        into = (self.x(i, t) + self.span / 2) % self.cell
        return np.asarray(t) + (self.cell - into) / self.p.v

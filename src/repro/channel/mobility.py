"""Vehicle mobility (Eqs. 3-4): constant eastbound velocity, RSU at origin
with antennas at height H.  Positions are a pure function of time."""
from __future__ import annotations

import numpy as np

from repro.channel.params import ChannelParams


class Mobility:
    """Tracks K vehicles.  x_i(t) = x_i(0) + v t (Eq. 3), with wrap-around
    re-entry at the coverage edge (the paper keeps K vehicles under the RSU;
    re-entry keeps the population constant — documented in DESIGN.md)."""

    def __init__(self, params: ChannelParams, x0: np.ndarray | None = None):
        self.p = params
        if x0 is None:
            # spread vehicles across the western half of the coverage
            x0 = -params.coverage + (2 * params.coverage) * (
                np.arange(params.K) / params.K)
        self.x0 = np.asarray(x0, np.float64)

    def position(self, i: int, t: float) -> np.ndarray:
        """P^i(t) = (d_x, d_y, 0), Eq. (3), with coverage wrap."""
        span = 2 * self.p.coverage
        dx = self.x0[i] + self.p.v * t
        dx = ((dx + self.p.coverage) % span) - self.p.coverage
        return np.array([dx, self.p.d_y, 0.0])

    def distance(self, i: int, t: float) -> float:
        """d^i(t) = || P^i(t) - P_R ||, Eq. (4), P_R = (0, 0, H)."""
        pos = self.position(i, t)
        ref = np.array([0.0, 0.0, self.p.H])
        return float(np.linalg.norm(pos - ref))

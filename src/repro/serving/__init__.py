from repro.serving.batcher import BatchedServer, Request

__all__ = ["BatchedServer", "Request"]

"""Slot-based continuous-batching server for the decode path.

The decode shapes (decode_32k / long_500k) measure ONE step of exactly this
runtime: a fixed pool of B cache slots, each slot independently somewhere in
its sequence, one fused ``serve_step`` advancing every active slot per tick.
New requests claim free slots (their prompt is prefilled into the slot's
cache region); finished slots free immediately — no batch barrier.

Per-slot positions require position-aware decode, so the server drives
``decode_step`` with a per-slot ``pos`` vector via ``jax.vmap`` over the
batch dim of the cache pytree.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T


@dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [P] int32
    max_new: int
    out: list = field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Continuous batching over a fixed slot pool."""

    def __init__(self, cfg: ArchConfig, params, n_slots: int = 4,
                 max_seq: int = 128, eos_id: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.cache = T.init_cache(cfg, n_slots, max_seq)
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int64)
        self.queue: list[Request] = []
        self._rid = 0

        def step_all(params, tokens, cache, pos_vec):
            """One fused decode step for ALL slots: ``decode_step`` accepts
            a per-sequence position vector (continuous batching)."""
            logits, new_cache = T.decode_step(cfg, params, tokens, cache,
                                              pos_vec)
            return jnp.argmax(logits, -1).astype(jnp.int32), new_cache

        self._step = jax.jit(step_all)
        self._prefill = jax.jit(
            lambda p, t: T.prefill(cfg, p, t))
        self._last_tokens = np.zeros((n_slots, 1), np.int32)

    # -- client API ---------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int) -> Request:
        req = Request(self._rid, np.asarray(prompt, np.int32), max_new)
        self._rid += 1
        self.queue.append(req)
        return req

    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def pending(self) -> int:
        return len(self.queue)

    # -- engine ---------------------------------------------------------------
    def _admit(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            logits, cache = self._prefill(self.params,
                                          jnp.asarray(req.prompt[None]))
            cache = T.grow_cache(self.cfg, cache, 1, self.max_seq)

            # write the slot's cache row; stack leaves carry the period axis
            # first (batch at axis 1), everything else has batch leading
            def write(path, full, one):
                names = [str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path]
                if "stack" in names:
                    return full.at[:, slot].set(one[:, 0])
                return full.at[slot].set(one[0])

            self.cache = jax.tree_util.tree_map_with_path(
                write, self.cache, cache)
            first = int(jnp.argmax(logits[0, -1]))
            req.out.append(first)
            self.slot_req[slot] = req
            self.slot_pos[slot] = len(req.prompt)
            self._last_tokens[slot, 0] = first

    def tick(self):
        """One decode step for every active slot."""
        self._admit()
        if self.active() == 0:
            return
        pos = jnp.asarray(self.slot_pos, jnp.int32)
        tokens = jnp.asarray(self._last_tokens)          # [n_slots, 1]
        next_tokens, self.cache = self._step(self.params, tokens,
                                             self.cache, pos)
        next_np = np.asarray(next_tokens)[:, 0]
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = int(next_np[slot])
            req.out.append(tok)
            self.slot_pos[slot] += 1
            self._last_tokens[slot, 0] = tok
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if len(req.out) >= req.max_new or hit_eos or \
                    self.slot_pos[slot] >= self.max_seq - 1:
                req.done = True
                self.slot_req[slot] = None       # slot freed immediately

    def run_until_drained(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or self.active()) and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks
